#!/usr/bin/env python
"""Docs lane: fail on broken intra-repo links and on serve.py CLI flags
missing from the README flag reference.  Pure stdlib (CI runs it before
any heavy deps install).

Checks
------
1. Every relative markdown link target in README.md and docs/**.md
   resolves to a file or directory in the repo (anchors stripped;
   http(s)/mailto links skipped).
2. Every ``--flag`` registered by ``add_argument`` in
   src/repro/launch/serve.py appears verbatim in README.md — the README
   is the flag reference of record, so a new flag without docs fails CI.
3. Every ``choices=`` value of those flags appears in README.md too: an
   enum flag (``--restore {journal,snapshot}``, ``--shed-policy``,
   ``--kv-bits {8,4}``, ...) is only documented when its MODES are — a
   new mode without docs fails CI just like a new flag.  Both string and
   integer choices count (``--kv-bits`` is an int enum).
4. Every analyzer finding code defined in src/repro/analysis/ and
   scripts/repro_lint.py (string literals shaped ``family.rule``, e.g.
   ``drift.promote``) appears in docs/serving.md — the "Static analysis
   & compile budgets" section is the finding-code reference of record,
   so a new check without docs fails CI.

Run: python scripts/check_docs.py   (from anywhere; paths resolve
relative to the repo root, which is this script's parent directory).
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images is unnecessary: image targets must
# resolve too.  Inline code spans are stripped first so `a[i](x)` bits in
# code don't parse as links.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN = re.compile(r"`[^`]*`")


def md_files():
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in md_files():
        text = _CODE_SPAN.sub("", md.read_text())
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def serve_flags() -> list[tuple[str, list[str]]]:
    """(--flag, [choices]) pairs registered in serve.py, via the ast
    (no jax import); choices is empty for non-enum flags."""
    tree = ast.parse((REPO / "src/repro/launch/serve.py").read_text())
    flags = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            name = None
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    name = arg.value
            if name is None:
                continue
            choices = []
            for kw in node.keywords:
                if (kw.arg == "choices" and
                        isinstance(kw.value, (ast.List, ast.Tuple))):
                    # ints coerce to their decimal spelling — an int enum
                    # like ``--kv-bits`` documents "8" / "4" in the README
                    choices = [str(c.value) for c in kw.value.elts
                               if isinstance(c, ast.Constant)
                               and isinstance(c.value, (str, int))
                               and not isinstance(c.value, bool)]
            flags.append((name, choices))
    return flags


def check_flag_reference() -> list[str]:
    readme = (REPO / "README.md").read_text()
    errors = []
    for flag, choices in serve_flags():
        if flag not in readme:
            errors.append(f"README.md: serve.py flag {flag} missing from "
                          "the flag reference")
            continue
        for c in choices:
            # word-boundary match: a mode named "block" must appear as
            # the word itself, not buried inside "block-steps"
            if not re.search(rf"(?<![\w-])`?{re.escape(c)}`?(?![\w-])",
                             readme):
                errors.append(f"README.md: serve.py flag {flag} choice "
                              f"{c!r} missing from the flag reference")
    return errors


_FINDING_CODE = re.compile(
    r'"((?:drift|budget|pallas|donate|freeze|lint)\.[a-z0-9-]+)"')


def finding_codes() -> set[str]:
    """Every finding code a checker can emit, scraped from the string
    literals of the analyzer sources (stdlib-only: no import of jax-
    dependent analysis modules)."""
    sources = sorted((REPO / "src/repro/analysis").glob("*.py"))
    sources.append(REPO / "scripts/repro_lint.py")
    codes: set[str] = set()
    for src in sources:
        codes.update(_FINDING_CODE.findall(src.read_text()))
    return codes


def check_finding_code_reference() -> list[str]:
    doc = (REPO / "docs/serving.md").read_text()
    return [f"docs/serving.md: analyzer finding code `{code}` is "
            "undocumented (Static analysis & compile budgets section)"
            for code in sorted(finding_codes()) if code not in doc]


def main() -> int:
    errors = (check_links() + check_flag_reference()
              + check_finding_code_reference())
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        return 1
    n_flags = len(serve_flags())
    print(f"check_docs OK: {len(md_files())} markdown files, "
          f"{n_flags} serve.py flags documented, "
          f"{len(finding_codes())} analyzer finding codes documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
