#!/usr/bin/env python
"""Repo lint: tracer-hostile python and undocumented surface, by AST.

Pure stdlib (the report schema module is loaded by file path, so this
runs with no jax import).  Four rules, each encoding an invariant the
engine has already been bitten by or explicitly documents:

``lint.tracer-cast``
    ``float(x)`` / ``int(x)`` applied to a function parameter that the
    same function also treats as an array (passes to jnp/lax, calls
    ``.astype`` on, …).  Under jit that parameter is a tracer and the
    cast raises ``ConcretizationTypeError`` — but only on the traced
    path, so the bug ships dormant (optim/adam.py carried exactly this
    on its non-traced fallback branch).  Host-side casts of genuinely
    host values (lengths, config ints) don't trip this: the parameter
    must ALSO flow into array code.

``lint.host-in-scan``
    ``time.time()`` / ``time.perf_counter()`` / ``random.*`` /
    ``np.random.*`` inside a function passed to ``lax.scan`` /
    ``while_loop`` / ``fori_loop`` / ``cond``.  Scanned bodies trace
    once: a host clock or host RNG there is baked in as a constant —
    it "works" and silently never varies again.

``lint.jit-method``
    ``@jax.jit`` (or ``@functools.partial(jax.jit, ...)``) directly on a
    method.  Each bound method is a fresh callable, so the jit cache
    keys on the instance — every new object recompiles.  The engine's
    idiom is jitting closures built per instance (scheduler) or
    module-level functions.

``lint.undocumented-flag``
    ``add_argument`` without ``help=``.  The CLIs are the public
    surface; check_docs.py cross-references flags into README, and a
    flag with no help string is invisible to ``--help`` users.

Suppress a finding by appending ``# repro-lint: ok`` to the flagged
line (greppable, reviewable).  Exits 1 on findings, 0 clean; ``--out``
writes the shared schema-validated JSON report.
"""
from __future__ import annotations

import argparse
import ast
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "_analysis_report", ROOT / "src" / "repro" / "analysis" / "report.py")
_report = importlib.util.module_from_spec(_spec)
sys.modules[_spec.name] = _report  # dataclasses resolves types via sys.modules
_spec.loader.exec_module(_report)
Finding, make_report, write_report = (_report.Finding, _report.make_report,
                                      _report.write_report)

SUPPRESS = "repro-lint: ok"
_ARRAY_MODULES = {"jnp", "jax", "lax"}  # host numpy is never traced
_LOOP_PRIMS = {"scan", "while_loop", "fori_loop", "cond", "switch"}
_HOST_TIME = {"time", "perf_counter", "monotonic", "process_time"}


def _suppressed(lines: list[str], lineno: int) -> bool:
    return 0 < lineno <= len(lines) and SUPPRESS in lines[lineno - 1]


def _dotted(node) -> str:
    """'jax.lax.scan' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _finding(code, rel, node, msg):
    return Finding(analyzer="lint", code=code, location=f"{rel}:{node.lineno}",
                   message=msg)


# ---------------------------------------------------------------------------
# rule passes (one module at a time)
# ---------------------------------------------------------------------------

def _tracer_cast(tree, rel, lines) -> list:
    out = []
    for fn in (n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))):
        if fn.name.startswith("__") and fn.name.endswith("__"):
            continue  # dunders (constructors etc.) are host-side code
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                  + fn.args.kwonlyargs} - {"self", "cls"}
        if not params:
            continue
        arrayish: set = set()
        casts: list = []
        conversion_casts: set = set()  # casts fed straight INTO jax calls
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            # p.astype(...) / p.dtype-bearing method => p is an array here
            if (isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in params
                    and node.func.attr in ("astype", "reshape", "sum",
                                           "mean", "block_until_ready")):
                arrayish.add(node.func.value.id)
            # jnp.foo(..., p, ...) => p flows into array code
            root = _dotted(node.func).split(".")[0]
            if root in _ARRAY_MODULES:
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    for name in ast.walk(arg):
                        if (isinstance(name, ast.Name)
                                and name.id in params):
                            arrayish.add(name.id)
                    # int(p) passed directly INTO jax (fold_in(k, int(rid)))
                    # is an explicit host->device handoff, not a tracer
                    # readback — the cast runs before tracing sees it
                    for sub in ast.walk(arg):
                        if (isinstance(sub, ast.Call)
                                and isinstance(sub.func, ast.Name)
                                and sub.func.id in ("float", "int")):
                            conversion_casts.add(id(sub))
            # float(p) / int(p)
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int")
                    and len(node.args) == 1
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in params):
                casts.append((node, node.func.id, node.args[0].id))
        for node, cast, pname in casts:
            if (pname in arrayish and id(node) not in conversion_casts
                    and not _suppressed(lines, node.lineno)):
                out.append(_finding(
                    "lint.tracer-cast", rel, node,
                    f"{fn.name}: {cast}({pname}) on a parameter this "
                    "function also treats as an array — under jit the "
                    "parameter is a tracer and the cast raises; use "
                    f"jnp.asarray({pname}, ...) instead"))
    return out


def _host_in_scan(tree, rel, lines) -> list:
    out = []
    # names of local defs handed to lax control-flow primitives, plus
    # lambdas passed inline
    scanned_names: set = set()
    scanned_lambdas: list = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _dotted(node.func).split(".")[-1]
        if leaf not in _LOOP_PRIMS:
            continue
        for arg in node.args:
            if isinstance(arg, ast.Name):
                scanned_names.add(arg.id)
            elif isinstance(arg, ast.Lambda):
                scanned_lambdas.append(arg)

    def hits(body_node, where):
        for sub in ast.walk(body_node):
            if not isinstance(sub, ast.Call):
                continue
            dotted = _dotted(sub.func)
            parts = dotted.split(".")
            bad = (
                (parts[0] == "time" and parts[-1] in _HOST_TIME)
                or (parts[0] == "random" and len(parts) > 1)
                or (len(parts) >= 2 and parts[0] in ("np", "numpy")
                    and parts[1] == "random")
            )
            if bad and not _suppressed(lines, sub.lineno):
                out.append(_finding(
                    "lint.host-in-scan", rel, sub,
                    f"{where}: '{dotted}' inside a lax-scanned/looped "
                    "body — traced once, then frozen as a constant; "
                    "thread time/randomness in as scan inputs"))

    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in scanned_names):
            hits(node, node.name)
    for lam in scanned_lambdas:
        hits(lam, "<lambda>")
    return out


def _jit_method(tree, rel, lines) -> list:
    out = []
    for cls in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = meth.args.posonlyargs + meth.args.args
            if not args or args[0].arg not in ("self", "cls"):
                continue
            for dec in meth.decorator_list:
                is_jit = _dotted(dec).endswith("jit") or (
                    isinstance(dec, ast.Call)
                    and _dotted(dec.func).endswith("partial")
                    and dec.args
                    and _dotted(dec.args[0]).endswith("jit"))
                if is_jit and not _suppressed(lines, dec.lineno):
                    out.append(_finding(
                        "lint.jit-method", rel, dec,
                        f"{cls.name}.{meth.name}: @jit on a method keys "
                        "the compile cache on the bound instance — every "
                        "object recompiles; jit a per-instance closure "
                        "in __init__ or a module-level function"))
    return out


def _undocumented_flag(tree, rel, lines) -> list:
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        if any(kw.arg == "help" for kw in node.keywords):
            continue
        if _suppressed(lines, node.lineno):
            continue
        flag = (node.args[0].value
                if node.args and isinstance(node.args[0], ast.Constant)
                else "?")
        out.append(_finding(
            "lint.undocumented-flag", rel, node,
            f"add_argument({flag!r}) without help= — CLI flags are the "
            "public surface; document or suppress"))
    return out


_RULES = (_tracer_cast, _host_in_scan, _jit_method, _undocumented_flag)


def lint_paths(paths) -> list:
    findings = []
    for path in paths:
        path = Path(path)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            src = f.read_text()
            try:
                tree = ast.parse(src)
            except SyntaxError as e:
                findings.append(Finding(
                    analyzer="lint", code="lint.syntax",
                    location=f"{f}:{e.lineno or 0}",
                    message=f"unparseable python: {e.msg}"))
                continue
            lines = src.splitlines()
            try:
                rel = str(f.relative_to(ROOT))
            except ValueError:
                rel = str(f)
            for rule in _RULES:
                findings += rule(tree, rel, lines)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST lint for tracer-hostile python and undocumented "
                    "CLI surface.")
    ap.add_argument("paths", nargs="*",
                    default=["src", "tests", "scripts", "benchmarks",
                             "examples"],
                    help="files or directories to lint (default: the repo)")
    ap.add_argument("--out", default=None,
                    help="write the JSON findings report here")
    args = ap.parse_args(argv)

    paths = [ROOT / p if not Path(p).is_absolute() else Path(p)
             for p in args.paths]
    findings = lint_paths(paths)
    if args.out:
        write_report(args.out, make_report(
            findings, tool="repro_lint",
            entry_points=[str(p) for p in args.paths]))
    for f in findings:
        print(f"{f.location}: {f.code}: {f.message}")
    print(f"repro_lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
