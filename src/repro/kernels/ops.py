"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode
(Python emulation of the kernel body) — correctness-identical to the TPU
path, validated against the pure-jnp oracles in ref.py.  On a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (default when a TPU backend is detected).

``fake_quant`` carries the STE custom_vjp (paper eqs. 16-19): forward runs
the fused Pallas kernel, backward computes
  dx     = 1{|x| <= T_adj}                                  (round STE + clip)
  dalpha = d/dalpha [ q(x; alpha) ]  via the threshold scale
in plain jnp (the backward is bandwidth-trivial relative to the matmuls
around it).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import fake_quant as _fq
from repro.kernels import prefill_attention as _pa
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def quant_matmul(x, w_q, w_scale, act_scale, **kw):
    """Fused quantize -> int8 matmul -> dequant (serving hot path).

    x: (M, K) raw bf16/f32 activations; w_q: (K, N) int8; w_scale: (N,)
    combined dequant scale (already folds 1/act_scale); act_scale: scalar
    quantization scale levels/T_adj applied to x inside the kernel.
    act_scale is mandatory — quantizing raw activations with an implicit
    scale of 1 silently clips them to small ints.
    """
    return _qm.quant_matmul(x, w_q, w_scale, act_scale,
                            interpret=_interpret(), **kw)


quant_matmul_ref = _ref.quant_matmul_ref


def decode_attention(q, k_cache, v_cache, k_scale, v_scale, cur_pos, **kw):
    """Fused one-token flash-decode over the int8 KV cache.

    q: (B, KV, G, D); k/v_cache: (B, S, KV, D) int8 with per-head dequant
    scales (KV,) — the serving decode hot path.  A bf16 cache runs through
    the same kernel with scales of ones.
    """
    return _da.decode_attention_int8(q, k_cache, v_cache, k_scale, v_scale,
                                     cur_pos, interpret=_interpret(), **kw)


decode_attention_ref = _ref.decode_attention_ref


def decode_attention_view(q, view, k_scale, v_scale, cur_pos, **kw):
    """Fused decode over a cache's ``KernelView`` (repro.cache): a dense/
    ring view (``block_table is None``) routes through the identity-table
    entry point, a paged view streams its page pool through the same
    kernel body via the block table.  ``view.bits`` picks the dequant
    epilogue (int4 views unpack nibbles in-kernel)."""
    if view.block_table is None:
        return _da.decode_attention_int8(
            q, view.k, view.v, k_scale, v_scale, cur_pos,
            interpret=_interpret(), kv_bits=view.bits, **kw)
    return _da.decode_attention_tiles(
        q, view.k, view.v, view.block_table, k_scale, v_scale, cur_pos,
        interpret=_interpret(), kv_bits=view.bits, **kw)


def decode_attention_partials(q, k_cache, v_cache, k_scale, v_scale,
                              cur_pos, **kw):
    """Partial-softmax flash-decode over ONE shard's dense cache slice
    (sequence-parallel serving).  Same inputs as ``decode_attention`` but
    ``cur_pos`` counts the LOCAL visible slots and the return is the raw
    flash state ``(acc, m, l)`` — acc (B, KV, G, D) unnormalized (value-
    dequantized), m/l (B, KV, G) — for the cross-shard merge in
    ``repro.shard.partial_softmax.sp_partial_combine``."""
    return _da.decode_attention_partials(
        q, k_cache, v_cache, k_scale, v_scale, cur_pos,
        interpret=_interpret(), **kw)


def decode_attention_partials_view(q, view, k_scale, v_scale, cur_pos,
                                   **kw):
    """Partials variant of ``decode_attention_view``: same dense-vs-paged
    (and ``view.bits``) routing, raw ``(acc, m, l)`` flash state out."""
    if view.block_table is None:
        return _da.decode_attention_partials(
            q, view.k, view.v, k_scale, v_scale, cur_pos,
            interpret=_interpret(), kv_bits=view.bits, **kw)
    return _da.decode_attention_partials_tiles(
        q, view.k, view.v, view.block_table, k_scale, v_scale, cur_pos,
        interpret=_interpret(), kv_bits=view.bits, **kw)


def prefill_attention(q, k, v, k_scale, v_scale, q_start, kv_len, **kw):
    """Fused flash-prefill over an int8 (or unit-scale float) KV stream.

    q: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D) int8 with per-head dequant
    scales (KV,) — the serving prefill hot path.  ``q_start`` offsets
    query positions (chunked prefill); ``kv_len`` (B,) masks each
    request's valid KV slots (ragged prompt lengths).  Causal and
    sliding-window masks skip fully-dead tiles at block level.
    """
    return _pa.prefill_attention_int8(q, k, v, k_scale, v_scale, q_start,
                                      kv_len, interpret=_interpret(), **kw)


prefill_attention_ref = _ref.prefill_attention_ref


def prefill_attention_view(q, view, k_scale, v_scale, q_start, kv_len,
                           **kw):
    """Fused prefill over a cache's ``KernelView`` (repro.cache); same
    dense-vs-paged (and ``view.bits``) routing as
    ``decode_attention_view``."""
    if view.block_table is None:
        return _pa.prefill_attention_int8(
            q, view.k, view.v, k_scale, v_scale, q_start, kv_len,
            interpret=_interpret(), kv_bits=view.bits, **kw)
    return _pa.prefill_attention_tiles(
        q, view.k, view.v, view.block_table, k_scale, v_scale, q_start,
        kv_len, interpret=_interpret(), kv_bits=view.bits, **kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fake_quant(x, t_max, alpha, levels=127.0, alpha_min=0.5, alpha_max=1.0):
    """Fused per-channel fake-quant with STE backward.

    x: (M, N); t_max/alpha: (N,) per-out-channel (paper vector mode).
    """
    return _fq.fake_quant_fwd(
        x, t_max, alpha, levels=levels, qmin=-levels, qmax=levels,
        alpha_min=alpha_min, alpha_max=alpha_max, interpret=_interpret(),
    )


def _fq_fwd(x, t_max, alpha, levels, alpha_min, alpha_max):
    y = fake_quant(x, t_max, alpha, levels, alpha_min, alpha_max)
    return y, (x, t_max, alpha)


def _fq_bwd(levels, alpha_min, alpha_max, res, g):
    x, t_max, alpha = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    a = jnp.clip(alpha.astype(jnp.float32), alpha_min, alpha_max)
    t_adj = jnp.maximum(a * t_max.astype(jnp.float32), 1e-8)
    s = levels / t_adj  # (N,)
    inside = (jnp.abs(xf) <= t_adj[None, :]).astype(jnp.float32)
    # STE: straight-through inside the clip range (eqs. 17, 19)
    dx = (gf * inside).astype(x.dtype)
    # d y / d t_adj:
    #   inside:  y = round(x s)/s -> dy/dt = (round(xs) - xs)/ (s t) * ...
    #            == (y - x)/t_adj   (rounding residual shrinks/grows with T)
    #   outside: y = sign(x) * levels / s = sign(x) * t_adj -> dy/dt = sign(x)
    y = _ref.fake_quant_ref(x, t_max, alpha, levels=levels, qmin=-levels,
                            qmax=levels, alpha_min=alpha_min,
                            alpha_max=alpha_max).astype(jnp.float32)
    dy_dt = jnp.where(inside > 0, (y - xf) / t_adj[None, :], jnp.sign(xf))
    # alpha gradient only inside the clip(alpha) passthrough band (eq. 19)
    pass_band = ((alpha >= alpha_min) & (alpha <= alpha_max)).astype(jnp.float32)
    dalpha = jnp.sum(gf * dy_dt, axis=0) * t_max.astype(jnp.float32) * pass_band
    # t_max is calibration data, not trained — zero cotangent
    return dx, jnp.zeros_like(t_max), dalpha.astype(alpha.dtype)


fake_quant.defvjp(_fq_fwd, _fq_bwd)

fake_quant_ref = _ref.fake_quant_ref
