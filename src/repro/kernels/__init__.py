"""Pallas kernel layer: the fused int8/int4 serving hot paths.

``ops.py`` holds the jit'd public wrappers (interpret-mode selection per
backend), ``ref.py`` the pure-jnp oracles every kernel is pinned
against.  ``PALLAS_MODULES`` enumerates the modules that contain
``pl.pallas_call`` sites — the static contract checker
(repro.analysis.pallas_contracts) walks exactly this list, so a new
kernel module is added HERE to come under contract, and a module that
stops appearing here fails the checker's coverage guard rather than
silently dropping out of CI.
"""

# module basenames under repro.kernels with pallas_call sites (checked
# by repro.analysis.pallas_contracts.check_kernel_sources)
PALLAS_MODULES = (
    "decode_attention",
    "prefill_attention",
    "quant_matmul",
    "fake_quant",
)
