"""Pallas TPU kernel: fused fake-quantize (quantize-dequantize) — the QAT
student's elementwise hot op (§3.1.3).

One VMEM pass computes  clip(round(x * s), qmin, qmax) / s  with
s = levels / (clip(alpha) * t_max) per output channel (vector mode) or per
tensor (scalar mode).  Saves two HBM round-trips versus the unfused
mul/round/clip/div chain on big activation tensors.

The backward (STE, eqs. 16-19) is provided by ops.fake_quant's custom_vjp;
this file is forward-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, t_ref, o_ref, *, levels: float, qmin: float, qmax: float,
            alpha_min: float, alpha_max: float):
    # t_ref rows: [0] = t_max, [1] = alpha  (stacked so one (2, bn) block
    # streams both per-channel vectors)
    t_max = t_ref[0, :]
    alpha = jnp.clip(t_ref[1, :], alpha_min, alpha_max)
    t_adj = jnp.maximum(alpha * t_max, 1e-8)
    s = levels / t_adj  # (bn,)
    x = x_ref[...].astype(jnp.float32)
    xq = jnp.clip(jnp.round(x * s[None, :]), qmin, qmax)
    o_ref[...] = (xq / s[None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("levels", "qmin", "qmax", "alpha_min", "alpha_max",
                     "block_m", "block_n", "interpret"),
)
def fake_quant_fwd(
    x: jax.Array,       # (M, N)
    t_max: jax.Array,   # (N,) per-channel or broadcastable scalar->(N,)
    alpha: jax.Array,   # (N,)
    *,
    levels: float = 127.0,
    qmin: float = -127.0,
    qmax: float = 127.0,
    alpha_min: float = 0.5,
    alpha_max: float = 1.0,
    block_m: int = 512,
    block_n: int = 512,
    interpret: bool = False,
):
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    t_stack = jnp.stack([
        jnp.broadcast_to(t_max.astype(jnp.float32), (n,)),
        jnp.broadcast_to(alpha.astype(jnp.float32), (n,)),
    ])  # (2, N)
    kernel = functools.partial(
        _kernel, levels=levels, qmin=qmin, qmax=qmax,
        alpha_min=alpha_min, alpha_max=alpha_max,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((2, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, t_stack)
