"""Pallas TPU kernel: fused int8 serving matmul.

The paper's inference speedup comes from int8 execution; on TPU v5e the MXU
runs int8 at 2x bf16 throughput *and* int8-resident weights halve the HBM
stream.  This kernel implements the whole FAT serving contraction in one
pass over VMEM tiles:

    x_bf16 --quantize(static threshold)--> x_int8
    acc_int32 = x_int8 @ w_int8                        (MXU)
    out = acc * (w_scale * t_a / (levels_a * levels_w)) (VPU epilogue)

Grid is (M/bm, N/bn, K/bk) with a VMEM int32 accumulator tile; K is the
innermost ("arbitrary") dimension so each (i, j) output tile accumulates
across K-steps without re-materializing.  Tile defaults are MXU-aligned
(multiples of 128 in the lane dim; int8 native VMEM tiling is (32, 128)).

Weights arrive pre-quantized (int8) with per-output-channel dequant scales —
the paper's vector mode (§3.1.5).  Activation quantization uses the static
calibrated+trained threshold (§2: thresholds computed beforehand so nothing
is "calculated on the fly" at serving time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import unpack_int4
from repro.kernels.tpu_compat import tpu_compiler_params


def _kernel(x_ref, w_ref, sw_ref, sa_ref, o_ref, acc_ref, *, n_k: int,
            qmin: float, qmax: float, w_bits: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # fused activation quantization (VPU) — static threshold scale;
    # activations stay int8 regardless of the weight width
    s_a = sa_ref[0, 0]
    x = x_ref[...].astype(jnp.float32) * s_a
    x_q = jnp.clip(jnp.round(x), qmin, qmax).astype(jnp.int8)

    w = w_ref[...]
    if w_bits == 4:
        # int4 weights ride packed along K (two K rows per stored byte);
        # one VMEM unpack restores the (bk, bn) int8 tile the MXU eats
        w = unpack_int4(w, axis=0)

    # int8 x int8 -> int32 on the MXU (int4 weights are int8-resident
    # values in [-7, 7] after the unpack)
    acc_ref[...] += jax.lax.dot_general(
        x_q, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        # per-out-channel dequant: w_scale already folds 1/s_a at call site
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * sw_ref[...]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret", "w_bits"),
)
def quant_matmul(
    x: jax.Array,        # (M, K) float (bf16/f32) activations
    w_q: jax.Array,      # (K, N) int8 weights — (K/2, N) packed at w_bits=4
    w_scale: jax.Array,  # (N,) f32 combined dequant scale (already / s_a)
    act_scale: jax.Array,  # scalar f32: levels / T_adj (quantization scale)
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
    w_bits: int = 8,
):
    """Fused quantize -> int8 matmul -> dequant.

    K and N (weight dims) must tile evenly — they are config-sized and
    chosen MXU-aligned.  M is the token dim and ragged at decode (M = B*1);
    it is padded up to a sublane-aligned tile and the pad rows sliced off,
    so the same kernel serves prefill (M large) and decode (M = 1..8).

    ``w_bits == 4``: weights arrive nibble-packed along K (pack_int4 on
    axis 0 — half the resident weight bytes), quantized to ±7 with the
    per-channel ``w_scale`` carrying T/7; the kernel unpacks each VMEM
    tile before the MXU dot, so the N BlockSpec and the (N,) scale
    mapping are untouched.
    """
    m0, k = x.shape
    k2, n = w_q.shape
    assert k == k2 * (2 if w_bits == 4 else 1), (x.shape, w_q.shape, w_bits)
    # M tiling: sublane-align (f32 min tile is (8, 128)), then prefer an
    # exact-divisor tile (zero pad rows) but never shrink below a quarter
    # of block_m — a tiny bm turns the MXU matmul into a long sequential
    # grid.  When no acceptable divisor exists, pad to a block_m multiple
    # (waste < bm rows, amortized at the prefill Ms where this fires).
    m_aligned = -(-m0 // 8) * 8
    bm_cap = max(8, min(block_m, m_aligned) // 8 * 8)
    bm = next(
        (c for c in range(bm_cap, max(8, bm_cap // 4) - 1, -8)
         if m_aligned % c == 0),
        bm_cap,
    )
    m = -(-m_aligned // bm) * bm
    if m != m0:
        x = jnp.pad(x, [(0, m - m0), (0, 0)])
    bn, bk = min(block_n, n), min(block_k, k)
    assert n % bn == 0 and k % bk == 0, (
        f"weight dims (K={k}, N={n}) not tiled by (bk={bk}, bn={bn})"
    )
    # packed weights halve the K BlockSpec dim: tile kk covers logical
    # rows [kk*bk, (kk+1)*bk) either way, so the index map is unchanged
    bkw = bk // 2 if w_bits == 4 else bk
    assert bkw * (2 if w_bits == 4 else 1) == bk, (
        f"int4 weight K tile must be even, got bk={bk}")
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(_kernel, n_k=n_k, qmin=-127.0, qmax=127.0,
                               w_bits=w_bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkw, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem_scratch(bm, bn)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_q, w_scale.reshape(1, n).astype(jnp.float32),
      jnp.reshape(act_scale, (1, 1)).astype(jnp.float32))[:m0]


def _vmem_scratch(bm, bn):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.int32)
