"""Pallas TPU kernel: fused int8-KV flash-decode attention.

One decode step reads the whole KV cache once — at production shapes the
step is purely HBM-bandwidth-bound, which is why the cache is stored int8
(half the bytes of bf16).  This kernel keeps the int8 stream all the way
into VMEM and runs the one-token flash-decode online softmax in a single
pass:

    k_tile_int8 --DMA--> VMEM --dequant(per-head static scale)--> f32
    s   = (q * k_scale / sqrt(D)) @ k_tile^T          (MXU)
    m,l = running max / normalizer update             (VPU)
    acc = acc * exp(m_old - m_new) + softmax_tile @ v_tile
    out = acc * v_scale / l                           (epilogue)

Tiles and block tables
----------------------
The kernel core (``decode_attention_tiles``) reads KV through a **block
table**: K/V arrive as a page pool ``(pages, block_s, KV, D)`` and a
``(B, S/block_s)`` int32 table maps each (batch row, logical block) to a
pool page via a scalar-prefetch index map — the table rides in SMEM and
steers the DMA engine, costing nothing on the compute path.  The paged
cache (repro.cache.paged) passes its pool/table straight through; the
dense entry point ``decode_attention_int8`` reshapes its contiguous
cache into pool form (a free leading-axis split) and passes the identity
table — so dense and paged layouts share ONE kernel body and the table
is always data, never shape.

Grid layout
-----------
``(B, KV-heads, S/block_s)`` with the sequence dimension innermost and
declared "arbitrary" (B and head axes are "parallel"): sequential
execution along the KV axis is what lets the (G, D) accumulator tile live
in VMEM scratch across sequence steps — the partial-max/partial-sum
combine of flash-decode.  Per-head dequant scales fold into q (keys) and
the epilogue (values), so dequantization costs one scalar multiply per
tile element, on the VPU, overlapping the MXU contraction.

VMEM scratch expectations
-------------------------
Three scratch buffers persist across the innermost grid axis: the (G, D)
f32 output accumulator plus (G, 1) running max and normalizer.  They are
(re)initialized at ``si == 0`` and flushed to the output ref at
``si == n_s - 1`` — correctness relies on the innermost axis running
in-order on one core, which the "arbitrary" dimension semantics
guarantee.  Budget: one (1, block_s, 1, D) int8 K tile + V tile are
resident per step alongside the scratch; block_s is chosen so a whole
tile fits comfortably (default 128 x D; the paged layout uses its page
size).

Masking semantics
-----------------
``cur_pos`` is the number of valid cache slots per batch row — a scalar
(uniform batch, the single-stream serving path) or a (B,) vector (the
slot-based continuous-batching scheduler: each slot of the batch decodes
at its own position).  Positions are LOGICAL (block index * block_s +
offset) — the table only relocates storage.  Slots at ``k_pos >=
cur_pos[b]`` are masked BEFORE the running-max update and re-masked after
(an all-masked tile has s == m_new == NEG_INF and exp(0) == 1, which
would corrupt l).  A row with ``cur_pos[b] == 0`` (inactive scheduler
slot) masks every key and normalizes to exact zeros in the epilogue.

A bf16 cache runs through the same kernel with scales == 1.  The
pure-jnp oracle is kernels/ref.py::decode_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import unpack_int4
from repro.kernels.tpu_compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_step(q_ref, k_ref, v_ref, ks_ref, pos_ref, acc_ref, m_ref,
                l_ref, si, *, block_s: int, dim: int, kv_bits: int):
    """One online-softmax tile update (shared by the normalized kernel
    and the sequence-parallel partials kernel — same math up to, but not
    including, the epilogue)."""

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # fold the key dequant scale and 1/sqrt(D) into q: per-head scales are
    # uniform within the head, so (q*c) @ k_int8 == c * (q @ k)
    c = ks_ref[0, 0] * jax.lax.rsqrt(jnp.asarray(dim, jnp.float32))
    q = q_ref[0, 0].astype(jnp.float32) * c          # (G, D)
    k = k_ref[0, :, 0, :]                            # (bs, D) — D/2 packed
    if kv_bits == 4:
        # the ONE extra op of the int4 lane: nibbles -> int8 in VMEM,
        # before the f32 cast the int8 path already does.  Scales carry
        # T/7 instead of T/127, so the fold below is unchanged.
        k = unpack_int4(k, axis=-1)
    k = k.astype(jnp.float32)                        # (bs, D) dequant-free
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (G, bs)

    # mask the unwritten tail (cache slots >= this row's cur_pos); pos_ref
    # is blocked per batch row, so slot-ragged positions mask per slot.
    # k_pos is the LOGICAL position — the block table only moves storage
    k_pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = k_pos < pos_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    # re-mask: an all-masked tile has s == m_new == NEG_INF and exp(0) == 1
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)    # (G, bs)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, :, 0, :]                            # (bs, D)
    if kv_bits == 4:
        v = unpack_int4(v, axis=-1)
    v = v.astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new


def _kernel(tab_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, pos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, n_s: int, block_s: int, dim: int,
            kv_bits: int):
    # tab_ref is the scalar-prefetch block table: consumed by the K/V
    # index maps (page steering), never by the compute body
    del tab_ref
    si = pl.program_id(2)
    _flash_step(q_ref, k_ref, v_ref, ks_ref, pos_ref, acc_ref, m_ref,
                l_ref, si, block_s=block_s, dim=dim, kv_bits=kv_bits)

    @pl.when(si == n_s - 1)
    def _epilogue():
        # value dequant folds once into the epilogue (linear in v)
        o = acc_ref[...] * vs_ref[0, 0] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def _partials_kernel(tab_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, pos_ref,
                     oa_ref, om_ref, ol_ref, acc_ref, m_ref, l_ref, *,
                     n_s: int, block_s: int, dim: int, kv_bits: int):
    """Sequence-parallel epilogue: emit the raw flash state (unnormalized
    accumulator — value-dequantized, since v_scale is linear in v — plus
    running max and normalizer) instead of normalizing.  One shard of a
    sequence-split cache runs this over its LOCAL tiles; the cross-shard
    merge (repro.shard.partial_softmax.sp_partial_combine) produces the
    exact unsharded softmax from the gathered (m, l, acc) triples."""
    del tab_ref
    si = pl.program_id(2)
    _flash_step(q_ref, k_ref, v_ref, ks_ref, pos_ref, acc_ref, m_ref,
                l_ref, si, block_s=block_s, dim=dim, kv_bits=kv_bits)

    @pl.when(si == n_s - 1)
    def _epilogue():
        oa_ref[0, 0] = (acc_ref[...] * vs_ref[0, 0]).astype(oa_ref.dtype)
        om_ref[0, 0] = m_ref[...].astype(om_ref.dtype)
        ol_ref[0, 0] = l_ref[...].astype(ol_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "interpret", "kv_bits"))
def decode_attention_tiles(
    q: jax.Array,          # (B, KV, G, D) float — one query token, GQA view
    k_pool: jax.Array,     # (pages, block_s, KV, D) int8/float (D/2 packed
    v_pool: jax.Array,     # (pages, block_s, KV, D)   bytes at kv_bits=4)
    block_tab: jax.Array,  # (B, n_blocks) int32 page per (row, logical blk)
    k_scale: jax.Array,    # (KV,) f32 per-head dequant scale
    v_scale: jax.Array,    # (KV,) f32 per-head dequant scale
    cur_pos: jax.Array,    # int32 valid-slot count: scalar or per-slot (B,)
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
    kv_bits: int = 8,
):
    """Kernel core: fused one-token decode over block-table-mapped KV
    tiles.  The dense layout passes a free reshape of its cache plus the
    identity table (``decode_attention_int8``); the paged layout passes
    its pool/table directly — same compiled kernel either way.

    ``kv_bits == 4``: K/V tiles hold packed nibbles (D/2 bytes wide) and
    the kernel body unpacks them in VMEM right before the f32 cast.  The
    block table and index maps are UNCHANGED — they address blocks, not
    bytes; only the tile's last BlockSpec dim halves."""
    b, kvh, g, d = q.shape
    dp = k_pool.shape[-1]  # storage width (D, or D/2 packed)
    assert dp * (2 if kv_bits == 4 else 1) == d, (
        f"kv_bits={kv_bits}: pool head dim {dp} does not match q head "
        f"dim {d}")
    bs = k_pool.shape[1]
    n_s = block_tab.shape[1]

    kernel = functools.partial(_kernel, n_s=n_s, block_s=bs, dim=d,
                               kv_bits=kv_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, si, tab: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dp),
                         lambda bi, h, si, tab: (tab[bi, si], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dp),
                         lambda bi, h, si, tab: (tab[bi, si], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, h, si, tab: (bi, h, 0, 0)),
        scratch_shapes=_scratch(g, d),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), out_dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(
        block_tab.astype(jnp.int32),
        q,
        k_pool,
        v_pool,
        k_scale.reshape(kvh, 1).astype(jnp.float32),
        v_scale.reshape(kvh, 1).astype(jnp.float32),
        # per-batch-row valid-slot count (prefill's kv_len pattern): a
        # scalar broadcasts to all rows, a (B,) vector is slot-ragged
        jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1),
                         (b,)).reshape(b, 1),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_s", "out_dtype", "interpret", "kv_bits"))
def decode_attention_int8(
    q: jax.Array,        # (B, KV, G, D) float — one query token, GQA view
    k_cache: jax.Array,  # (B, S, KV, D) int8 (or float with scales == 1;
    v_cache: jax.Array,  # (B, S, KV, D)  D/2 packed bytes at kv_bits=4)
    k_scale: jax.Array,  # (KV,) f32 per-head dequant scale
    v_scale: jax.Array,  # (KV,) f32 per-head dequant scale
    cur_pos: jax.Array,  # int32 valid-slot count: scalar or per-slot (B,)
    *,
    block_s: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
    kv_bits: int = 8,
):
    """Dense entry point: contiguous (B, S, KV, D) caches degenerate to
    the identity block table over a leading-axis reshape of the same
    buffer — the kernel body is shared with the paged layout.

    ``cur_pos`` broadcasts to a per-batch-row (B,) valid-slot vector (the
    prefill kernel's per-request ``kv_len`` pattern): a scalar serves the
    uniform single-stream path, a vector serves slot-ragged continuous
    batching, where a 0 entry marks an inactive slot (output zeros).
    """
    b, kvh, g, d = q.shape
    d = k_cache.shape[-1]  # storage width (packed bytes at kv_bits == 4)
    s = k_cache.shape[1]
    # prefer a sublane-aligned tile that divides S exactly: a pad here
    # copies the WHOLE cache every decode step (it cannot be hoisted out
    # of a scanned decode loop), which would double the HBM traffic the
    # int8 cache exists to halve.  serve.py rounds the cache length to a
    # block_s multiple for the kernel path; the pad fallback below only
    # fires for odd ad-hoc lengths.
    bs = max(8, min(block_s, s) // 8 * 8)
    while bs > 8 and s % bs:
        bs -= 8
    s_pad = -(-s // bs) * bs
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    n_s = s_pad // bs

    # identity view: splitting the contiguous sequence axis into
    # (blocks, block_s) merges with batch into a page axis copy-free
    k_pool = k_cache.reshape(b * n_s, bs, kvh, d)
    v_pool = v_cache.reshape(b * n_s, bs, kvh, d)
    tab = jnp.arange(b * n_s, dtype=jnp.int32).reshape(b, n_s)
    return decode_attention_tiles(
        q, k_pool, v_pool, tab, k_scale, v_scale, cur_pos,
        out_dtype=out_dtype, interpret=interpret, kv_bits=kv_bits)


def _scratch(g, d):
    return [
        pltpu.VMEM((g, d), jnp.float32),  # output accumulator
        pltpu.VMEM((g, 1), jnp.float32),  # running max
        pltpu.VMEM((g, 1), jnp.float32),  # running normalizer
    ]


@functools.partial(jax.jit, static_argnames=("interpret", "kv_bits"))
def decode_attention_partials_tiles(
    q: jax.Array,          # (B, KV, G, D) float — one query token, GQA view
    k_pool: jax.Array,     # (pages, block_s, KV, D) int8/float
    v_pool: jax.Array,     # (pages, block_s, KV, D)
    block_tab: jax.Array,  # (B, n_blocks) int32
    k_scale: jax.Array,    # (KV,) f32
    v_scale: jax.Array,    # (KV,) f32
    cur_pos: jax.Array,    # int32 valid-slot count: scalar or per-slot (B,)
    *,
    interpret: bool = False,
    kv_bits: int = 8,
):
    """Partial-softmax variant of ``decode_attention_tiles`` for the
    sequence-parallel engine: same grid, same block specs, same online-
    softmax body, but the epilogue emits the raw flash state —
    (acc, m, l) with acc UNNORMALIZED (already v-dequantized) — so a
    shard holding a slice of the S axis can hand its partials to the
    cross-shard tree merge.  ``cur_pos`` here counts the valid slots IN
    THIS POOL (the caller clips the global count to its local slice);
    a shard with nothing visible returns (0, NEG_INF, 0) — the merge
    identity.  Returns ((B, KV, G, D) f32, (B, KV, G) f32, (B, KV, G)
    f32).  The single-shard invariant ``acc / max(l, eps) ==
    decode_attention_tiles(...)`` is pinned in tests/test_sharded.py."""
    b, kvh, g, d = q.shape
    dp = k_pool.shape[-1]
    assert dp * (2 if kv_bits == 4 else 1) == d
    bs = k_pool.shape[1]
    n_s = block_tab.shape[1]

    kernel = functools.partial(_partials_kernel, n_s=n_s, block_s=bs,
                               dim=d, kv_bits=kv_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, si, tab: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dp),
                         lambda bi, h, si, tab: (tab[bi, si], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dp),
                         lambda bi, h, si, tab: (tab[bi, si], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, si, tab: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, h, si, tab: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1),
                         lambda bi, h, si, tab: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1),
                         lambda bi, h, si, tab: (bi, h, 0, 0)),
        ],
        scratch_shapes=_scratch(g, d),
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(
        block_tab.astype(jnp.int32),
        q,
        k_pool,
        v_pool,
        k_scale.reshape(kvh, 1).astype(jnp.float32),
        v_scale.reshape(kvh, 1).astype(jnp.float32),
        jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1),
                         (b,)).reshape(b, 1),
    )
    return acc, m[..., 0], l[..., 0]


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret", "kv_bits"))
def decode_attention_partials(
    q: jax.Array,        # (B, KV, G, D)
    k_cache: jax.Array,  # (B, S_local, KV, D) — ONE shard's cache slice
    v_cache: jax.Array,
    k_scale: jax.Array,  # (KV,) f32
    v_scale: jax.Array,
    cur_pos: jax.Array,  # int32 LOCAL valid-slot count: scalar or (B,)
    *,
    block_s: int = 128,
    interpret: bool = False,
    kv_bits: int = 8,
):
    """Dense entry point for the partials kernel (identity block table
    over the shard-local cache slice — same degenerate-table trick as
    ``decode_attention_int8``)."""
    b, kvh, g, d = q.shape
    d = k_cache.shape[-1]
    s = k_cache.shape[1]
    bs = max(8, min(block_s, s) // 8 * 8)
    while bs > 8 and s % bs:
        bs -= 8
    s_pad = -(-s // bs) * bs
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    n_s = s_pad // bs
    k_pool = k_cache.reshape(b * n_s, bs, kvh, d)
    v_pool = v_cache.reshape(b * n_s, bs, kvh, d)
    tab = jnp.arange(b * n_s, dtype=jnp.int32).reshape(b, n_s)
    return decode_attention_partials_tiles(
        q, k_pool, v_pool, tab, k_scale, v_scale, cur_pos,
        interpret=interpret, kv_bits=kv_bits)
