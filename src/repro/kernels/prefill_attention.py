"""Pallas TPU kernel: fused int8-KV flash-prefill attention.

The prefill twin of kernels/decode_attention.py: where decode reads the
whole cache for ONE query token, prefill attends a whole prompt (or one
chunk of it) against the int8 KV stream — the dominant HBM cost of long
prompts.  The paper's frozen per-head thresholds (FAT §2, calibrated then
finalized) make the KV scales static at serve time, so the prompt's K/V
quantize once and this kernel attends directly over the int8 tiles the
cache stores — no bf16 re-materialization between "attend" and "append".

    k/v int8 tile --DMA--> VMEM --(scales fold into q / epilogue)--> f32
    s   = (q * k_scale / sqrt(D)) @ k_tile^T            (MXU)
    m,l = running max / normalizer update               (VPU)
    acc = acc * exp(m_old - m_new) + softmax_tile @ v_tile
    out = acc * v_scale / l                             (epilogue)

Tiles and block tables
----------------------
Like the decode kernel, the core (``prefill_attention_tiles``) reads KV
through a **block table**: tiles arrive as a page pool
``(pages, block_k, KV, D)`` and a ``(B, KV-chunks)`` int32 table maps
each (batch row, logical KV block) to a pool page via a scalar-prefetch
index map.  The paged cache passes its pool/table straight through (so a
chunked prefill can attend pages shared with other requests); the dense
entry point ``prefill_attention_int8`` reshapes its contiguous stream
and passes the identity table — one kernel body for every layout.

Grid layout
-----------
``(B, KV-heads, Q-chunks, KV-chunks)`` with the KV axis innermost and
declared "arbitrary" (the three outer axes are "parallel"): in-order
execution along KV is what lets the (block_q * G, D) accumulator tile
live in VMEM scratch across KV steps — classic flash-attention online
softmax.  GQA groups are flattened into the query-row axis so every
kernel tile is a plain 2D matmul operand.

VMEM scratch expectations
-------------------------
Three scratch buffers persist across the innermost (KV) axis: the
(block_q * G, D) f32 output accumulator plus (block_q * G, 1) running max
and normalizer.  They are (re)initialized at ``ki == 0`` and flushed at
``ki == n_k - 1``, so correctness relies on the KV axis running in-order
on one core (the "arbitrary" dimension contract).  Per step the resident
set adds one (block_k, D) int8 K tile and V tile; the default 256-row
blocks keep q-tile + scratch + K/V tiles within VMEM at head dims <= 256
(paged pools use their page size as block_k).

Masking semantics
-----------------
Positional and block-skipped, always in LOGICAL positions (block index *
block_k + offset — the table only relocates storage): causal and
sliding-window predicates are evaluated per TILE first and a fully-masked
tile skips its matmuls entirely via ``pl.when`` — a sliding-window layer
therefore costs O(S * window) compute, not O(S^2).  ``q_start`` (chunk
offset of query row 0 — a scalar, or a (B,) vector for the per-slot
speculative-verify pass) and ``kv_len`` (per-request valid KV count)
make the same executable serve chunked, ragged prefill: element masks
re-apply after the running-max update (an all-masked tile has
s == m_new == NEG_INF and exp(0) == 1), and padded/garbage rows end
with l == 0, normalizing to exact zeros like the decode kernel's
empty-cache case.  The decode kernel's per-slot ``cur_pos`` vector and
the slot scheduler's inactive slots (kv_len == 0) reuse this same
convention.

DMA skip (index-map clamp)
--------------------------
``q_start`` / ``kv_len`` ride the scalar-prefetch path next to the block
table, so the K/V index maps can see them: a KV block whose every (q, k)
pair is masked (beyond the causal frontier, past ``kv_len``, or left of
the sliding-window band) has its block index CLAMPED to the nearest live
block — Pallas elides the copy when a block's index repeats between grid
steps, so fully-dead tiles cost neither MXU time (``pl.when``, as
before) nor DMA bandwidth.  The clamp predicate is exactly the kernel
body's ``live`` predicate, so a clamped tile is never read.
``dma_skip=False`` keeps the unclamped maps (the parity oracle in
tests/test_prefill_fastpath.py).

A bf16/f32 K/V stream runs through the same kernel with scales == 1.
The pure-jnp oracle is kernels/ref.py::prefill_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import unpack_int4
from repro.kernels.tpu_compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(tab_ref, qs_ref, kl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            o_ref, acc_ref, m_ref, l_ref, *, n_k: int, block_q: int,
            block_k: int, groups: int, dim: int, causal: bool,
            window: int | None, kv_bits: int):
    # tab_ref: scalar-prefetch block table — consumed by the K/V index
    # maps only; positions below are logical.  qs_ref/kl_ref are the
    # per-request (B,) q_start / kv_len vectors, shared with the index
    # maps (the DMA-skip clamp) and read per batch row here.
    del tab_ref
    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qs_ref[bi]
    kv_len = kl_ref[bi]
    q_lo = q_start + qi * block_q      # absolute position of query row 0
    k_lo = ki * block_k                # absolute position of key col 0

    # block-level skip: a tile whose every (q, k) pair is masked never
    # touches the MXU — causal skips the upper-triangular half, a sliding
    # window additionally skips everything left of the band
    live = k_lo < kv_len
    if causal:
        live &= k_lo <= q_lo + (block_q - 1)
    if window is not None:
        live &= k_lo + (block_k - 1) >= q_lo - (window - 1)

    @pl.when(live)
    def _tile():
        # fold key dequant scale and 1/sqrt(D) into q: the scale is uniform
        # within a head, so (q*c) @ k_int8 == c * (q @ k)
        c = ks_ref[0, 0] * jax.lax.rsqrt(jnp.asarray(dim, jnp.float32))
        q = q_ref[0, 0].astype(jnp.float32) * c          # (block_q*G, D)
        k = k_ref[0, :, 0, :]                            # (block_k, D)
        if kv_bits == 4:
            # int4 lane: one nibble unpack in VMEM before the f32 cast
            # the int8 path already pays; scales carry T/7, so the
            # q-fold above is unchanged
            k = unpack_int4(k, axis=-1)
        k = k.astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (block_q*G, block_k)

        # element masks: GQA groups are flattened into rows, so row r is
        # query position q_lo + r // G
        rows = block_q * groups
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // groups
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        valid = k_pos < kv_len
        if causal:
            valid &= k_pos <= q_pos
        if window is not None:
            valid &= (q_pos - k_pos) < window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                              # (rows, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        # re-mask: an all-masked row has s == m_new == NEG_INF and exp(0) == 1
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :]                            # (block_k, D)
        if kv_bits == 4:
            v = unpack_int4(v, axis=-1)
        v = v.astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _epilogue():
        # value dequant folds once into the epilogue (linear in v); rows
        # with no visible key (padding / ragged tail) have l == 0 -> zeros
        o = acc_ref[...] * vs_ref[0, 0] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def _fit_block(s: int, target: int) -> int:
    """Largest sublane-aligned tile <= target (padding covers remainders)."""
    return max(8, min(target, -(-s // 8) * 8) // 8 * 8)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "out_dtype",
                     "interpret", "dma_skip", "kv_bits"))
def prefill_attention_tiles(
    q: jax.Array,          # (B, Sq, KV, G, D) float — prompt queries
    k_pool: jax.Array,     # (pages, block_k, KV, D) int8/float (D/2 packed
    v_pool: jax.Array,     # (pages, block_k, KV, D)   bytes at kv_bits=4)
    block_tab: jax.Array,  # (B, KV-chunks) int32 page per logical block
    k_scale: jax.Array,    # (KV,) f32 per-head dequant scale
    v_scale: jax.Array,    # (KV,) f32 per-head dequant scale
    q_start: jax.Array,    # scalar or (B,) int32: position of query row 0
    kv_len: jax.Array,     # (B,) int32: valid KV count per request
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
    dma_skip: bool = True,
    kv_bits: int = 8,
):
    """Kernel core: fused multi-query-row flash attention over
    block-table-mapped KV tiles.  Returns (B, Sq, KV, G, D).

    ``q_start`` may be per-request (B,): the speculative-verify pass runs
    each slot's draft window at its own offset through this one
    executable (a scalar broadcasts — chunked prefill's uniform offset).
    ``dma_skip=False`` disables the masked-tile index-map clamp (see
    module docstring), for parity testing only.  ``kv_bits == 4``: K/V
    tiles hold packed nibbles (D/2 bytes) unpacked in the kernel body;
    index maps (including the DMA-skip clamp) are unchanged — they
    address blocks, not bytes.
    """
    b, sq, kvh, g, d = q.shape
    dp = k_pool.shape[-1]  # storage width (D, or D/2 packed)
    assert dp * (2 if kv_bits == 4 else 1) == d, (
        f"kv_bits={kv_bits}: pool head dim {dp} does not match q head "
        f"dim {d}")
    bk = k_pool.shape[1]
    n_k = block_tab.shape[1]

    bq = _fit_block(sq, block_q)
    sq_p = -(-sq // bq) * bq
    # prefill runs once per prompt (or chunk), so unlike the decode kernel
    # a pad copy here is not on the per-token path — plain jnp.pad is fine
    if sq_p != sq:
        q = jnp.pad(q, [(0, 0), (0, sq_p - sq), (0, 0), (0, 0), (0, 0)])
    n_q = sq_p // bq

    # flatten GQA groups into the query-row axis: (B, KV, Sq*G, D) keeps
    # every kernel tile a 2D matmul operand
    q2 = jnp.transpose(q, (0, 2, 1, 3, 4)).reshape(b, kvh, sq_p * g, d)
    rows = bq * g

    kernel = functools.partial(
        _kernel, n_k=n_k, block_q=bq, block_k=bk, groups=g, dim=d,
        causal=causal, window=window, kv_bits=kv_bits)

    def kv_index(bi, h, qi, ki, tab, qs, kl):
        if dma_skip:
            # clamp a fully-masked block to the nearest LIVE block: its
            # page index then repeats a neighbouring grid step's, so the
            # copy is elided.  The live range below mirrors the kernel
            # body's ``live`` predicate exactly (see _kernel), so a
            # clamped tile's (wrong) contents are never read.
            q_lo = qs[bi] + qi * bq
            last = jnp.minimum(n_k - 1,
                               (jnp.maximum(kl[bi], 1) - 1) // bk)
            if causal:
                last = jnp.minimum(last, (q_lo + bq - 1) // bk)
            first = 0
            if window is not None:
                first = jnp.maximum(0, (q_lo - (window - 1)) // bk)
            ki = jnp.clip(ki, first, last)
        return (tab[bi, ki], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kvh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda bi, h, qi, ki, tab, qs, kl: (bi, h, qi, 0)),
            pl.BlockSpec((1, bk, 1, dp), kv_index),
            pl.BlockSpec((1, bk, 1, dp), kv_index),
            pl.BlockSpec((1, 1), lambda bi, h, qi, ki, tab, qs, kl: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, qi, ki, tab, qs, kl: (h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rows, d),
            lambda bi, h, qi, ki, tab, qs, kl: (bi, h, qi, 0)),
        scratch_shapes=_scratch(rows, d),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, sq_p * g, d), out_dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(
        block_tab.astype(jnp.int32),
        jnp.broadcast_to(jnp.asarray(q_start, jnp.int32).reshape(-1), (b,)),
        jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,)),
        q2,
        k_pool,
        v_pool,
        k_scale.reshape(kvh, 1).astype(jnp.float32),
        v_scale.reshape(kvh, 1).astype(jnp.float32),
    )
    out = out.reshape(b, kvh, sq_p, g, d).transpose(0, 2, 1, 3, 4)
    return out[:, :sq]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "out_dtype",
                     "interpret", "dma_skip", "kv_bits"))
def prefill_attention_int8(
    q: jax.Array,        # (B, Sq, KV, G, D) float — prompt queries, GQA view
    k: jax.Array,        # (B, Sk, KV, D) int8 (or float with scales == 1;
    v: jax.Array,        # (B, Sk, KV, D)  D/2 packed bytes at kv_bits=4)
    k_scale: jax.Array,  # (KV,) f32 per-head dequant scale
    v_scale: jax.Array,  # (KV,) f32 per-head dequant scale
    q_start: jax.Array,  # scalar or (B,) int32: position of query row 0
    kv_len: jax.Array,   # (B,) int32: valid KV count per request
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
    dma_skip: bool = True,
    kv_bits: int = 8,
):
    """Dense entry point: a contiguous (B, Sk, KV, D) KV stream
    degenerates to the identity block table over a free leading-axis
    reshape — same kernel body as the paged layout."""
    b = q.shape[0]
    sk = k.shape[1]
    kvh, d = k.shape[2], k.shape[3]

    bk = _fit_block(sk, block_k)
    sk_p = -(-sk // bk) * bk
    if sk_p != sk:
        pad = [(0, 0), (0, sk_p - sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_k = sk_p // bk
    k_pool = k.reshape(b * n_k, bk, kvh, d)
    v_pool = v.reshape(b * n_k, bk, kvh, d)
    tab = jnp.arange(b * n_k, dtype=jnp.int32).reshape(b, n_k)
    return prefill_attention_tiles(
        q, k_pool, v_pool, tab, k_scale, v_scale, q_start, kv_len,
        causal=causal, window=window, block_q=block_q, out_dtype=out_dtype,
        interpret=interpret, dma_skip=dma_skip, kv_bits=kv_bits)


def _scratch(rows, d):
    return [
        pltpu.VMEM((rows, d), jnp.float32),  # output accumulator
        pltpu.VMEM((rows, 1), jnp.float32),  # running max
        pltpu.VMEM((rows, 1), jnp.float32),  # running normalizer
    ]
