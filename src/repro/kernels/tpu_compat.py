"""Shared Pallas-TPU compatibility helpers for the kernel modules."""
from __future__ import annotations


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """CompilerParams across the jax API rename (TPUCompilerParams before)."""
    from jax.experimental.pallas import tpu as pltpu

    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # older API name
        return pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics)
