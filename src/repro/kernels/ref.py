"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import unpack_int4


def quant_matmul_ref(x, w_q, w_scale, act_scale, out_dtype=jnp.bfloat16,
                     w_bits=8):
    """Oracle for kernels.quant_matmul: quantize -> int8 matmul -> dequant.

    ``w_bits == 4``: w_q arrives nibble-packed along K ((K/2, N) bytes).
    """
    if w_bits == 4:
        w_q = unpack_int4(w_q, axis=0)
    x_q = jnp.clip(
        jnp.round(x.astype(jnp.float32) * act_scale), -127, 127
    ).astype(jnp.int8)
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return (acc.astype(jnp.float32) * w_scale[None, :]).astype(out_dtype)


def decode_attention_ref(q, k_cache, v_cache, k_scale, v_scale, cur_pos,
                         out_dtype=jnp.float32, kv_bits=8):
    """Oracle for kernels.decode_attention_int8: dequantize the cache,
    masked softmax over valid positions, GQA-grouped output.

    q: (B, KV, G, D); k/v_cache: (B, S, KV, D) int8 (or float) — at
    ``kv_bits == 4`` the caches are (B, S, KV, D/2) packed nibbles;
    k/v_scale: (KV,) dequant scales; cur_pos: valid cache length — a
    scalar (uniform batch) or a (B,) per-slot vector (continuous
    batching).  A row with cur_pos == 0 (empty cache / inactive slot)
    returns zeros, matching the kernel.
    """
    b = q.shape[0]
    d = q.shape[-1]
    if kv_bits == 4:
        k_cache = unpack_int4(k_cache, axis=-1, size=d)
        v_cache = unpack_int4(v_cache, axis=-1, size=d)
    kf = k_cache.astype(jnp.float32) * k_scale.reshape(1, 1, -1, 1)
    vf = v_cache.astype(jnp.float32) * v_scale.reshape(1, 1, -1, 1)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    pos = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(k_cache.shape[1])[None, :] < pos[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return (out * (pos > 0)[:, None, None, None]).astype(out_dtype)


def prefill_attention_ref(q, k, v, k_scale, v_scale, q_start, kv_len, *,
                          causal=True, window=None, out_dtype=jnp.float32,
                          kv_bits=8):
    """Oracle for kernels.prefill_attention_int8: dequantize the K/V
    stream, masked softmax per query row, GQA-grouped output.

    q: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D) int8 (or float) — at
    ``kv_bits == 4`` they are (B, Sk, KV, D/2) packed nibbles;
    k/v_scale: (KV,) dequant scales; q_start: absolute position of query
    row 0 (scalar); kv_len: (B,) valid KV count per request.  Query rows
    with no visible key return zeros, matching the kernel.
    """
    b, sq, kvh, g, d = q.shape
    if kv_bits == 4:
        k = unpack_int4(k, axis=-1, size=d)
        v = unpack_int4(v, axis=-1, size=d)
    sk = k.shape[1]
    kf = k.astype(jnp.float32) * k_scale.reshape(1, 1, -1, 1)
    vf = v.astype(jnp.float32) * v_scale.reshape(1, 1, -1, 1)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)
    q_pos = jnp.asarray(q_start) + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
    mask = (k_pos[None, :] < kv_len[:, None])[:, None, None, None, :]
    mask = jnp.broadcast_to(mask, s.shape)
    if causal:
        mask &= (q_pos[:, None] >= k_pos[None, :])[None, None, None]
    if window is not None:
        mask &= ((q_pos[:, None] - k_pos[None, :]) < window)[None, None, None]
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p / denom, vf)
    return out.astype(out_dtype)


def fake_quant_ref(x, t_max, alpha, *, levels=127.0, qmin=-127.0, qmax=127.0,
                   alpha_min=0.5, alpha_max=1.0):
    """Oracle for kernels.fake_quant_fwd (per-out-channel thresholds)."""
    a = jnp.clip(alpha.astype(jnp.float32), alpha_min, alpha_max)
    t_adj = jnp.maximum(a * t_max.astype(jnp.float32), 1e-8)
    s = levels / t_adj
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * s[None, :]), qmin, qmax)
    return (xq / s[None, :]).astype(x.dtype)
