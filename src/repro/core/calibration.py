"""Calibration observers (paper §2, §4.1.2).

The paper calibrates activation thresholds on ~100 unlabeled images before
fine-tuning: "A set of data is provided to the network input to find desired
thresholds (in the example above — the maximum absolute value) of each
layer."  We implement the max-abs observer (paper default) plus a percentile
observer (a standard robustification against exactly the outlier problem the
paper's Figure 1 illustrates); both are functional — `update` returns a new
observer state so calibration can run under jit/pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.quant import QuantSpec

ObserverKind = Literal["max_abs", "percentile", "min_max"]


def init_observer(spec: QuantSpec, channels: int | None = None,
                  lead_shape: tuple = ()) -> dict:
    """Fresh observer state.

    For per-channel (vector) mode pass the channel count.  ``lead_shape``
    prepends stacked-layer dims (scanned stacks slice observers per layer).
    State fields:
      t_max : running max |x|          (symmetric)
      t_min / t_hi : running min/max   (asymmetric)
      count : number of batches seen
    """
    shape = tuple(lead_shape) + (
        (channels,) if (spec.per_channel and channels) else ()
    )
    z = jnp.zeros(shape, jnp.float32)
    return {
        "t_max": z,
        "t_min": jnp.full(shape, jnp.inf, jnp.float32),
        "t_hi": jnp.full(shape, -jnp.inf, jnp.float32),
        "count": jnp.zeros(shape, jnp.int32),
    }


def _reduce_axes(x: jax.Array, spec: QuantSpec) -> tuple[int, ...]:
    if spec.per_channel:
        return tuple(i for i in range(x.ndim) if i != (spec.channel_axis % x.ndim))
    return tuple(range(x.ndim))


def update_observer(
    state: dict,
    x: jax.Array,
    spec: QuantSpec,
    kind: ObserverKind = "max_abs",
    percentile: float = 99.99,
) -> dict:
    """One calibration step: fold batch statistics into the observer."""
    axes = _reduce_axes(x, spec)
    xf = x.astype(jnp.float32)
    if kind == "percentile":
        # Robust threshold: high percentile of |x| instead of the raw max.
        # Tracks the *running mean* of per-batch percentiles, which converges
        # to a stable threshold even with heavy-tailed activations (Fig. 1).
        batch_t = jnp.percentile(jnp.abs(xf), percentile, axis=axes)
        c = state["count"].astype(jnp.float32)
        t_max = (state["t_max"] * c + batch_t) / (c + 1.0)
    else:
        batch_t = jnp.max(jnp.abs(xf), axis=axes)
        t_max = jnp.maximum(state["t_max"], batch_t)
    t_min = jnp.minimum(state["t_min"], jnp.min(xf, axis=axes))
    t_hi = jnp.maximum(state["t_hi"], jnp.max(xf, axis=axes))
    return {
        "t_max": t_max,
        "t_min": t_min,
        "t_hi": t_hi,
        "count": state["count"] + 1,
    }


def observer_thresholds(state: dict, spec: QuantSpec) -> dict:
    """Finalize calibration into threshold parameters (§3.1.3 init).

    Symmetric: T_max from the observer, trained scale alpha=1.
    Asymmetric: (T_l, T_r) from min/max, alpha_t=0, alpha_r=1 (§3.1.4).
    """
    shape = state["t_max"].shape
    ones = jnp.ones(shape, jnp.float32)
    t_min = jnp.where(jnp.isfinite(state["t_min"]), state["t_min"], 0.0)
    t_hi = jnp.where(jnp.isfinite(state["t_hi"]), state["t_hi"], 0.0)
    return {
        "t_max": jnp.maximum(state["t_max"], 1e-8),
        "t_l": t_min,
        "t_r": jnp.maximum(t_hi, t_min + 1e-8),
        "alpha": ones,
        "alpha_t": jnp.zeros(shape, jnp.float32),
        "alpha_r": ones,
    }
