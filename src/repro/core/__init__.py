"""FAT quantization core — the paper's contribution as a composable module.

Public API:
  QuantSpec / fake_quant_* / quantize_*_int8   (quant.py  — §2, §3.1)
  init_observer / update_observer / ...        (calibration.py — §2)
  QuantPolicy / QuantCtx / init_qparams / ...  (api.py    — integration)
  fold_batchnorm / fold_model_norms            (folding.py — §3.1.2)
  dws_relu6_rescale / pair_rescale / ...       (equalization.py — §3.3)
  rmse_distill_loss / chunked_rmse_distill     (distill.py — §3.2)
"""
from repro.core.quant import (
    QuantSpec,
    ste_round,
    fake_quant_symmetric,
    fake_quant_asymmetric,
    quantize_weights_int8,
    quantize_acts_int8,
    quantize_bias_int32,
    apply_pointwise_scale,
    max_abs_threshold,
    adjusted_threshold,
)
from repro.core.calibration import init_observer, update_observer, observer_thresholds
from repro.core.api import (
    QuantPolicy,
    QuantCtx,
    make_ctx,
    init_qparams,
    finalize_calibration,
    trainable_mask,
    convert_to_int8,
)
from repro.core.folding import fold_batchnorm, fold_model_norms
from repro.core.equalization import dws_relu6_rescale, pair_rescale, equalize_model
from repro.core.distill import (
    rmse_distill_loss,
    chunked_rmse_distill,
    chunked_ce_loss,
)
