"""Unlabeled distillation loss (paper §3.2, eqs. 24-25).

The paper's total loss L = alpha*H(y, z_T) + beta*H(y, z_A) + gamma*H(z_T, z_A)
with alpha = beta = 0 — i.e. *only* the teacher/student term survives, and
it is the RMSE between pre-softmax outputs:

    H(z_T, z_A) = sqrt( sum_i ||z_i^T - z_i^A||^2 / N )         (eq. 25)

with N the batch size.  No labels are used anywhere, which is what lets FAT
train on ~10% of unlabeled data in hours.

For LM backbones the "output before softmax" is the logits tensor
(B, S, V); materializing it replicated is infeasible at vocab 256k, so
``chunked_rmse_distill`` folds the lm_head matmuls and the squared-error
reduction into one scan over sequence chunks — logits only ever exist for
one chunk, sharded over the model axis.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def rmse_distill_loss(z_teacher: jax.Array, z_student: jax.Array) -> jax.Array:
    """Eq. 25 verbatim: sqrt(sum of squared logit error / batch size).

    Batch size N = product of all leading (non-logit) dims.
    """
    zt = z_teacher.astype(jnp.float32)
    za = z_student.astype(jnp.float32)
    n = 1
    for d in zt.shape[:-1]:
        n *= d
    sq = jnp.sum((zt - za) ** 2)
    return jnp.sqrt(sq / jnp.maximum(n, 1))


def chunked_sq_err(
    h_teacher: jax.Array,   # (B, S, d) final hidden, teacher
    h_student: jax.Array,   # (B, S, d) final hidden, student
    readout: Callable[[jax.Array], jax.Array],  # (B, c, d) -> (B, c, V)
    readout_student: Callable | None = None,    # student's (quantized) head
    *,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Sum of squared logit error, computed chunk-by-chunk over sequence.

    Returns (sum_sq, count_examples) so the caller applies eq. 25's
    sqrt(. / N).  The scan keeps peak logits memory at (B, chunk, V_shard).
    ``readout_student`` lets the student use its fake-quantized lm_head
    while the teacher reads out in full precision.
    """
    b, s, _ = h_teacher.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    ro_s = readout_student or readout

    # checkpoint: without it the scan's backward saves every chunk's
    # (B, chunk, V) logits — at vocab 256k that re-materializes the full
    # logits tensor the chunking exists to avoid
    @jax.checkpoint
    def body(acc, idx):
        sl = jax.lax.dynamic_slice_in_dim(h_teacher, idx * chunk, chunk, axis=1)
        zt = readout(sl).astype(jnp.float32)
        sl_s = jax.lax.dynamic_slice_in_dim(h_student, idx * chunk, chunk, axis=1)
        za = ro_s(sl_s).astype(jnp.float32)
        return acc + jnp.sum((zt - za) ** 2), None

    acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n_chunks))
    return acc, jnp.asarray(b * s, jnp.float32)


def chunked_rmse_distill(h_teacher, h_student, readout, readout_student=None,
                         *, chunk: int = 256):
    """Eq. 25 over sequence-chunked logits (N = batch*seq positions)."""
    sq, n = chunked_sq_err(h_teacher, h_student, readout, readout_student,
                           chunk=chunk)
    return jnp.sqrt(sq / n)


def chunked_ce_loss(
    h: jax.Array,            # (B, S, d) final hidden
    labels: jax.Array,       # (B, S) int32
    readout: Callable,       # (B, c, d) -> (B, c, V)
    *,
    chunk: int = 256,
) -> jax.Array:
    """Standard next-token CE, sequence-chunked (pretrain mode)."""
    b, s, _ = h.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0

    @jax.checkpoint
    def body(acc, idx):
        hs = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = readout(hs).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n_chunks))
    return acc / (b * s)
