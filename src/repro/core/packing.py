"""int4 nibble packing: two signed 4-bit values per int8 byte.

The int4 KV cache (and the int4 weight path of ``kernels/quant_matmul``)
stores quantized values as packed nibbles so every buffer shrinks to half
the int8 bytes — the whole point of dropping to 4 bits is halving the
HBM stream, so the *storage* layout must actually be 4 bits wide.

Layout: along the packed axis, element ``2i`` lives in the LOW nibble and
element ``2i + 1`` in the HIGH nibble of byte ``i``.  Values must lie in
the signed int4 range [-8, 7] (the symmetric quantizer only emits
[-7, 7]).  An odd-length axis is padded with one zero nibble; callers
that pack odd lengths must pass the original ``size`` to ``unpack_int4``
to slice the pad back off (cache head dims are always even, so the
serving path never pads).

Sign handling is the classic two's-complement trick, kept in int32 where
Pallas/TPU integer ops are native:

    lo = ((b & 15) ^ 8) - 8      # low nibble, sign-extended
    hi = b >> 4                  # int32 arithmetic shift sign-extends

Both helpers are pure jnp and trace inside Pallas kernel bodies — the
attention kernels call ``unpack_int4`` on their VMEM tiles directly, so
there is exactly one unpack implementation in the repo.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_int4(x, axis: int = -1):
    """Pack signed int values in [-8, 7] into nibbles along ``axis``.

    Returns an int8 array whose ``axis`` length is ``ceil(n / 2)``.
    """
    x = jnp.asarray(x)
    ax = axis % x.ndim
    x = jnp.moveaxis(x, ax, -1)
    if x.shape[-1] % 2:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, 1)])
    xi = x.astype(jnp.int32)
    even = xi[..., 0::2] & 15
    odd = xi[..., 1::2] & 15
    return jnp.moveaxis((even | (odd << 4)).astype(jnp.int8), -1, ax)


def unpack_int4(p, axis: int = -1, size: int | None = None):
    """Unpack nibbles along ``axis`` back to int8 values in [-8, 7].

    ``size`` slices the axis back to an odd pre-pack length; by default
    the unpacked length is ``2 * packed_length``.
    """
    p = jnp.asarray(p)
    ax = axis % p.ndim
    p = jnp.moveaxis(p, ax, -1)
    pi = p.astype(jnp.int32)
    lo = ((pi & 15) ^ 8) - 8
    hi = pi >> 4  # arithmetic shift: the high nibble carries the byte sign
    out = jnp.stack([lo, hi], axis=-1).reshape(
        p.shape[:-1] + (2 * p.shape[-1],))
    if size is not None:
        out = out[..., :size]
    return jnp.moveaxis(out.astype(jnp.int8), -1, ax)
