"""Normalization folding (paper §3.1.2, eqs. 10-11).

The paper folds batch-norm into the preceding conv before quantization:

    W_fold = gamma * W / sqrt(sigma^2 + eps)                    (eq. 10)
    b_fold = beta - gamma * mu / sqrt(sigma^2 + eps)            (eq. 11)

For pre-norm transformer blocks the analogous transform folds the norm's
diagonal scale *forward* into every projection that consumes the normed
activations:  y = Norm(x) * gamma;  q = y @ W  ==  Norm(x) @ (diag(gamma) W).
After folding, the norm's scale is the identity and quantization sees a
single fused weight — exactly the simplification the paper wants
("it simplifies discretization and speeds up the neural network inference").

LayerNorm additionally has a bias beta, which folds into the projection
bias: b' = b + beta @ W (mirrors eq. 11's additive term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fold_batchnorm(w, gamma, beta, mu, sigma2, eps: float = 1e-5):
    """Eqs. 10-11 verbatim, for the DWS/conv benchmark models.

    w: (..., C_out) conv weights with output channels last.
    Returns (w_fold, b_fold).
    """
    inv = gamma / jnp.sqrt(sigma2 + eps)
    w_fold = w * inv  # broadcast over output channels (last axis)
    b_fold = beta - mu * inv
    return w_fold, b_fold


def fold_norm_into_projections(norm_scale, proj_weights: list, norm_bias=None,
                               proj_biases: list | None = None):
    """Fold a pre-norm gamma (and beta) into the consuming projections.

    norm_scale: (d,). proj_weights: list of (d, out) matrices that all
    consume the same normed activation (q/k/v, or gate/up).

    Returns (new_scale, new_weights, new_biases): new_scale is all-ones.
    """
    g = norm_scale.astype(jnp.float32)
    new_ws = [ (w.astype(jnp.float32) * g[:, None]).astype(w.dtype) for w in proj_weights ]
    new_bs = None
    if norm_bias is not None:
        b_extra = [
            (norm_bias.astype(jnp.float32) @ w.astype(jnp.float32)) for w in proj_weights
        ]
        if proj_biases is None:
            new_bs = [e.astype(w.dtype) for e, w in zip(b_extra, proj_weights)]
        else:
            new_bs = [
                ((b.astype(jnp.float32) if b is not None else 0.0) + e).astype(w.dtype)
                for b, e, w in zip(proj_biases, b_extra, proj_weights)
            ]
    return jnp.ones_like(norm_scale), new_ws, new_bs


def fold_model_norms(model, params: dict) -> dict:
    """Walk a transformer param tree and fold every pre-norm scale into its
    consuming projections.  Uses the model's declared fold plan
    (``model.fold_plan()`` -> list of (norm_path, [proj_paths])) so each
    architecture states exactly which algebraic folds are valid.
    """
    plan = getattr(model, "fold_plan", lambda: [])()
    flat = _flatten_ref(params)
    for norm_path, proj_paths in plan:
        scale_key = norm_path + "/scale"
        bias_key = norm_path + "/bias"
        if scale_key not in flat:
            continue
        gamma = flat[scale_key][0][flat[scale_key][1]]
        beta = None
        if bias_key in flat:
            beta = flat[bias_key][0][flat[bias_key][1]]
        ws, parents = [], []
        for pp in proj_paths:
            wk = pp + "/w"
            if wk not in flat:
                ws = None
                break
            parent, leaf = flat[wk]
            ws.append(parent[leaf])
            parents.append((parent, leaf))
        if ws is None:
            continue
        new_scale, new_ws, new_bs = fold_norm_into_projections(gamma, ws, beta)
        sp, sl = flat[scale_key]
        sp[sl] = new_scale
        if beta is not None:
            bp, bl = flat[bias_key]
            bp[bl] = jnp.zeros_like(beta)
        for (parent, leaf), nw in zip(parents, new_ws):
            parent[leaf] = nw
        if new_bs is not None:
            for (parent, leaf), nb in zip(parents, new_bs):
                parent["b"] = parent.get("b", 0) + nb if "b" in parent else nb
    return params


def _flatten_ref(params: dict, prefix: str = "") -> dict:
    """path -> (parent_dict, leaf_key) so we can rewrite in place."""
    out = {}
    for k, v in params.items():
        kk = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten_ref(v, kk))
        else:
            out[kk] = (params, k)
    return out
