"""FAT quantization context — the integration point between the paper's
technique (repro.core.quant) and the model substrate (repro.models).

Modes
-----
  none       full-precision forward (the distillation *teacher*, §3.2)
  calibrate  full-precision forward that also feeds activation observers
             (paper §2 "calibration procedure"; 100 unlabeled samples)
  fake       fake-quantized forward with trained threshold scales — the
             distillation *student* (§3.1.3-3.1.5); differentiable via STE
  int8       real integer serving path: int8 weights resident in memory,
             int8 activations with static calibrated thresholds, int32
             accumulation (§2, eq. 20), dequant fused into the epilogue

State layout
------------
``qparams``   flat dict  path -> {"act": {...}, "w": {...}} of threshold
              states.  Trainable leaves are exactly the alpha scales
              (alpha / alpha_t / alpha_r) and optional pointwise scales —
              everything else (t_max, t_l, t_r, observers) is frozen
              calibration data.  This is what makes FAT *fast*: the
              optimizer state is a few scalars per layer.
``params``    the model pytree; in int8 mode quantized Dense leaves hold
              {"w_q": int8, "w_scale": f32[C]} instead of {"w": bf16}.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import calibration as calib
from repro.core import quant as Q

Mode = str  # 'none' | 'calibrate' | 'fake' | 'int8'


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which FAT variant to run (the paper's experiment grid, Tables 1-2)."""

    bits: int = 8
    act_symmetric: bool = True          # Table 1/2: symmetric vs asymmetric
    weight_per_channel: bool = True     # §3.1.5: vector vs scalar mode
    act_per_channel: bool = False       # activations stay per-tensor (paper)
    pointwise_scales: bool = False      # §4.2 trainable [0.75, 1.25] scales
    observer: str = "max_abs"           # 'max_abs' (paper) | 'percentile'
    percentile: float = 99.99
    skip_patterns: tuple[str, ...] = () # layer paths excluded (e.g. routers)
    use_pallas: bool = False            # Pallas kernels on real TPU hot path
    kv_int8: bool = False               # quantized KV cache (per-head static T)
    kv_bits: int = 8                    # KV cache width: 8, or 4 (packed nibbles)

    @functools.cached_property
    def _skip_res(self) -> tuple[re.Pattern, ...]:
        # compiled once per policy instance (cached_property writes the
        # instance __dict__ directly, which frozen= does not block);
        # skips() runs per layer per call, so re-running re.compile via
        # re.search's internal cache lookup was measurable overhead
        return tuple(re.compile(p) for p in self.skip_patterns)

    def skips(self, path: str) -> bool:
        return any(p.search(path) for p in self._skip_res)

    def weight_spec(self, channel_axis: int = -1) -> Q.QuantSpec:
        return Q.QuantSpec(
            bits=self.bits,
            symmetric=True,  # weights are symmetric in the paper (eq. 1-4)
            per_channel=self.weight_per_channel,
            channel_axis=channel_axis,
        )

    def act_spec(self, unsigned: bool = False) -> Q.QuantSpec:
        return Q.QuantSpec(
            bits=self.bits,
            symmetric=self.act_symmetric,
            unsigned=unsigned,
            per_channel=self.act_per_channel,
        )

    def kv_spec(self) -> Q.QuantSpec:
        """K/V cache entries (B, S, KV, D): symmetric int8/int4 with one
        static threshold per KV head (channel_axis=-2).  Per-head rather
        than per-tensor because K magnitudes vary strongly across heads
        (rope frequencies), and per-head scales stay O(KV) resident
        floats.  ``kv_bits`` picks the integer width (levels = 127 or
        7); the cache stores int4 as packed nibbles."""
        return Q.QuantSpec(
            bits=self.kv_bits,
            symmetric=True,
            per_channel=True,
            channel_axis=-2,
        )


@dataclasses.dataclass
class QuantCtx:
    """Threaded through every forward; mutable only during tracing.

    ``updates`` collects observer states during a 'calibrate' trace; the
    step function merges them back into qparams functionally, so
    calibration jits/pjits cleanly.
    """

    mode: Mode
    policy: QuantPolicy
    qparams: dict
    updates: dict = dataclasses.field(default_factory=dict)

    def enabled(self, layer) -> bool:
        return (
            self.mode != "none"
            and getattr(layer, "quantize", False)
            and not self.policy.skips(layer.path)
        )


def make_ctx(mode: Mode, policy: QuantPolicy, qparams: dict | None = None) -> QuantCtx:
    return QuantCtx(mode=mode, policy=policy, qparams=qparams or {})


# ---------------------------------------------------------------------------
# qparams construction
# ---------------------------------------------------------------------------


def init_qparams(model, params: dict, policy: QuantPolicy) -> dict:
    """Build threshold state for every quantizable layer.

    Weight thresholds come straight from the weights (T_w = max|W|, eq. 2);
    activation thresholds start as empty observers to be filled by
    calibration.  Weight alpha starts at 1.0 (threshold == T_max, §3.1.3).
    """
    from repro.models.module import ExpertDense

    qparams: dict = {}
    for layer, lp in _quant_layers_with_params(model, params, policy):
        w = lp["w"]
        wspec = policy.weight_spec()
        # scanned stacks store weights with a leading (L,) axis; thresholds
        # get the same leading axis so lax.scan slices them per layer
        base_ndim = 3 if isinstance(layer, ExpertDense) else 2
        lead = w.shape[: w.ndim - base_ndim]
        if wspec.per_channel:
            # per output channel: (out,) / (E, out), + leading stack dims
            t_w = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
        else:
            axes = tuple(range(w.ndim - base_ndim, w.ndim))
            t_w = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axes)
        entry = {
            "w": {
                "t_max": t_w,
                "alpha": jnp.ones_like(t_w),
            },
            "act": calib.init_observer(policy.act_spec(), lead_shape=lead),
        }
        if policy.pointwise_scales:
            entry["w"]["pointwise"] = jnp.ones(w.shape, jnp.float32)
        qparams[layer.path] = entry
    if policy.kv_int8:
        for attn, lp in _kv_attention_with_params(model, params):
            # scanned stacks carry (L,) on every weight; observers get the
            # same leading axis so lax.scan slices them per layer
            lead = lp["wk"]["w"].shape[:-2]
            spec = policy.kv_spec()
            qparams[kv_path(attn.path)] = {
                "k": calib.init_observer(spec, channels=attn.n_kv,
                                         lead_shape=lead),
                "v": calib.init_observer(spec, channels=attn.n_kv,
                                         lead_shape=lead),
            }
    return qparams


def kv_path(attn_path: str) -> str:
    """qparams key holding the KV-cache thresholds of one attention layer."""
    return f"{attn_path}/kv"


def is_kv_path(path: str) -> bool:
    """True for KV-cache threshold entries (structure {'k':…, 'v':…})."""
    return path.endswith("/kv")


def _kv_attention_with_params(model, params):
    """(decode-caching self-attention layer, its params subtree) pairs.

    Cross-attention caches encoder memory (computed once per request, not
    the decode bandwidth bottleneck) and bidirectional encoder layers
    never decode — neither owns a KV cache, so neither gets threshold
    state (observers on them would burn calibration compute for nothing).
    """
    from repro.models.attention import Attention  # avoid cycle

    for module, sub in model.walk_with_params(params):
        if isinstance(module, Attention) and not module.cross and module.causal:
            yield module, sub


def _quant_layers_with_params(model, params, policy: QuantPolicy | None = None):
    """(quantizable leaf layer, its params subtree) pairs, skip-filtered.

    Walks the module tree in parallel with the param pytree so the stable
    layer *path* (quantization-state key) pairs with the structural param
    location — the two need not share naming.
    """
    from repro.models.module import Dense, ExpertDense  # avoid cycle

    for module, sub in model.walk_with_params(params):
        if isinstance(module, (Dense, ExpertDense)) and module.quantize:
            if policy is not None and policy.skips(module.path):
                continue
            yield module, sub


def finalize_calibration(qparams: dict, policy: QuantPolicy, *,
                         train_thresholds: bool = False) -> dict:
    """Convert observer stats into threshold params (paper §3.1.3 init).

    KV-cache entries normally freeze to bare per-head thresholds — unlike
    activation thresholds they carry no trainable alpha: the cache is
    written and read with the same scale, so the FAT fine-tuning
    objective has no gradient signal through it (§2: everything static at
    serving time).  With ``train_thresholds=True`` each KV entry instead
    gains a trainable log2-domain threshold (``log2_t``, TQT-style —
    initialized at the §2 max-abs value), the fake-mode forward
    fake-quantizes K/V through it so the distillation loss reaches it,
    and ``freeze_thresholds`` collapses it back to a bare ``t_max`` for
    serving once fine-tuning is done.
    """
    out = {}
    for path, entry in qparams.items():
        if is_kv_path(path):  # KV observer entry: {"k": obs, "v": obs}
            # where(), not maximum(): a NaN-poisoned observer (e.g. a
            # non-finite calibration batch) must still floor — maximum
            # propagates the NaN straight into every cache scale
            kv = {
                kk: {"t_max": jnp.where(obs["t_max"] > 1e-8,
                                        obs["t_max"], 1e-8)}
                for kk, obs in entry.items()
            }
            if train_thresholds:
                for st in kv.values():
                    st["log2_t"] = jnp.log2(st["t_max"]).astype(jnp.float32)
            out[path] = kv
            continue
        e = dict(entry)
        e["act"] = calib.observer_thresholds(entry["act"], policy.act_spec())
        out[path] = e
    return out


def freeze_thresholds(qparams: dict) -> dict:
    """Collapse trained KV thresholds back to the frozen serving form.

    Every KV entry carrying a trained ``log2_t`` becomes a bare
    ``{"t_max": 2**log2_t}`` (floored like finalize_calibration), which
    is exactly what the serving path (`Attention._kv_scales`) reads —
    after this the engine cannot tell trained thresholds from §2 ones.
    """
    out = {}
    for path, entry in qparams.items():
        if is_kv_path(path) and any("log2_t" in st for st in entry.values()):
            out[path] = {
                kk: {"t_max": jnp.where(jnp.exp2(st["log2_t"]) > 1e-8,
                                        jnp.exp2(st["log2_t"]), 1e-8)}
                for kk, st in entry.items()
            }
        else:
            out[path] = entry
    return out


def trainable_mask(qparams: dict) -> dict:
    """Pytree of bools: True only on the trained FAT parameters —
    threshold scale factors, trained log2 thresholds (TQT mode), and
    pointwise scales if enabled."""
    trainable_keys = {"alpha", "alpha_t", "alpha_r", "pointwise", "log2_t"}

    def mask_entry(d):
        return {
            k: (mask_entry(v) if isinstance(v, dict) else k in trainable_keys)
            for k, v in d.items()
        }

    return {p: mask_entry(e) for p, e in qparams.items()}


def _flatten(params: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in params.items():
        kk = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            flat.update(_flatten(v, kk))
        else:
            flat[kk] = v
    return flat


# ---------------------------------------------------------------------------
# Forward implementations (called by Dense / ExpertDense)
# ---------------------------------------------------------------------------


def _fq_act(x, astate, spec: Q.QuantSpec):
    if spec.symmetric:
        return Q.fake_quant_symmetric_fused(
            x, astate["t_max"], astate["alpha"], spec
        )
    return Q.fake_quant_asymmetric(
        x, astate["t_l"], astate["t_r"], astate["alpha_t"],
        astate["alpha_r"], spec,
    )


def _weight_threshold_shape(w: jax.Array) -> tuple[int, ...]:
    """Broadcast shape of per-out-channel thresholds against w.

    Dense w: (in, out) -> (1, out).  ExpertDense w: (E, in, out) ->
    (E, 1, out) — per-(expert, filter) thresholds, the vector mode of
    §3.1.5 generalized to batched expert weights.  Scanned stacks prepend
    (L,) to either form; the rule is uniform: every axis except the
    contraction (-2) keeps its own threshold.
    """
    return tuple(w.shape[:-2]) + (1, w.shape[-1])


def _fq_weight(w, wstate, spec: Q.QuantSpec):
    if "pointwise" in wstate:
        w = Q.apply_pointwise_scale(w, wstate["pointwise"].astype(w.dtype))
    if spec.per_channel:
        shape = _weight_threshold_shape(w)
        t = wstate["t_max"].reshape(shape)
        alpha = wstate["alpha"].reshape(shape)
        t_adj = jnp.maximum(Q.adjusted_threshold(t, alpha, spec), 1e-8)
        s = (spec.levels / t_adj).astype(jnp.float32)
        wq = Q.clip_grad_passthrough(
            Q.ste_round(w.astype(jnp.float32) * s), spec.qmin, spec.qmax
        )
        return (wq / s).astype(w.dtype)
    return Q.fake_quant_symmetric(
        w.astype(jnp.float32), wstate["t_max"], wstate["alpha"], spec
    ).astype(w.dtype)


def dense_forward(layer, params: dict, x: jax.Array, ctx: Optional[QuantCtx]):
    """All four modes for a Dense layer."""
    b = params.get("b")
    if _tp_reduce_axis(layer) is not None and (
            ctx is None or ctx.mode != "int8" or not ctx.enabled(layer)):
        # the float paths have no integer accumulator to reduce exactly —
        # a silent skip here would return per-shard partial products
        raise ValueError(
            f"{layer.path}: tensor-parallel serving requires mode='int8' "
            "with this layer quantized (the row epilogue reduces int32 "
            "accumulators; see repro.shard)")
    if ctx is None or not ctx.enabled(layer):
        y = x @ params["w"]
    elif ctx.mode == "calibrate":
        ctx.updates[layer.path] = calib.update_observer(
            ctx.qparams[layer.path]["act"],
            x,
            ctx.policy.act_spec(layer.act_unsigned),
            kind=ctx.policy.observer,
            percentile=ctx.policy.percentile,
        )
        y = x @ params["w"]
    elif ctx.mode == "fake":
        qs = ctx.qparams[layer.path]
        xq = _fq_act(x, qs["act"], ctx.policy.act_spec(layer.act_unsigned)).astype(
            x.dtype
        )
        wq = _fq_weight(params["w"], qs["w"], ctx.policy.weight_spec())
        y = xq @ wq
    elif ctx.mode == "int8":
        y = _int8_matmul(
            x,
            params["w_q"],
            params["w_scale"],
            ctx.qparams[layer.path]["act"],
            ctx.policy.act_spec(layer.act_unsigned),
            use_pallas=ctx.policy.use_pallas,
            reduce_axis=_tp_reduce_axis(layer),
        )
        b = params.get("b_q")
        if b is not None:
            # int32 bias folded at the dequantized output scale (eq. 20)
            b = b.astype(jnp.float32) * params["b_scale"]
            y = y + b.astype(y.dtype)
            b = None
    else:
        raise ValueError(f"unknown quant mode {ctx.mode}")
    if b is not None:
        y = y + b
    return y


def expert_dense_forward(layer, params: dict, x: jax.Array, ctx: Optional[QuantCtx]):
    """x: (..., E, C, in) @ w: (E, in, out) -> (..., E, C, out)."""
    if ctx is None or not ctx.enabled(layer):
        return jnp.einsum("...ecd,edf->...ecf", x, params["w"])
    if ctx.mode == "calibrate":
        ctx.updates[layer.path] = calib.update_observer(
            ctx.qparams[layer.path]["act"],
            x,
            ctx.policy.act_spec(),
            kind=ctx.policy.observer,
            percentile=ctx.policy.percentile,
        )
        return jnp.einsum("...ecd,edf->...ecf", x, params["w"])
    if ctx.mode == "fake":
        qs = ctx.qparams[layer.path]
        xq = _fq_act(x, qs["act"], ctx.policy.act_spec()).astype(x.dtype)
        wq = _fq_weight(params["w"], qs["w"], ctx.policy.weight_spec())
        return jnp.einsum("...ecd,edf->...ecf", xq, wq)
    if ctx.mode == "int8":
        astate = ctx.qparams[layer.path]["act"]
        spec = ctx.policy.act_spec()
        t_adj = jnp.maximum(
            Q.adjusted_threshold(astate["t_max"], astate["alpha"], spec), 1e-8
        )
        s_x = spec.levels / t_adj
        x_int = jnp.clip(jnp.round(x * s_x), spec.qmin, spec.qmax).astype(jnp.int8)
        # (..., E, C, in) @ (E, in, out) with int32 accumulation
        acc = jnp.einsum(
            "...ecd,edf->...ecf", x_int, params["w_q"],
            preferred_element_type=jnp.int32,
        )
        scale = (params["w_scale"] / s_x).astype(jnp.float32)  # (E, out)
        return (acc.astype(jnp.float32) * scale[:, None, :]).astype(x.dtype)
    raise ValueError(ctx.mode)


def _tp_reduce_axis(layer):
    """Mesh axis a row-parallel layer must psum over, or None.

    Only consults the trace-time shard context (repro.shard.context) —
    the unsharded engine never pays an import or a branch.  Row-parallel
    roles are identified by the layer's logical input axis: 'heads'
    (attention wo) and 'mlp' (ffn down/fc2) are the projections whose
    contraction dimension is split across tensor-parallel shards.
    """
    try:
        from repro.shard.context import tp_shard_info
    except ImportError:
        return None
    info = tp_shard_info()
    if info is None:
        return None
    axes = getattr(layer, "logical_axes", None)
    if axes and axes[0] in ("heads", "mlp"):
        return info.axis
    return None


def _int8_matmul(x, w_q, w_scale, astate, aspec, *, use_pallas=False,
                 reduce_axis=None):
    """int8 x int8 -> int32 -> dequant.  Static activation threshold.

    The XLA path (dot_general with int32 accumulation) maps onto the MXU's
    native int8 pipeline on TPU; the Pallas kernel (kernels/quant_matmul)
    additionally fuses the per-channel dequant epilogue and is selected on
    real hardware via policy.use_pallas.

    ``reduce_axis`` (tensor-parallel row epilogue) psums the int32
    accumulators over that mesh axis BEFORE the single dequant: integer
    addition is exact, so the sharded product is bit-identical to the
    unsharded one and the wire carries int32, never f32
    (dist/collectives.py::compressed_psum's integer fast path).  The
    fused Pallas path cannot host a mid-kernel collective, so a reduced
    matmul always takes the XLA path.
    """
    t_adj = jnp.maximum(
        Q.adjusted_threshold(astate["t_max"], astate["alpha"], aspec), 1e-8
    )
    s_x = aspec.levels / t_adj
    if use_pallas and reduce_axis is None and jnp.ndim(s_x) == 0:
        # raw activations + act_scale: the kernel's fused VPU quantize does
        # the round/clip in VMEM (quantizing here first would round twice
        # and stream an extra tensor through HBM)
        from repro.kernels import ops as kops

        lead = x.shape[:-1]
        y = kops.quant_matmul(
            x.reshape(-1, x.shape[-1]),
            w_q,
            (w_scale / s_x).astype(jnp.float32),
            s_x.astype(jnp.float32),
        )
        return y.reshape(*lead, -1).astype(x.dtype)
    x_int = jnp.clip(jnp.round(x * s_x), aspec.qmin, aspec.qmax).astype(jnp.int8)
    acc = jax.lax.dot_general(
        x_int,
        w_q,
        (((x_int.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if reduce_axis is not None:
        from repro.dist.collectives import compressed_psum

        acc = compressed_psum(acc, reduce_axis, mean=False)
    scale = (w_scale / s_x).astype(jnp.float32)
    return (acc.astype(jnp.float32) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# int8 model conversion (serving path)
# ---------------------------------------------------------------------------


def convert_to_int8(model, params: dict, qparams: dict, policy: QuantPolicy) -> dict:
    """Replace every quantized Dense/ExpertDense 'w' with int8 + scales.

    Produces the *serving* parameter pytree: quantized weights live in
    memory as int8 (half/quarter the HBM bytes — the inference speedup the
    paper targets), biases as int32 at the combined scale (eq. 20).
    """
    out = jax.tree.map(lambda x: x, params)  # structural copy
    for layer, lp in _quant_layers_with_params(model, out, policy):
        if layer.path not in qparams:
            continue
        wstate = qparams[layer.path]["w"]
        w = lp.pop("w").astype(jnp.float32)
        if "pointwise" in wstate:
            w = Q.apply_pointwise_scale(w, wstate["pointwise"])
        spec = policy.weight_spec()
        t = wstate["t_max"]
        alpha = wstate["alpha"]
        if spec.per_channel:
            shape = _weight_threshold_shape(w)
            t = t.reshape(shape)
            alpha = alpha.reshape(shape)
        t_adj = jnp.maximum(Q.adjusted_threshold(t, alpha, spec), 1e-8)
        s = spec.levels / t_adj
        lp["w_q"] = jnp.clip(jnp.round(w * s), spec.qmin, spec.qmax).astype(jnp.int8)
        w_scale = 1.0 / s
        if spec.per_channel:
            w_scale = jnp.squeeze(w_scale, axis=-2)
        lp["w_scale"] = w_scale.astype(jnp.float32)
        if "b" in lp:
            astate = qparams[layer.path]["act"]
            aspec = policy.act_spec(layer.act_unsigned)
            t_a = jnp.maximum(
                Q.adjusted_threshold(astate["t_max"], astate["alpha"], aspec),
                1e-8,
            )
            act_scale = t_a / aspec.levels
            # stacked layers: (L,) act scale broadcasts against (L, C)
            # per-channel weight scales
            if act_scale.ndim < lp["w_scale"].ndim:
                act_scale = act_scale.reshape(
                    act_scale.shape
                    + (1,) * (lp["w_scale"].ndim - act_scale.ndim))
            b = lp.pop("b")
            lp["b_q"] = Q.quantize_bias_int32(
                b.astype(jnp.float32), act_scale, lp["w_scale"]
            )
            lp["b_scale"] = (act_scale * lp["w_scale"]).astype(jnp.float32)
    return out
