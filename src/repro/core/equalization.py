"""Cross-layer equalization — the paper's §3.3 DWS-rescaling, generalized.

Paper's core identity: if a per-channel positive scale S is applied to the
output channels of layer k and 1/S to the matching input rows of layer k+1,
the composite function is unchanged *provided the op in between commutes
with positive diagonal scaling* (eq. 26-27 proves this for ReLU6 on channels
that never saturate).  Choosing S so per-channel thresholds equalize makes
scalar (per-tensor) quantization as good as vector (per-channel) — the
paper's fix for MobileNet-v2's scalar-mode collapse (1.6% -> 67% top-1).

This module implements:
  * the paper's exact DWS -> ReLU6 -> Conv algorithm (steps 1-6 of §3.3.1),
    including the "locked channel" rule for outputs near the 6.0 saturation;
  * the transformer analogs:
      - SwiGLU: up-projection output channels scale through the elementwise
        product (the silu(gate) path is untouched => always commutes);
      - attention v -> o: value head-channels scale through the
        attention-weighted sum (linear in v => always commutes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class EqualizationResult:
    scales: jax.Array          # per-channel S_W applied
    locked: jax.Array          # bool mask of locked channels
    t_before: jax.Array        # per-channel thresholds before
    t_after: jax.Array         # after rescaling


def _per_channel_t(w: jax.Array, axis: int) -> jax.Array:
    axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    return jnp.max(jnp.abs(w), axis=axes)


def dws_relu6_rescale(
    w_dws: jax.Array,        # (..., C) depthwise weights, channels last
    b_dws: jax.Array | None, # (C,) bias
    w_conv: jax.Array,       # (C, F) following 1x1 conv / projection
    act_max: jax.Array,      # (C,) calibrated max of DWS output pre-ReLU6
    *,
    relu_cap: float = 6.0,
    lock_limit: float = 5.9,
) -> tuple[jax.Array, jax.Array | None, jax.Array, EqualizationResult]:
    """The paper's §3.3.1 algorithm, steps 1-6.

    1. T_c = max|w_dws[..., c]| per filter.
    2. act_max from calibration (max of each output channel, pre-ReLU6).
    3. lock channels with act_max >= lock_limit (5.9 in the paper).
    4. T0 = mean of locked channels' thresholds (fallback: mean of all).
    5. S_c = T0 / T_c for non-locked channels (1 for locked).
    6. cap S_c so act_max * S_c <= relu_cap.

    Returns (w_dws', b_dws', w_conv', result) with w_conv rows divided by S.
    """
    t_w = _per_channel_t(w_dws, -1)
    locked = act_max >= lock_limit
    any_locked = jnp.any(locked)
    t0_locked = jnp.sum(jnp.where(locked, t_w, 0.0)) / jnp.maximum(
        jnp.sum(locked.astype(jnp.float32)), 1.0
    )
    t0 = jnp.where(any_locked, t0_locked, jnp.mean(t_w))
    s = t0 / jnp.maximum(t_w, _EPS)
    # step 6: never push an output past the ReLU6 saturation knee
    s_cap = relu_cap / jnp.maximum(act_max, _EPS)
    s = jnp.minimum(s, s_cap)
    # never scale a locked channel
    s = jnp.where(locked, 1.0, s)
    s = jnp.maximum(s, _EPS)

    w_dws2 = (w_dws.astype(jnp.float32) * s).astype(w_dws.dtype)
    b_dws2 = None if b_dws is None else (b_dws.astype(jnp.float32) * s).astype(
        b_dws.dtype
    )
    w_conv2 = (w_conv.astype(jnp.float32) / s[:, None]).astype(w_conv.dtype)
    res = EqualizationResult(
        scales=s,
        locked=locked,
        t_before=t_w,
        t_after=_per_channel_t(w_dws2, -1),
    )
    return w_dws2, b_dws2, w_conv2, res


def pair_rescale(
    w_up: jax.Array,   # (d, h): producer, channels on last axis
    w_down: jax.Array, # (h, d): consumer, channels on first axis
    *,
    target: str = "mean",
) -> tuple[jax.Array, jax.Array, EqualizationResult]:
    """Equalize per-channel thresholds across a *linear* producer/consumer
    pair (SwiGLU up->down through the gate product; attention v->o).

    No activation cap applies (silu/attention are unbounded on the scaled
    path and the scaling commutes exactly), so this is the paper's step 4-5
    with no locking — the analog of its 'all channels below 5.9' case.

    target='mean'  -> paper's T0 = mean threshold.
    target='joint' -> sqrt(T_up / T_down) geometric balance (equalizes the
    product pair, Nagel-style; kept as a beyond-paper option).
    """
    t_up = _per_channel_t(w_up, -1)
    if target == "joint":
        t_down = _per_channel_t(w_down, 0)
        s = jnp.sqrt(t_down / jnp.maximum(t_up, _EPS))
    else:
        t0 = jnp.mean(t_up)
        s = t0 / jnp.maximum(t_up, _EPS)
    s = jnp.maximum(s, _EPS)
    w_up2 = (w_up.astype(jnp.float32) * s).astype(w_up.dtype)
    w_down2 = (w_down.astype(jnp.float32) / s[:, None]).astype(w_down.dtype)
    res = EqualizationResult(
        scales=s,
        locked=jnp.zeros_like(s, dtype=bool),
        t_before=t_up,
        t_after=_per_channel_t(w_up2, -1),
    )
    return w_up2, w_down2, res


def equalize_model(model, params: dict) -> tuple[dict, dict]:
    """Apply the architecture's declared equalization plan.

    ``model.equalization_plan()`` yields (up_path, down_path) Dense pairs
    whose in-between op commutes with positive channel scaling.  Pairs in
    nonlinear/stateful positions (e.g. through an SSM recursion) must NOT
    be declared — the paper's own restriction ("any non-linear operations
    on the scaled data ... are not allowed").
    """
    from repro.core.folding import _flatten_ref

    plan = getattr(model, "equalization_plan", lambda: [])()
    flat = _flatten_ref(params)
    report = {}
    for up_path, down_path in plan:
        uk, dk = up_path + "/w", down_path + "/w"
        if uk not in flat or dk not in flat:
            continue
        up_parent, up_leaf = flat[uk]
        dn_parent, dn_leaf = flat[dk]
        w_up, w_down = up_parent[up_leaf], dn_parent[dn_leaf]
        if w_up.ndim == 3:  # expert weights: vmap the rescale over experts
            ws = []
            sds = []
            for e in range(w_up.shape[0]):
                wu, wd, res = pair_rescale(w_up[e], w_down[e])
                ws.append(wu)
                sds.append(wd)
            up_parent[up_leaf] = jnp.stack(ws)
            dn_parent[dn_leaf] = jnp.stack(sds)
            report[up_path] = res
        else:
            wu, wd, res = pair_rescale(w_up, w_down)
            up_parent[up_leaf] = wu
            dn_parent[dn_leaf] = wd
            report[up_path] = res
    return params, report
