"""FAT uniform quantization primitives (paper §2, §3.1).

Implements the paper's quantization scheme:

  * symmetric thresholds with a trained scale factor
        T_adj = clip(alpha, a_min, a_max) * T_max               (eq. 12-13)
        S     = levels / T_adj                                  (eq. 14)
        x_q   = clip(round(x * S), qmin, qmax)                  (eq. 15, 4, 8-9)
  * asymmetric thresholds parametrised as (left limit, width)
        R     = T_r - T_l                                       (eq. 21)
        T_adj = T_l + clip(alpha_T, aT_min, aT_max) * R         (eq. 22)
        R_adj = clip(alpha_R, aR_min, aR_max) * R               (eq. 23)
  * STE derivatives for round (eq. 16-17) and clip (eq. 18-19).

A note on eq. (1): the paper writes ``S_w = (2^n - 1) / T_w`` while clipping
signed values to ``±(2^{n-1}-1)``; taken literally this saturates everything
above ``T/2``.  The released reference code (and eqs. 5-9 for the unsigned
case) resolve the ambiguity as: *signed* tensors use ``(2^{n-1}-1)/T`` with
clip ``±(2^{n-1}-1)`` and *unsigned* tensors use ``(2^n-1)/T`` with clip
``[0, 2^n-1]``.  We implement that resolution.

Everything here is shape-polymorphic and works per-tensor (the paper's
"scalar" mode) or per-channel (the paper's "vector" mode, §3.1.5) by passing
threshold arrays that broadcast against ``x``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-8


# ---------------------------------------------------------------------------
# Quantization specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one quantization point.

    Attributes:
      bits: bit width (the paper targets 8).
      symmetric: symmetric (§3.1.3) vs asymmetric (§3.1.4) thresholds.
      unsigned: unsigned integer range (activations after ReLU-family);
        only meaningful for symmetric quantization — the asymmetric scheme
        is affine and always maps onto the unsigned range like Ref. [12].
      per_channel: the paper's "vector" mode (§3.1.5) — one threshold per
        channel along ``channel_axis``.
      channel_axis: axis of ``x`` holding the channels (output filters for
        weights).
      alpha_min/alpha_max: clip range of the trained threshold scale
        (paper: 0.5 / 1.0).
      alpha_t_min/alpha_t_max: clip range for the asymmetric left-limit
        shift (paper: -0.2 / 0.4 signed, 0 / 0.4 unsigned).
      alpha_r_min/alpha_r_max: clip range for the asymmetric width scale
        (paper: 0.5 / 1.0).
    """

    bits: int = 8
    symmetric: bool = True
    unsigned: bool = False
    per_channel: bool = False
    channel_axis: int = -1
    alpha_min: float = 0.5
    alpha_max: float = 1.0
    alpha_t_min: float = -0.2
    alpha_t_max: float = 0.4
    alpha_r_min: float = 0.5
    alpha_r_max: float = 1.0

    # -- derived integer ranges ------------------------------------------
    @property
    def levels(self) -> float:
        """Positive scale numerator (see module docstring on eq. 1)."""
        if self.symmetric and not self.unsigned:
            return float(2 ** (self.bits - 1) - 1)  # 127 for int8
        return float(2**self.bits - 1)  # 255 for uint8 / affine

    @property
    def qmin(self) -> float:
        if self.symmetric and not self.unsigned:
            return -float(2 ** (self.bits - 1) - 1)  # -127 (eq. 4)
        return 0.0

    @property
    def qmax(self) -> float:
        if self.symmetric and not self.unsigned:
            return float(2 ** (self.bits - 1) - 1)  # 127
        return float(2**self.bits - 1)  # 255

    def signed_alpha_t_range(self) -> tuple[float, float]:
        """§3.1.4: the left-limit shift range depends on signedness."""
        if self.unsigned:
            return (0.0, self.alpha_t_max)
        return (self.alpha_t_min, self.alpha_t_max)


# ---------------------------------------------------------------------------
# STE primitives (paper eqs. 16-19)
# ---------------------------------------------------------------------------


def ste_round(x: jax.Array) -> jax.Array:
    """Round-to-nearest with a straight-through gradient (eq. 16-17)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_floor(x: jax.Array) -> jax.Array:
    """Floor with a straight-through gradient (used by integer repack)."""
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def clip_grad_passthrough(x: jax.Array, lo, hi) -> jax.Array:
    """clip with the paper's eq. 18-19 gradient (1 inside, 0 outside).

    ``jnp.clip``'s VJP already matches eq. 19, so this is an alias kept for
    symmetry with the paper's notation.
    """
    return jnp.clip(x, lo, hi)


# ---------------------------------------------------------------------------
# Threshold computation
# ---------------------------------------------------------------------------


def max_abs_threshold(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """T = max|x| (eq. 2/6) — per tensor, or per channel in vector mode."""
    if spec.per_channel:
        axes = tuple(
            i for i in range(x.ndim) if i != (spec.channel_axis % x.ndim)
        )
        return jnp.max(jnp.abs(x), axis=axes)
    return jnp.max(jnp.abs(x))


def min_max_threshold(x: jax.Array, spec: QuantSpec) -> tuple[jax.Array, jax.Array]:
    """(T_l, T_r) for asymmetric quantization (§3.1.4)."""
    if spec.per_channel:
        axes = tuple(
            i for i in range(x.ndim) if i != (spec.channel_axis % x.ndim)
        )
        return jnp.min(x, axis=axes), jnp.max(x, axis=axes)
    return jnp.min(x), jnp.max(x)


def _bcast(t: jax.Array, x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Broadcast per-channel threshold against x along channel_axis."""
    if not spec.per_channel or t.ndim == 0:
        return t
    shape = [1] * x.ndim
    shape[spec.channel_axis % x.ndim] = t.shape[0]
    return t.reshape(shape)


# ---------------------------------------------------------------------------
# Fake quantization (quantize-dequantize) with trained thresholds
# ---------------------------------------------------------------------------


def adjusted_threshold(t_max: jax.Array, alpha: jax.Array, spec: QuantSpec) -> jax.Array:
    """T_adj = clip(alpha, a_min, a_max) * T_max  (eq. 12-13)."""
    a = clip_grad_passthrough(alpha, spec.alpha_min, spec.alpha_max)
    return a * t_max


def fake_quant_symmetric(
    x: jax.Array,
    t_max: jax.Array,
    alpha: jax.Array,
    spec: QuantSpec,
) -> jax.Array:
    """Symmetric fake-quant with trained threshold scale (§3.1.3).

    Gradients flow to ``alpha`` through both the scale multiply and the
    dequantize divide; the round/clip use STE (eqs. 16-19).
    """
    t_adj = adjusted_threshold(_bcast(t_max, x, spec), alpha, spec)
    t_adj = jnp.maximum(t_adj, _EPS)
    scale = spec.levels / t_adj  # eq. 14
    x_int = ste_round(x * scale)  # eq. 15
    x_q = clip_grad_passthrough(x_int, spec.qmin, spec.qmax)  # eq. 4/8/9
    return x_q / scale


def asymmetric_limits(
    t_l: jax.Array,
    t_r: jax.Array,
    alpha_t: jax.Array,
    alpha_r: jax.Array,
    spec: QuantSpec,
) -> tuple[jax.Array, jax.Array]:
    """Adjusted (left, width) for asymmetric thresholds (eqs. 21-23)."""
    r = t_r - t_l  # eq. 21
    at_min, at_max = spec.signed_alpha_t_range()
    left = t_l + clip_grad_passthrough(alpha_t, at_min, at_max) * r  # eq. 22
    width = clip_grad_passthrough(alpha_r, spec.alpha_r_min, spec.alpha_r_max) * r  # eq. 23
    width = jnp.maximum(width, _EPS)
    return left, width


def fake_quant_asymmetric(
    x: jax.Array,
    t_l: jax.Array,
    t_r: jax.Array,
    alpha_t: jax.Array,
    alpha_r: jax.Array,
    spec: QuantSpec,
) -> jax.Array:
    """Asymmetric (affine) fake-quant with trained limits (§3.1.4).

    Maps [left, left+width] onto [0, 2^n - 1] with an integer zero point
    (Ref. [12] style, which the paper adapts).
    """
    left, width = asymmetric_limits(
        _bcast(t_l, x, spec), _bcast(t_r, x, spec), alpha_t, alpha_r, spec
    )
    n_levels = float(2**spec.bits - 1)
    scale = n_levels / width
    # Integer zero-point (rounded so quantized values stay on the integer
    # grid; STE'd so alpha_t still receives gradient).  NOT clamped to the
    # level range: for one-sided ranges (e.g. [2.6, 3.4]) the affine
    # zero-point legitimately falls far outside [0, n_levels].
    zp = ste_round(-left * scale)
    x_int = ste_round(x * scale) + zp
    x_q = clip_grad_passthrough(x_int, 0.0, n_levels)
    return (x_q - zp) / scale


def _fq_sym_fwd_math(x, t_max, alpha, spec: QuantSpec):
    t_adj = adjusted_threshold(_bcast(t_max, x, spec), alpha, spec)
    t_adj = jnp.maximum(t_adj, _EPS)
    scale = spec.levels / t_adj
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), spec.qmin, spec.qmax)
    return (xq / scale).astype(x.dtype), t_adj


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant_symmetric_fused(x, t_max, alpha, spec: QuantSpec):
    """Same math as fake_quant_symmetric but with an analytic STE VJP.

    The stop_gradient formulation keeps several same-shape f32 temporaries
    alive through autodiff; this version's forward is one fusable
    elementwise chain, the backward another, and the only residual is x
    (plus the tiny threshold vectors) — the memory-lean path used by the
    QAT student at production shapes.
    """
    y, _ = _fq_sym_fwd_math(x, t_max, alpha, spec)
    return y


def _fq_sym_fwd(x, t_max, alpha, spec):
    y, _ = _fq_sym_fwd_math(x, t_max, alpha, spec)
    return y, (x, t_max, alpha)


def _fq_sym_bwd(spec, res, g):
    x, t_max, alpha = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    t_b = _bcast(t_max, x, spec)
    a_b = _bcast(alpha, x, spec) if alpha.ndim else alpha
    a_c = jnp.clip(a_b, spec.alpha_min, spec.alpha_max)
    t_adj = jnp.maximum(a_c * t_b, _EPS)
    inside = jnp.abs(xf) <= t_adj
    # dx: straight-through inside the clip range (eqs. 17, 19)
    dx = jnp.where(inside, gf, 0.0).astype(x.dtype)
    # dy/dT: inside -> (y - x)/T (rounding residual), outside -> sign(x)
    scale = spec.levels / t_adj
    y = jnp.clip(jnp.round(xf * scale), spec.qmin, spec.qmax) / scale
    dy_dt = jnp.where(inside, (y - xf) / t_adj, jnp.sign(xf))
    # alpha passthrough band (eq. 19 on clip(alpha))
    band = (a_b >= spec.alpha_min) & (a_b <= spec.alpha_max)
    dalpha_full = gf * dy_dt * t_b * band.astype(jnp.float32)
    if alpha.ndim == 0:
        dalpha = jnp.sum(dalpha_full)
        dt = jnp.zeros_like(t_max)
    else:
        axes = tuple(
            i for i in range(x.ndim) if i != (spec.channel_axis % x.ndim)
        )
        dalpha = jnp.sum(dalpha_full, axis=axes).reshape(alpha.shape)
        dt = jnp.zeros_like(t_max)
    return dx, dt, dalpha.astype(alpha.dtype)


fake_quant_symmetric_fused.defvjp(_fq_sym_fwd, _fq_sym_bwd)


# ---------------------------------------------------------------------------
# TQT-style trained thresholds (log2 parameterization)
# ---------------------------------------------------------------------------

_LN2 = 0.6931471805599453


def _fq_log_t_math(x, log2_t, spec: QuantSpec):
    t = jnp.exp2(_bcast(log2_t, x, spec).astype(jnp.float32))
    scale = spec.levels / jnp.maximum(t, _EPS)
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * scale),
                  spec.qmin, spec.qmax)
    return (xq / scale).astype(x.dtype)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant_log_t(x, log2_t, spec: QuantSpec):
    """Symmetric fake-quant with a TRAINED log2-domain threshold (TQT,
    arxiv 1903.08066; also the Qualcomm white paper arxiv 2106.08295).

    Where the paper's §3.1.3 trains a bounded multiplier ``alpha`` on a
    frozen calibrated ``t_max``, this trains the threshold itself as
    ``t = 2**log2_t`` — unbounded, always positive, and with a gradient
    scale-invariant across layers (the log2 reparameterization is exactly
    TQT's), which is what lets a handful of epochs move a badly
    outlier-calibrated threshold all the way back to the data's bulk.

    Backward (TQT eq. 6-8):
      dx        = g            inside the clip band (|x| <= t), 0 saturated
      d/dt      = (y - x)/t    inside (rounding residual),
                  sign(x)      saturated (y rides the clip edge t)
      d/dlog2_t = ln(2) * t * d/dt
    """
    return _fq_log_t_math(x, log2_t, spec)


def _fq_log_t_fwd(x, log2_t, spec):
    return _fq_log_t_math(x, log2_t, spec), (x, log2_t)


def _fq_log_t_bwd(spec, res, g):
    x, log2_t = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    t = jnp.maximum(jnp.exp2(_bcast(log2_t, x, spec).astype(jnp.float32)),
                    _EPS)
    inside = jnp.abs(xf) <= t
    dx = jnp.where(inside, gf, 0.0).astype(x.dtype)
    scale = spec.levels / t
    y = jnp.clip(jnp.round(xf * scale), spec.qmin, spec.qmax) / scale
    dy_dt = jnp.where(inside, (y - xf) / t, jnp.sign(xf))
    dlog_full = gf * dy_dt * _LN2 * t
    if jnp.ndim(log2_t) == 0:
        dlog = jnp.sum(dlog_full)
    else:
        axes = tuple(
            i for i in range(x.ndim) if i != (spec.channel_axis % x.ndim)
        )
        dlog = jnp.sum(dlog_full, axis=axes).reshape(jnp.shape(log2_t))
    return dx, dlog.astype(jnp.result_type(log2_t))


fake_quant_log_t.defvjp(_fq_log_t_fwd, _fq_log_t_bwd)


# ---------------------------------------------------------------------------
# Real integer quantization (serving path)
# ---------------------------------------------------------------------------


def quantize_weights_int8(
    w: jax.Array, t_max: jax.Array, alpha: jax.Array, spec: QuantSpec
) -> tuple[jax.Array, jax.Array]:
    """Produce (w_int8, per-channel float scale) for the int8 serving path.

    Returns ``w_q`` int8 and ``scale`` such that ``w ≈ w_q * scale`` with
    ``scale = T_adj / levels``.
    """
    t_adj = jnp.maximum(adjusted_threshold(t_max, alpha, spec), _EPS)
    s = spec.levels / t_adj
    w_int = jnp.clip(jnp.round(w * _bcast(s, w, spec)), spec.qmin, spec.qmax)
    return w_int.astype(jnp.int8), (1.0 / s).astype(jnp.float32)


def quantize_acts_int8(
    x: jax.Array, t_max: jax.Array, alpha: jax.Array, spec: QuantSpec
) -> tuple[jax.Array, jax.Array]:
    """Quantize activations with a *static* calibrated threshold.

    Symmetric signed int8; the threshold is frozen after calibration +
    fine-tuning, which is what makes on-device inference fast (§2: thresholds
    "are usually calculated beforehand in calibration procedure").
    """
    t_adj = jnp.maximum(adjusted_threshold(t_max, alpha, spec), _EPS)
    s = spec.levels / t_adj
    x_int = jnp.clip(jnp.round(x * _bcast(s, x, spec)), spec.qmin, spec.qmax)
    return x_int.astype(jnp.int8), (1.0 / s).astype(jnp.float32)


def quantize_bias_int32(
    b: jax.Array, act_scale: jax.Array, w_scale: jax.Array
) -> jax.Array:
    """Bias to int32 with the combined input/weight scale (eq. 20).

    b_q = clip(round(S_i * S_w * b), ±(2^31 - 1)); here ``act_scale`` and
    ``w_scale`` are *dequantization* scales (T/levels), so S = 1/scale.
    """
    s = 1.0 / jnp.maximum(act_scale * w_scale, _EPS)
    lim = float(2**31 - 1)
    return jnp.clip(jnp.round(b * s), -lim, lim).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Pointwise weight fine-tuning scales (§4.2)
# ---------------------------------------------------------------------------


def apply_pointwise_scale(
    w: jax.Array, p: jax.Array, lo: float = 0.75, hi: float = 1.25
) -> jax.Array:
    """W_eff = W * clip(p, 0.75, 1.25) — the paper's per-value trainable
    scale that lets individual weights switch quantization bins (§4.2)."""
    return w * clip_grad_passthrough(p, lo, hi)
