"""Step functions — the units the launcher jits/lowers/compiles.

  * fat_train_step   — the paper's contribution: distillation training of
                       quantization thresholds on unlabeled data (§3.2):
                       FP teacher forward + fake-quant student forward,
                       RMSE on pre-softmax logits (eq. 24-25 with
                       alpha=beta=0), Adam masked to the threshold scales,
                       cosine-annealed LR (§4.1.2).
  * calibrate_step   — observer pass (§2 calibration).
  * pretrain_step    — standard next-token CE on all params (substrate
                       proof: the framework trains, not just fine-tunes).
  * prefill_step     — int8 serving prefill; ``prefill_chunk`` switches to
                       the chunked ragged variant (lax.scan over fixed
                       prompt chunks + a per-request length vector: one
                       executable for every prompt length).
  * serve_step / decode_loop — one-token decode and the scanned
                       whole-generation loop; ``temperature``/``top_p``
                       sample from the carried PRNG key (greedy default).
  * slot_decode_loop — the continuous-batching decode block: per-slot
                       position/active vectors in the scan carry, EOS
                       freezing one slot mid-scan without touching the
                       rest, fixed (max_slots, cache_len, n_steps) shapes
                       so one executable serves every admission pattern
                       (driven by launch/scheduler.py).

The decode loops are thin compatibility wrappers now: the scan bodies
live in ``launch/strategies.py`` behind the ``DecodeStrategy`` protocol
(propose/verify/accept over an explicit ``DecodeState`` carry), with
``GreedyStrategy``/``SamplingStrategy`` as bit-exact ports of the old
bodies and ``SpeculativeStrategy`` (prompt-lookup drafting + batched
verify) as the first new scheme.  These wrappers keep the historical
signatures — (temperature, top_p) knobs in, (toks, ...) shapes out.

Masking semantics shared by the serving steps: a request's raggedness is
always DATA (length vectors, per-slot positions, active masks), never
SHAPE — that is what keeps each step a single compiled executable.  A
masked row (padded prompt tail, inactive slot) attends over zero keys and
produces exact-zero attention output; cache writes for masked rows are
bit-exact no-ops.  VMEM expectations live with the kernels
(repro.kernels.prefill_attention / decode_attention): the steps only
pick grid-friendly shapes (chunk multiples, 128-tiled cache lengths).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import api as A
from repro.core.distill import chunked_ce_loss, chunked_sq_err
from repro.launch.strategies import (  # noqa: F401  (re-exports: the
    DecodeState, DecodeStrategy, GreedyStrategy,  # pre-redesign public
    SamplingStrategy, SpeculativeStrategy,        # home of sample_tokens
    _attn_cache_len, _serve_ctx, make_strategy,
    make_strategy_decode_loop, make_strategy_slot_loop, sample_tokens)
from repro.optim.adam import AdamState, adam_init, adam_update, cosine_restarts


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    base_lr: float = 1e-3
    anneal_period: int = 100   # cosine restart period (steps)
    weight_decay: float = 0.0
    aux_weight: float = 0.01   # MoE load-balance weight (pretrain mode)


def make_calibrate_step(model, cfg, policy: A.QuantPolicy):
    def calibrate_step(params, qparams, batch):
        ctx = A.make_ctx("calibrate", policy, qparams)
        model.hidden(params, batch, ctx, remat=False)
        merged = dict(qparams)
        for path, obs in ctx.updates.items():
            if A.is_kv_path(path):  # KV observer entry: replace wholesale
                merged[path] = obs
            else:
                merged[path] = {**merged[path], "act": obs}
        return merged

    return calibrate_step


def make_fat_train_step(model, cfg, policy: A.QuantPolicy,
                        hp: TrainHParams = TrainHParams(),
                        n_micro: int = 1):
    """The FAT QAT step.  Gradients are taken w.r.t. the full qparams tree
    but the Adam update is masked to the trained leaves (alpha scales and
    optional pointwise scales) — §3.1.3: "All network parameters except
    quantization thresholds are fixed".

    ``n_micro`` > 1 runs microbatched gradient accumulation: activations
    peak at one microbatch's footprint while the accumulated state is just
    the qparams-shaped gradient (a few KB of alphas + thresholds) — the
    cheapest gradient-accumulation in existence, courtesy of FAT's tiny
    trainable set.
    """

    def loss_for(qp, params, batch):
        # weights are frozen in FAT (§3.1.3) — without this stop_gradient
        # the layer-scan VJP materializes a stacked (L, ...) f32 cotangent
        # for every weight tensor it scans over (GBs that are immediately
        # discarded)
        params = jax.lax.stop_gradient(params)
        # teacher: full precision, frozen
        h_t, _ = model.hidden(params, batch, None, remat=cfg.remat)
        h_t = jax.lax.stop_gradient(h_t)
        # student: fake-quantized with trained thresholds
        ctx = A.make_ctx("fake", policy, qp)
        h_s, _ = model.hidden(params, batch, ctx, remat=cfg.remat)
        ro_t = model.readout_fn(params, None)
        ro_s = model.readout_fn(params, ctx)
        sq, n = chunked_sq_err(h_t, h_s, ro_t, ro_s, chunk=cfg.loss_chunk)
        return jnp.sqrt(sq / n)  # eq. 25

    def train_step(params, qparams, opt_state: AdamState, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_for)(qparams, params, batch)
        else:
            from repro.dist.constraints import constrain_activation

            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                mb = jax.tree.map(constrain_activation, mb)
                l, g = jax.value_and_grad(loss_for)(qparams, params, mb)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), qparams)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        lr = cosine_restarts(opt_state.step, hp.base_lr, hp.anneal_period)
        mask = A.trainable_mask(qparams)
        new_qp, new_opt = adam_update(grads, opt_state, qparams, lr, mask=mask)
        return new_qp, new_opt, {"loss": loss, "lr": lr}

    return train_step


def finetune_thresholds(model, cfg, policy: A.QuantPolicy, params, qparams,
                        batches, *, epochs: int = 4,
                        hp: TrainHParams = TrainHParams()):
    """Train the quantization thresholds by distillation (paper §3 + TQT).

    Runs the existing FAT QAT step — fp teacher vs fake-quant student,
    RMSE on pre-softmax logits, Adam masked to the trainable qparams
    leaves — over ``epochs`` passes of a small calibration set.  With
    ``finalize_calibration(..., train_thresholds=True)`` qparams the
    trainable set includes the per-head KV ``log2_t`` thresholds (the
    attention fake-mode hook quantizes the KV stream through them), so a
    handful of epochs repairs max-abs thresholds that an outlier in the
    calibration data blew up — exactly the paper's pitch, applied to the
    int4 cache where the 7-level grid makes threshold quality decisive.

    ``epochs`` is capped at 8: the paper's method converges within a few
    epochs on a small unlabeled set, and the cap keeps serving bring-up
    (engine --finetune-thresholds) bounded.  Returns
    ``(qparams, losses)`` — per-step distill losses, first entry the
    pre-training loss (tests pin strict decrease against it).
    """
    if not 1 <= epochs <= 8:
        raise ValueError(f"epochs must be in [1, 8], got {epochs}")
    batches = list(batches)
    if not batches:
        raise ValueError("finetune_thresholds needs >= 1 calibration batch")
    train_step = jax.jit(make_fat_train_step(model, cfg, policy, hp))
    opt = adam_init(qparams)
    losses = []
    for _ in range(epochs):
        for batch in batches:
            qparams, opt, metrics = train_step(params, qparams, opt, batch)
            losses.append(float(metrics["loss"]))
    return qparams, losses


def make_pretrain_step(model, cfg, hp: TrainHParams = TrainHParams()):
    def pretrain_step(params, opt_state: AdamState, batch):
        def loss_fn(params):
            h, aux = model.hidden(params, batch, None, remat=cfg.remat)
            if cfg.modality == "vlm":
                h = h[:, cfg.mm_patches:, :]
            ce = chunked_ce_loss(h, batch["labels"], model.readout_fn(params),
                                 chunk=cfg.loss_chunk)
            return ce + hp.aux_weight * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = cosine_restarts(opt_state.step, hp.base_lr, hp.anneal_period)
        new_params, new_opt = adam_update(grads, opt_state, params, lr,
                                          weight_decay=hp.weight_decay)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return pretrain_step


def pad_for_chunked_prefill(tokens, chunk: int, lengths=None):
    """Pad (B, S) tokens up to a ``chunk`` multiple and build the
    per-request length vector the chunked prefill step consumes
    (defaults to the unpadded S for every request)."""
    b, s = tokens.shape
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        tokens = jnp.pad(tokens, [(0, 0), (0, s_pad - s)])
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    return tokens, jnp.asarray(lengths, jnp.int32)


def make_prefill_step(model, cfg, policy: A.QuantPolicy, mode: str = "int8",
                      prefill_chunk: int | None = None):
    """Prefill step.  With ``prefill_chunk`` set, returns the CHUNKED
    variant ``prefill_step(params, qparams, batch, cache, lengths)``: a
    ``lax.scan`` over fixed-size prompt chunks with a per-request length
    vector, so ONE compiled executable serves ragged prompt lengths
    (tokens padded to a chunk multiple) instead of recompiling per shape.
    Each chunk appends its K/V at absolute cache slots and attends over
    the growing cache; the carry keeps each request's last valid hidden
    state so the readout runs once on (B, 1, d), never on full logits.
    """
    if prefill_chunk is None:
        def prefill_step(serve_params, qparams, batch, cache):
            ctx = _serve_ctx(mode, policy, qparams)
            logits, new_cache = model.prefill(serve_params, batch, cache, ctx)
            return logits, new_cache

        return prefill_step

    kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
    if kinds - {"attn", "attn_local"} or cfg.modality != "text":
        raise ValueError(
            "chunked prefill covers attention-only text stacks: SSM state "
            "folding has no per-request length masking yet "
            f"(got kinds={sorted(kinds)}, modality={cfg.modality})")

    def prefill_step(serve_params, qparams, batch, cache, lengths):
        ctx = _serve_ctx(mode, policy, qparams)
        tokens = batch["tokens"]
        b, s_max = tokens.shape
        if s_max % prefill_chunk:
            raise ValueError(
                f"tokens length {s_max} must pad to a multiple of "
                f"prefill_chunk={prefill_chunk} "
                "(steps.pad_for_chunked_prefill)")
        cache_len = _attn_cache_len(cache)
        if cache_len is not None and s_max > cache_len:
            # dynamic_update_slice would silently CLAMP the out-of-range
            # chunk write, shifting keys into wrong slots — reject instead
            raise ValueError(
                f"padded prompt {s_max} exceeds the cache length "
                f"{cache_len}; size the cache to at least the padded "
                "prompt (prompt_len rounded up to prefill_chunk) + gen")
        lengths = jnp.asarray(lengths, jnp.int32)
        n_chunks = s_max // prefill_chunk

        def body(carry, ci):
            cache, h_last = carry
            tok_c = jax.lax.dynamic_slice_in_dim(
                tokens, ci * prefill_chunk, prefill_chunk, axis=1)
            h, cache = model.prefill_chunk(
                serve_params, tok_c, cache, ci * prefill_chunk, ctx,
                lengths=lengths, kv_limit=s_max)
            # carry the last VALID hidden of each request (requests whose
            # final token lives in this chunk update; others keep theirs)
            last = lengths - 1
            here = (last >= ci * prefill_chunk) & (
                last < (ci + 1) * prefill_chunk)
            idx = jnp.clip(last - ci * prefill_chunk, 0, prefill_chunk - 1)
            h_sel = jnp.take_along_axis(h, idx[:, None, None], axis=1)
            h_last = jnp.where(here[:, None, None], h_sel.astype(h_last.dtype),
                               h_last)
            return (cache, h_last), None

        h0 = jnp.zeros((b, 1, cfg.d_model), cfg.dtype)
        (cache, h_last), _ = jax.lax.scan(body, (cache, h0),
                                          jnp.arange(n_chunks))
        logits = model.readout_fn(serve_params, ctx)(h_last)
        return logits, cache

    return prefill_step


def make_serve_step(model, cfg, policy: A.QuantPolicy, mode: str = "int8"):
    def serve_step(serve_params, qparams, tokens, cache, cur_pos,
                   slot_mask=None):
        ctx = _serve_ctx(mode, policy, qparams)
        logits, new_cache = model.decode_step(serve_params, tokens, cache,
                                              cur_pos, ctx,
                                              slot_mask=slot_mask)
        # greedy next token; make_decode_loop overrides with sample_tokens
        # when a temperature is set
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step


def make_decode_loop(model, cfg, policy: A.QuantPolicy, mode: str = "int8",
                     n_steps: int = 16, temperature: float = 0.0,
                     top_p: float = 1.0):
    """Whole-generation decode as ONE compiled call (the serving fast path).

    Compatibility wrapper over ``strategies.make_strategy_decode_loop``:
    ``temperature`` picks ``GreedyStrategy`` (0.0, the default — bit-
    identical greedy decoding) or ``SamplingStrategy`` (per-step key
    split, optional nucleus ``top_p``).  The scan carries (token, cache,
    position, PRNG key); N tokens cost one dispatch and XLA keeps the
    cache resident across steps.  Callers should jit with
    ``donate_argnums=(3,)`` so the input cache buffer is reused for the
    scan carry instead of doubling resident cache HBM (serve.py does).

    Returns (tokens (B, n_steps), final cache); tokens[:, 0] is ``tok0``
    (the caller's prefill argmax/sample), the rest come from the scan.
    For speculative decoding build the loop directly via
    ``strategies.make_strategy_decode_loop(...,  SpeculativeStrategy)``
    (Engine.generate_batch does).
    """
    strategy = make_strategy(None, model, cfg, policy, mode,
                             temperature=temperature, top_p=top_p)
    return make_strategy_decode_loop(model, cfg, policy, strategy,
                                     mode=mode, n_steps=n_steps)


def make_slot_decode_loop(model, cfg, policy: A.QuantPolicy,
                          mode: str = "int8", n_steps: int = 8,
                          temperature: float = 0.0, top_p: float = 1.0,
                          eos_id: int = -1):
    """One continuous-batching decode BLOCK: ``n_steps`` scanned steps over
    a slot batch where every slot sits at its own position.

    Compatibility wrapper over ``strategies.make_strategy_slot_loop``
    with the one-token strategies (greedy / sampled by ``temperature``),
    keeping the historical signature and shapes:

    Returns ``(toks (B, n_steps), emitted (B, n_steps) bool, cache,
    pos, active, key)``: ``emitted[b, i]`` marks real tokens (the EOS
    itself is emitted; everything after is padding).  The scheduler
    (launch/scheduler.py) runs strategy loops directly (so it can thread
    speculative windows and the history carry); this wrapper serves the
    tests and tooling that pin the one-token block semantics.

    ``eos_id < 0`` disables EOS detection (fixed-budget generation).
    Callers should jit with ``donate_argnums=(3,)`` like the
    single-stream loop.
    """
    strategy = make_strategy(None, model, cfg, policy, mode,
                             temperature=temperature, top_p=top_p)
    inner = make_strategy_slot_loop(model, cfg, policy, strategy,
                                    mode=mode, n_steps=n_steps,
                                    eos_id=eos_id)

    def slot_decode_loop(serve_params, qparams, tok0, cache, pos0, active0,
                         key=None):
        toks, emitted, cache, pos, active, key, _, _ = inner(
            serve_params, qparams, tok0, cache, pos0, active0, key)
        return toks, emitted, cache, pos, active, key

    return slot_decode_loop
