"""Step functions — the units the launcher jits/lowers/compiles.

  * fat_train_step   — the paper's contribution: distillation training of
                       quantization thresholds on unlabeled data (§3.2):
                       FP teacher forward + fake-quant student forward,
                       RMSE on pre-softmax logits (eq. 24-25 with
                       alpha=beta=0), Adam masked to the threshold scales,
                       cosine-annealed LR (§4.1.2).
  * calibrate_step   — observer pass (§2 calibration).
  * pretrain_step    — standard next-token CE on all params (substrate
                       proof: the framework trains, not just fine-tunes).
  * prefill_step / serve_step — int8 serving paths (weights int8-resident).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import api as A
from repro.core.distill import chunked_ce_loss, chunked_sq_err
from repro.optim.adam import AdamState, adam_init, adam_update, cosine_restarts


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    base_lr: float = 1e-3
    anneal_period: int = 100   # cosine restart period (steps)
    weight_decay: float = 0.0
    aux_weight: float = 0.01   # MoE load-balance weight (pretrain mode)


def make_calibrate_step(model, cfg, policy: A.QuantPolicy):
    def calibrate_step(params, qparams, batch):
        ctx = A.make_ctx("calibrate", policy, qparams)
        model.hidden(params, batch, ctx, remat=False)
        merged = dict(qparams)
        for path, obs in ctx.updates.items():
            if A.is_kv_path(path):  # KV observer entry: replace wholesale
                merged[path] = obs
            else:
                merged[path] = {**merged[path], "act": obs}
        return merged

    return calibrate_step


def make_fat_train_step(model, cfg, policy: A.QuantPolicy,
                        hp: TrainHParams = TrainHParams(),
                        n_micro: int = 1):
    """The FAT QAT step.  Gradients are taken w.r.t. the full qparams tree
    but the Adam update is masked to the trained leaves (alpha scales and
    optional pointwise scales) — §3.1.3: "All network parameters except
    quantization thresholds are fixed".

    ``n_micro`` > 1 runs microbatched gradient accumulation: activations
    peak at one microbatch's footprint while the accumulated state is just
    the qparams-shaped gradient (a few KB of alphas + thresholds) — the
    cheapest gradient-accumulation in existence, courtesy of FAT's tiny
    trainable set.
    """

    def loss_for(qp, params, batch):
        # weights are frozen in FAT (§3.1.3) — without this stop_gradient
        # the layer-scan VJP materializes a stacked (L, ...) f32 cotangent
        # for every weight tensor it scans over (GBs that are immediately
        # discarded)
        params = jax.lax.stop_gradient(params)
        # teacher: full precision, frozen
        h_t, _ = model.hidden(params, batch, None, remat=cfg.remat)
        h_t = jax.lax.stop_gradient(h_t)
        # student: fake-quantized with trained thresholds
        ctx = A.make_ctx("fake", policy, qp)
        h_s, _ = model.hidden(params, batch, ctx, remat=cfg.remat)
        ro_t = model.readout_fn(params, None)
        ro_s = model.readout_fn(params, ctx)
        sq, n = chunked_sq_err(h_t, h_s, ro_t, ro_s, chunk=cfg.loss_chunk)
        return jnp.sqrt(sq / n)  # eq. 25

    def train_step(params, qparams, opt_state: AdamState, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_for)(qparams, params, batch)
        else:
            from repro.dist.constraints import constrain_activation

            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                mb = jax.tree.map(constrain_activation, mb)
                l, g = jax.value_and_grad(loss_for)(qparams, params, mb)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), qparams)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        lr = cosine_restarts(opt_state.step, hp.base_lr, hp.anneal_period)
        mask = A.trainable_mask(qparams)
        new_qp, new_opt = adam_update(grads, opt_state, qparams, lr, mask=mask)
        return new_qp, new_opt, {"loss": loss, "lr": lr}

    return train_step


def make_pretrain_step(model, cfg, hp: TrainHParams = TrainHParams()):
    def pretrain_step(params, opt_state: AdamState, batch):
        def loss_fn(params):
            h, aux = model.hidden(params, batch, None, remat=cfg.remat)
            if cfg.modality == "vlm":
                h = h[:, cfg.mm_patches:, :]
            ce = chunked_ce_loss(h, batch["labels"], model.readout_fn(params),
                                 chunk=cfg.loss_chunk)
            return ce + hp.aux_weight * aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = cosine_restarts(opt_state.step, hp.base_lr, hp.anneal_period)
        new_params, new_opt = adam_update(grads, opt_state, params, lr,
                                          weight_decay=hp.weight_decay)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return pretrain_step


def _serve_ctx(mode: str, policy: A.QuantPolicy, qparams):
    """Serving ctx.  A ctx is built even for mode='none' when the policy
    quantizes the KV cache (Dense layers still run full precision —
    enabled() is False): the int8-KV-over-bf16-weights ablation needs the
    KV thresholds in qparams to reach attention."""
    if mode == "none" and not policy.kv_int8:
        return None
    return A.make_ctx(mode, policy, qparams)


def make_prefill_step(model, cfg, policy: A.QuantPolicy, mode: str = "int8"):
    def prefill_step(serve_params, qparams, batch, cache):
        ctx = _serve_ctx(mode, policy, qparams)
        logits, new_cache = model.prefill(serve_params, batch, cache, ctx)
        return logits, new_cache

    return prefill_step


def make_serve_step(model, cfg, policy: A.QuantPolicy, mode: str = "int8"):
    def serve_step(serve_params, qparams, tokens, cache, cur_pos):
        ctx = _serve_ctx(mode, policy, qparams)
        logits, new_cache = model.decode_step(serve_params, tokens, cache,
                                              cur_pos, ctx)
        # greedy next token (sampled serving wires a temperature here)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step


def make_decode_loop(model, cfg, policy: A.QuantPolicy, mode: str = "int8",
                     n_steps: int = 16):
    """Whole-generation decode as ONE compiled call (the serving fast path).

    The per-token Python loop re-dispatches the jitted step every token —
    at decode shapes the dispatch overhead rivals the compute.  Here the
    greedy-decode body rolls into a single ``jax.lax.scan`` carrying
    (token, cache, position): N tokens cost one dispatch and XLA keeps the
    cache resident across steps.  Callers should jit with
    ``donate_argnums=(3,)`` so the input cache buffer is reused for the
    scan carry instead of doubling resident cache HBM (serve.py does).

    Returns (tokens (B, n_steps), final cache); tokens[:, 0] is ``tok0``
    (the prefill argmax), the remaining n_steps-1 come from the scan.
    """

    step = make_serve_step(model, cfg, policy, mode=mode)

    def decode_loop(serve_params, qparams, tok0, cache, pos0):
        def body(carry, _):
            tok, cache, pos = carry
            nxt, _, cache = step(serve_params, qparams, tok[:, None], cache,
                                 pos)
            return (nxt, cache, pos + 1), nxt

        carry0 = (tok0, cache, jnp.asarray(pos0, jnp.int32))
        (_, cache, _), toks = jax.lax.scan(body, carry0, None,
                                           length=n_steps - 1)
        toks = jnp.concatenate([tok0[:, None], jnp.moveaxis(toks, 0, 1)],
                               axis=1)
        return toks, cache

    return decode_loop
