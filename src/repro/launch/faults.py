"""Deterministic fault injection for the serving stack.

The resilience layer (per-request isolation, deadlines, preemption,
load shedding — see ``launch/scheduler.py``) is only trustworthy if its
degraded paths are *exercised*, and they are exactly the paths that
never fire in a healthy smoke run.  A :class:`FaultPlan` is a frozen,
hashable schedule of injected faults that the ``SlotScheduler`` consults
at fixed points of its host loop, so every degraded path can be driven
bit-reproducibly — in unit tests, in the ``degraded_traffic`` benchmark
scenario, and from the CLI (``serve.py --fault-plan``).

Fault classes (one knob per degraded path):

``reject``          admission fails for these request ids before any
                    device work runs — the simulated "prefill raised"
                    path (the request retires ``status='failed'``).
``nan_prefill``     the admission prefill's sampling logits are forced
                    non-finite for these request ids, exercising the
                    NaN-rejection guard without needing a genuinely
                    broken checkpoint.
``nan_decode``      ``(rid, step)`` pairs: request ``rid``'s decode
                    logits turn NaN at its ``step``-th decode scan step.
                    The injection happens INSIDE the compiled decode
                    loop, driven by a per-slot step vector — data, never
                    shape, so a faulted run reuses the clean run's
                    executable (the no-retrace contract).
``preempt``         ``(block, rid)`` pairs: at decode-block boundary
                    ``block`` the scheduler force-preempts request
                    ``rid`` (snapshot + park + later re-admit), as if a
                    higher-priority request had demanded its slot.
``exhaust_prefix``  every ``PrefixStore.reserve`` is treated as
                    pool-exhausted, forcing the fall-back-to-private-
                    pages path on every paged admission.
``crash``           decode-block boundaries (1-based: ``(k,)`` crashes
                    after the k-th completed block) at which the
                    scheduler raises :class:`SimulatedCrash` — AFTER its
                    write-ahead journal records for the boundary are
                    flushed, so crash recovery (``launch/journal.py``,
                    ``SlotScheduler.recover``) is driveable
                    deterministically at any boundary.  Unlike every
                    other fault class, a crash ESCAPES ``run()``: it
                    simulates process death, not a request-level fault.
``ms_per_block``    > 0 switches the scheduler to a VIRTUAL clock that
                    advances exactly this many milliseconds per decode
                    block — deadlines, arrivals, and shedding become
                    deterministic functions of the block schedule
                    instead of wall time.

Injection is host-driven or data-driven by construction: no fault ever
changes a compiled executable's shape, which is what makes "non-faulted
requests are bit-identical to the fault-free run" a testable property
(tests/test_resilience.py pins it).
"""
from __future__ import annotations

import dataclasses
import json
import os


class InjectedFault(RuntimeError):
    """Raised by the scheduler at an injection point; caught by the
    per-request isolation layer like any real admission failure."""


class SimulatedCrash(RuntimeError):
    """Raised at a ``crash`` decode-block boundary, after the journal
    records for that boundary are durable.  Deliberately NOT caught by
    the scheduler: it stands in for process death, so recovery must run
    through a fresh scheduler (``SlotScheduler.recover``)."""


def _int_tuple(xs):
    return tuple(sorted(int(x) for x in xs))


def _pair_tuple(xs):
    """Normalize {key: val} dicts (JSON) or (a, b) pair iterables into a
    sorted tuple of int pairs."""
    if isinstance(xs, dict):
        xs = [(k, v) for k, v in xs.items()]
    return tuple(sorted((int(a), int(b)) for a, b in xs))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, hashable fault schedule (see module docstring).

    Frozen with tuple-valued fields so a plan can sit directly in the
    Engine's scheduler cache key — two generates under different plans
    never share a stale scheduler, while re-running the same plan reuses
    the compiled executables.
    """

    reject: tuple = ()          # rids: admission fails before device work
    nan_prefill: tuple = ()     # rids: prefill sampling logits -> NaN
    nan_decode: tuple = ()      # ((rid, step), ...): decode logits -> NaN
    preempt: tuple = ()         # ((block, rid), ...): forced preemption
    exhaust_prefix: bool = False
    crash: tuple = ()           # block boundaries: simulated process crash
    ms_per_block: float = 0.0   # > 0: virtual clock, ms per decode block

    def __post_init__(self):
        object.__setattr__(self, "reject", _int_tuple(self.reject))
        object.__setattr__(self, "nan_prefill",
                           _int_tuple(self.nan_prefill))
        object.__setattr__(self, "nan_decode",
                           _pair_tuple(self.nan_decode))
        object.__setattr__(self, "preempt", _pair_tuple(self.preempt))
        object.__setattr__(self, "exhaust_prefix",
                           bool(self.exhaust_prefix))
        object.__setattr__(self, "crash", _int_tuple(self.crash))
        object.__setattr__(self, "ms_per_block",
                           float(self.ms_per_block))
        if self.ms_per_block < 0:
            raise ValueError("ms_per_block must be >= 0")
        # one NaN step per rid: ``nan_decode_step`` returns a single step,
        # so a duplicate rid would silently lose all but the first match
        rids = [r for r, _ in self.nan_decode]
        dup = sorted({r for r in rids if rids.count(r) > 1})
        if dup:
            raise ValueError(
                f"nan_decode schedules multiple steps for rid(s) {dup}; "
                "each rid may turn NaN at exactly one decode step")
        # duplicate (block, rid) preemptions would double-count the same
        # eviction (the pair either fires once or is a spec mistake)
        if len(set(self.preempt)) != len(self.preempt):
            dup = sorted({p for p in self.preempt
                          if self.preempt.count(p) > 1})
            raise ValueError(
                f"preempt lists duplicate (block, rid) pair(s) {dup}")
        if any(b < 1 for b in self.crash):
            raise ValueError(
                "crash boundaries are 1-based (after the k-th completed "
                f"decode block), got {self.crash}")

    # -- queries (the scheduler's injection points) -----------------------
    def rejects(self, rid: int) -> bool:
        return int(rid) in self.reject

    def nans_prefill(self, rid: int) -> bool:
        return int(rid) in self.nan_prefill

    def nan_decode_step(self, rid: int):
        """The absolute decode scan step at which ``rid``'s logits turn
        non-finite, or None."""
        for r, step in self.nan_decode:
            if r == int(rid):
                return step
        return None

    def preempts_at(self, block: int) -> tuple:
        """Request ids force-preempted at decode-block boundary
        ``block``."""
        return tuple(rid for blk, rid in self.preempt if blk == int(block))

    def crash_at(self, block: int) -> bool:
        """Whether the scheduler crashes after ``block`` completed decode
        blocks (checked once per boundary; a recovered run resumes past
        the boundary, so the same crash never re-fires)."""
        return int(block) in self.crash

    @property
    def empty(self) -> bool:
        return self == FaultPlan()

    # -- (de)serialization -------------------------------------------------
    @classmethod
    def parse(cls, spec) -> "FaultPlan":
        """Build a plan from a dict, a JSON string, or a path to a JSON
        file (the ``serve.py --fault-plan`` formats).  JSON keys match
        the field names; ``nan_decode``/``preempt`` accept either pair
        lists or ``{"rid": step}`` / ``{"block": rid}`` objects."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            if os.path.exists(spec):
                with open(spec) as f:
                    spec = json.load(f)
            else:
                spec = json.loads(spec)
        if not isinstance(spec, dict):
            raise ValueError(
                f"fault plan must be a JSON object, got {type(spec).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(spec) - known
        if unknown:
            raise ValueError(
                f"unknown fault plan keys {sorted(unknown)} "
                f"(known: {sorted(known)})")
        return cls(**spec)

    def describe(self) -> str:
        """One-line human summary for CLI / bench logs."""
        bits = []
        if self.reject:
            bits.append(f"reject rids {list(self.reject)}")
        if self.nan_prefill:
            bits.append(f"nan prefill rids {list(self.nan_prefill)}")
        if self.nan_decode:
            bits.append("nan decode " +
                        ", ".join(f"rid {r}@step {s}"
                                  for r, s in self.nan_decode))
        if self.preempt:
            bits.append("preempt " +
                        ", ".join(f"rid {r}@block {b}"
                                  for b, r in self.preempt))
        if self.exhaust_prefix:
            bits.append("prefix pool exhausted")
        if self.crash:
            bits.append(f"crash at block {list(self.crash)}")
        if self.ms_per_block:
            bits.append(f"virtual clock {self.ms_per_block:g} ms/block")
        return "; ".join(bits) if bits else "no faults"
