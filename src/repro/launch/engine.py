"""``Engine`` — the serving facade over the int8 FAT pipeline.

Before this module, every serving surface (the CLI, the examples, the
benchmark) re-assembled the same stack by hand: config -> model -> random
or checkpointed params -> calibration pass -> int8 conversion -> jitted
step functions -> cache sizing -> prefill/decode driver.  The Engine owns
that assembly once:

    engine = Engine.from_checkpoint(arch="smollm-135m", smoke=True)
    # batched one-shot serving (prefill + scanned decode, AOT-compiled):
    result = engine.generate_batch(batch, gen=16)
    # one prompt == generate_batch at B=1 (same executables, no drift):
    result = engine.generate_one(prompt_tokens, gen=16)
    # continuous batching (slot scheduler; paged layout => prefix sharing):
    completions = engine.generate(requests, max_slots=4)

The decode scheme is an Engine-level knob too (``decode_strategy`` in
{"greedy", "sample", "speculative"} + ``spec_k``/``spec_ngram``): both
serving paths run the same ``DecodeStrategy`` (launch/strategies.py),
so speculative decoding — bit-identical tokens to greedy — is one
constructor argument away on either.

``from_checkpoint`` restores params via repro.checkpoint.manager when a
directory is given (the ``{"params": ...}`` tree train.py writes) and
falls back to seeded random init; either way it runs the paper's §2
calibration pass and (unless ``fp=True``) the int8 weight conversion —
the frozen-threshold artifact every layout of the KV cache relies on.

The cache layout is an Engine-level knob (``cache_layout`` in
{"dense", "ring", "paged"} + ``page_size``), threaded through
``model.init_cache`` and the scheduler — callers never touch layout
internals.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model


def prepare_int8(model, cfg, policy, params, calib_batches, *,
                 convert: bool = True, finetune_epochs: int = 0):
    """Calibration + int8 conversion (the paper's deployment pipeline).

    ``convert=False`` stops after calibration (bf16-weight ablations need
    the thresholds but not a second, immediately-discarded param pytree).
    ``finetune_epochs`` > 0 inserts the paper's §3 threshold training
    between calibration and conversion: finalize emits trainable
    ``log2_t`` KV thresholds, ``steps.finetune_thresholds`` distills
    them against the fp teacher over the same calibration batches, and
    ``freeze_thresholds`` collapses the result back to the static
    ``t_max`` form serving consumes — so everything downstream of this
    function is byte-identical in shape either way.
    """
    qparams = A.init_qparams(model, params, policy)
    calib = jax.jit(ST.make_calibrate_step(model, cfg, policy))
    calib_batches = list(calib_batches)
    for b in calib_batches:
        qparams = calib(params, qparams, b)
    qparams = A.finalize_calibration(
        qparams, policy, train_thresholds=finetune_epochs > 0)
    if finetune_epochs > 0:
        qparams, _losses = ST.finetune_thresholds(
            model, cfg, policy, params, qparams, calib_batches,
            epochs=finetune_epochs)
        qparams = A.freeze_thresholds(qparams)
    serve_params = (A.convert_to_int8(model, params, qparams, policy)
                    if convert else params)
    return serve_params, qparams


@dataclasses.dataclass
class GenerationResult:
    """Output of the single-stream batched path, with the steady-state
    timings the CLI and benchmark report."""
    tokens: jax.Array          # (B, gen) generated token ids
    prefill_s: float           # AOT-compiled prefill wall time
    decode_s: float            # decode wall time (gen - 1 steps)
    prompt_tokens: int
    gen_tokens: int


class Engine:
    """One assembled serving stack: model + converted params + finalized
    thresholds + sampling policy + cache layout.  See module docstring."""

    def __init__(self, model, cfg, policy: A.QuantPolicy, serve_params,
                 qparams, *, mode: str = "int8", cache_layout: str = "ring",
                 page_size: int = 64, prefill_chunk: Optional[int] = None,
                 temperature: float = 0.0, top_p: float = 1.0,
                 seed: int = 0, decode_strategy: Optional[str] = None,
                 spec_k: int = 4, spec_ngram: int = 2,
                 queue_cap: Optional[int] = None, shed_policy: str = "shed",
                 fault_plan=None, journal: Optional[str] = None,
                 snapshot_every: int = 0,
                 snapshot_dir: Optional[str] = None):
        """``decode_strategy`` picks the decode-loop scheme
        (strategies.STRATEGIES: "greedy" | "sample" | "speculative");
        None auto-selects from ``temperature`` (the historical behavior).
        ``spec_k``/``spec_ngram`` are the speculative draft-window and
        prompt-lookup n-gram sizes (static — no retrace across draft
        contents).

        Resilience knobs (continuous batching; see launch/scheduler.py):
        ``queue_cap`` bounds the admission queue and ``shed_policy``
        ("shed" | "block") picks the overload behavior; ``fault_plan``
        (a :class:`repro.launch.faults.FaultPlan`, or anything its
        ``parse`` accepts) injects deterministic faults for chaos testing
        and the degraded-traffic benchmark.

        Durability knobs (see launch/journal.py and the scheduler's
        recovery methods): ``journal`` is the write-ahead request
        journal path (enables ``recover()`` after a crash);
        ``snapshot_every`` > 0 writes a full state snapshot every N
        decode-block boundaries through a ``CheckpointManager`` at
        ``snapshot_dir`` (which alone enables on-demand
        ``save_state``/``load_state``)."""
        from repro.cache import LAYOUTS
        from repro.launch import strategies as SG
        from repro.launch.faults import FaultPlan

        if cache_layout not in LAYOUTS:
            raise ValueError(f"cache_layout must be one of {LAYOUTS}, got "
                             f"{cache_layout!r}")
        if shed_policy not in ("shed", "block"):
            raise ValueError(f"shed_policy must be 'shed' or 'block', got "
                             f"{shed_policy!r}")
        if fault_plan is not None:
            fault_plan = FaultPlan.parse(fault_plan)
        if decode_strategy is not None:
            # eager validation through the single authority
            # (strategies.make_strategy): unknown names,
            # strategy/temperature conflicts, and attention-only-config
            # violations all reject at construction, not first generate
            SG.make_strategy(decode_strategy, model, cfg, policy, mode,
                             temperature=temperature, top_p=top_p,
                             spec_k=spec_k, spec_ngram=spec_ngram)
        self.model, self.cfg, self.policy = model, cfg, policy
        self.serve_params, self.qparams = serve_params, qparams
        self.mode = mode
        self.cache_layout = cache_layout
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.temperature, self.top_p, self.seed = temperature, top_p, seed
        self.decode_strategy = decode_strategy
        self.spec_k, self.spec_ngram = spec_k, spec_ngram
        self.queue_cap, self.shed_policy = queue_cap, shed_policy
        self.fault_plan = fault_plan
        if snapshot_every < 0:
            raise ValueError(
                f"snapshot_every must be >= 0, got {snapshot_every}")
        if snapshot_every > 0 and snapshot_dir is None:
            raise ValueError(
                "snapshot_every > 0 needs a snapshot_dir to write to")
        self.journal = journal
        self.snapshot_every = snapshot_every
        self.snapshot_dir = snapshot_dir
        self._scheduler = None
        self._scheduler_key = None

    # -- assembly ----------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, arch: str = "smollm-135m", *,
                        cfg=None,
                        checkpoint_dir: Optional[str] = None,
                        smoke: bool = True, fp: bool = False,
                        kv_int8: bool = True, kv_bits: int = 8,
                        finetune_thresholds: int = 0,
                        use_pallas: Optional[bool] = None,
                        calib_batches: Optional[Sequence] = None,
                        n_calib: int = 2, calib_batch: int = 4,
                        calib_len: int = 32, init_seed: int = 0,
                        **engine_kw) -> "Engine":
        """Build a ready-to-serve Engine.

        ``checkpoint_dir`` restores the newest ``{"params": ...}`` tree
        written by launch/train.py (mesh-agnostic restore); without one,
        params are seeded random init (smoke/bench usage).  ``fp`` serves
        bf16 weights (baseline); ``kv_int8`` quantizes the KV cache and
        ``kv_bits`` picks its width (8, or 4 = packed nibbles — quarter
        of the bf16 cache bytes).  ``finetune_thresholds`` > 0 trains
        the KV thresholds by distillation for that many epochs before
        freezing (paper §3; the knob that makes the 7-level int4 grid
        usable when max-abs calibration over-shoots).  ``calib_batches``
        overrides the default data-pipeline calibration stream
        (``n_calib`` batches of (calib_batch, calib_len) tokens).
        Remaining ``engine_kw`` go to ``Engine.__init__`` (cache_layout,
        page_size, temperature, ...).  ``cfg`` overrides the registry
        lookup with an explicit :class:`ModelConfig` (e.g. a
        ``cfg.replace(...)`` variant with shard-divisible head counts
        for the sharded engine); ``arch``/``smoke`` are ignored then.
        """
        if cfg is None:
            cfg = get_config(arch, smoke=smoke)
        model = build_model(cfg)
        use_pallas = (jax.default_backend() == "tpu" if use_pallas is None
                      else use_pallas)
        policy = A.QuantPolicy(kv_int8=kv_int8, kv_bits=kv_bits,
                               use_pallas=use_pallas)
        params = model.init(jax.random.PRNGKey(init_seed))
        if checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager

            tree, _meta = CheckpointManager(checkpoint_dir).restore_latest()
            params = tree["params"]

        if calib_batches is None:
            shape = ShapeSpec("engine", "train", calib_len, calib_batch)
            spec = DP.spec_for(cfg, shape)
            calib_batches = DP.calibration_batches(spec, n_calib)
            for b in calib_batches:
                b.pop("labels", None)

        mode = "none" if fp else "int8"
        if fp and not kv_int8:
            serve_params, qparams = params, A.finalize_calibration(
                A.init_qparams(model, params, policy), policy)
        else:
            # int8 weights and/or int8 KV both need the calibration pass;
            # bf16-weight ablations skip the weight conversion
            serve_params, qparams = prepare_int8(
                model, cfg, policy, params, calib_batches, convert=not fp,
                finetune_epochs=finetune_thresholds)
        return cls(model, cfg, policy, serve_params, qparams, mode=mode,
                   **engine_kw)

    # -- introspection -----------------------------------------------------
    def n_int8_weights(self) -> int:
        return sum(1 for l in jax.tree.leaves(self.serve_params)
                   if l.dtype == jnp.int8)

    def analyze(self, *, batch: int = 2, prompt_len: int = 32,
                cache_len: int = 64):
        """Static contract checks over THIS engine's serving graphs.

        Traces the engine's own prefill and decode entry points (its
        params, its policy, its cache layout — not the generic smoke
        assembly in repro.analysis.entrypoints) and runs the jaxpr
        analyzers plus the freeze/donation checks on the live state.
        Returns a list of findings; empty means every contract holds.
        Tracing only — no compiles, so this is cheap enough to run at
        startup or in a deploy gate.
        """
        from repro.analysis import (check_dtype_drift,
                                    check_duplicate_donation,
                                    check_frozen_qparams,
                                    check_no_fake_quant,
                                    check_pallas_jaxpr)
        from repro.kernels import ops as _ops

        expect_interpret = _ops._interpret()
        cache = self.init_cache(batch, cache_len)
        toks = jnp.zeros((batch, prompt_len), jnp.int32)
        entries = {
            "prefill": (ST.make_prefill_step(self.model, self.cfg,
                                             self.policy, self.mode),
                        (self.serve_params, self.qparams,
                         {"tokens": toks}, cache)),
            "decode_loop": (ST.make_decode_loop(self.model, self.cfg,
                                                self.policy, self.mode,
                                                n_steps=4),
                            (self.serve_params, self.qparams,
                             jnp.zeros((batch,), jnp.int32), cache,
                             jnp.int32(prompt_len))),
        }
        findings = []
        for name, (fn, args) in entries.items():
            jx = jax.make_jaxpr(fn)(*args)
            findings += check_dtype_drift(jx, entry_point=name)
            findings += check_pallas_jaxpr(jx, entry_point=name,
                                           expect_interpret=expect_interpret)
            findings += check_no_fake_quant(jx, entry_point=name)
        findings += check_frozen_qparams(self.qparams,
                                         entry_point="qparams")
        findings += check_duplicate_donation(cache, entry_point="cache",
                                             what="donated KV cache")
        return findings

    def init_cache(self, batch: int, max_len: int, **kw):
        """Engine-configured cache: layout/page_size/kv_int8/kv_bits
        applied."""
        kw.setdefault("kv_int8", bool(self.policy.kv_int8))
        kw.setdefault("layout", self.cache_layout)
        kw.setdefault("page_size", self.page_size)
        kw.setdefault("kv_bits", int(self.policy.kv_bits))
        return self.model.init_cache(batch, max_len, self.cfg.dtype, **kw)

    def _cache_len(self, prompt_len: int, gen: int) -> int:
        """Single-stream cache sizing: padded prompt + generation budget,
        rounded for the fused decode kernel (128 tiles) and the paged
        layout (whole pages)."""
        cap = prompt_len
        if self.prefill_chunk:
            # the cache must hold the PADDED prompt: chunked prefill
            # writes whole chunks (garbage tails masked by lengths)
            cap = -(-prompt_len // self.prefill_chunk) * self.prefill_chunk
        max_len = cap + gen + (self.cfg.mm_patches
                               if self.cfg.modality == "vlm" else 0)
        if self.policy.use_pallas:
            max_len = -(-max_len // 128) * 128
        if self.cache_layout == "paged":
            max_len = -(-max_len // self.page_size) * self.page_size
        return max_len

    # -- single-stream batched serving ------------------------------------
    def generate_batch(self, batch: dict, gen: int, *,
                       prompt_len: Optional[int] = None,
                       loop: bool = False) -> GenerationResult:
        """Serve one fixed batch: prefill the prompts, then decode ``gen``
        tokens (the first comes from the prefill logits).  The decode
        default is the single-dispatch scanned loop; ``loop=True`` keeps
        the legacy per-token driver for comparison.  Executables are
        AOT-compiled (lower().compile()) so the reported timings are
        steady-state with no warm-up execution — and the cache buffer is
        donated to decode, so the (possibly huge) cache is never resident
        twice."""
        model, cfg, policy = self.model, self.cfg, self.policy
        mode = self.mode
        speculative = self.decode_strategy == "speculative"
        if speculative and loop:
            raise ValueError("the legacy per-token loop has no "
                             "speculative variant (drop loop=True)")
        tokens = batch["tokens"]
        requests, s = tokens.shape
        if prompt_len is None:
            prompt_len = s
        # a speculative verify window appends spec_k + 1 entries before
        # accepting — reserve draft headroom past the generation budget.
        # Speculation also needs absolute slots: map the ring default to
        # dense (a max_len-sized dense cache serves SWA layers correctly
        # through window masks — the same aliasing the scheduler does)
        max_len = self._cache_len(prompt_len,
                                  gen + (self.spec_k if speculative else 0))
        cache_kw = {}
        if speculative and self.cache_layout == "ring":
            cache_kw["layout"] = "dense"
        cache = self.init_cache(requests, max_len, **cache_kw)

        prefill = jax.jit(
            ST.make_prefill_step(model, cfg, policy, mode=mode,
                                 prefill_chunk=self.prefill_chunk),
            donate_argnums=(3,))
        if self.prefill_chunk:
            # pad prompts to a chunk multiple; the per-request length
            # vector masks the tail, so THIS executable serves any
            # prompt length
            toks, lengths = ST.pad_for_chunked_prefill(tokens,
                                                       self.prefill_chunk)
            prefill_args = (self.serve_params, self.qparams,
                            {**batch, "tokens": toks}, cache, lengths)
        else:
            prefill_args = (self.serve_params, self.qparams, batch, cache)

        prefill_x = prefill.lower(*prefill_args).compile()
        key = jax.random.PRNGKey(self.seed)
        t0 = time.time()
        logits, cache = prefill_x(*prefill_args)
        key, sub = jax.random.split(key)
        next_tok = ST.sample_tokens(logits[:, -1, :], sub,
                                    temperature=self.temperature,
                                    top_p=self.top_p)
        next_tok.block_until_ready()
        prefill_s = time.time() - t0

        pos0 = prompt_len + (cfg.mm_patches if cfg.modality == "vlm" else 0)
        if loop:
            decode = jax.jit(
                ST.make_serve_step(model, cfg, policy, mode=mode),
                donate_argnums=(3,))
            decode_x = decode.lower(self.serve_params, self.qparams,
                                    next_tok[:, None], cache, pos0).compile()
            t0 = time.time()
            toks_out = [next_tok]
            for i in range(gen - 1):
                nxt, lg, cache = decode_x(self.serve_params, self.qparams,
                                          toks_out[-1][:, None], cache,
                                          pos0 + i)
                if self.temperature > 0:
                    key, sub = jax.random.split(key)
                    nxt = ST.sample_tokens(lg[:, -1, :], sub,
                                           temperature=self.temperature,
                                           top_p=self.top_p)
                toks_out.append(nxt)
            out = jnp.stack(toks_out, axis=1)
        elif speculative:
            from repro.launch import strategies as SG

            strat = SG.make_strategy("speculative", model, cfg, policy,
                                     mode, spec_k=self.spec_k,
                                     spec_ngram=self.spec_ngram)
            decode_loop = jax.jit(
                SG.make_strategy_decode_loop(model, cfg, policy, strat,
                                             mode=mode, n_steps=gen),
                donate_argnums=(3,))
            # prompt-lookup history: absolute position -> token, seeded
            # with the prompt and the pending first generated token
            hist = jnp.zeros((requests, max_len), jnp.int32)
            hist = hist.at[:, :prompt_len].set(
                tokens[:, :prompt_len].astype(jnp.int32))
            hist = hist.at[:, prompt_len].set(next_tok)
            pos_v = jnp.full((requests,), pos0, jnp.int32)
            loop_x = decode_loop.lower(self.serve_params, self.qparams,
                                       next_tok, cache, pos_v, key,
                                       hist).compile()
            t0 = time.time()
            out, cache = loop_x(self.serve_params, self.qparams, next_tok,
                                cache, pos_v, key, hist)
        else:
            decode_loop = jax.jit(
                ST.make_decode_loop(model, cfg, policy, mode=mode,
                                    n_steps=gen,
                                    temperature=self.temperature,
                                    top_p=self.top_p),
                donate_argnums=(3,))
            loop_x = decode_loop.lower(self.serve_params, self.qparams,
                                       next_tok, cache, pos0, key).compile()
            t0 = time.time()
            out, cache = loop_x(self.serve_params, self.qparams, next_tok,
                                cache, pos0, key)
        out.block_until_ready()
        decode_s = time.time() - t0
        return GenerationResult(
            tokens=out, prefill_s=prefill_s, decode_s=decode_s,
            prompt_tokens=requests * prompt_len,
            gen_tokens=int(out.shape[0]) * int(out.shape[1]))

    # -- continuous batching -----------------------------------------------
    def make_scheduler(self, *, max_slots: int = 4, prompt_cap: int = 64,
                       gen_cap: int = 32, block_steps: int = 8,
                       eos_id: int = -1, prefix_pages: Optional[int] = None):
        """Build (or reuse) the slot scheduler for this engine's layout.
        The scheduler is cached per parameter set so repeated
        ``generate`` calls keep their compiled executables AND the paged
        layout's prefix store (shared pages persist across calls)."""
        from repro.launch.scheduler import SlotScheduler

        # the key covers every knob the scheduler bakes in — including
        # the engine-level ones — so mutating e.g. engine.prefill_chunk
        # after a generate() call rebuilds instead of serving stale config
        key = (max_slots, prompt_cap, gen_cap, block_steps, eos_id,
               prefix_pages, self.cache_layout, self.page_size,
               self.prefill_chunk, self.temperature, self.top_p, self.seed,
               self.decode_strategy, self.spec_k, self.spec_ngram,
               self.queue_cap, self.shed_policy, self.fault_plan,
               self.journal, self.snapshot_every, self.snapshot_dir)
        if self._scheduler is None or self._scheduler_key != key:
            layout = ("paged" if self.cache_layout == "paged" else "dense")
            self._scheduler = SlotScheduler(
                self.model, self.cfg, self.policy, self.serve_params,
                self.qparams, mode=self.mode, max_slots=max_slots,
                prompt_cap=prompt_cap, gen_cap=gen_cap,
                prefill_chunk=self.prefill_chunk, block_steps=block_steps,
                cache_layout=layout, page_size=self.page_size,
                prefix_pages=prefix_pages, temperature=self.temperature,
                top_p=self.top_p, eos_id=eos_id, seed=self.seed,
                strategy=self.decode_strategy, spec_k=self.spec_k,
                spec_ngram=self.spec_ngram, queue_cap=self.queue_cap,
                shed_policy=self.shed_policy, fault_plan=self.fault_plan,
                journal=self.journal, snapshot_every=self.snapshot_every,
                snapshot_dir=self.snapshot_dir)
            self._scheduler_key = key
        return self._scheduler

    def generate(self, requests: Iterable, *, max_slots: int = 4,
                 prompt_cap: Optional[int] = None,
                 gen_cap: Optional[int] = None, block_steps: int = 8,
                 eos_id: int = -1, max_blocks: Optional[int] = None):
        """Continuous batching: stream ``requests`` (launch.scheduler
        Request objects) through ``max_slots`` cache slots; returns
        Completions in finish order.  With the paged layout, repeated
        prompts admit through the prefix store with zero prefill FLOPs.
        ``prompt_cap``/``gen_cap`` default to the queue's longest prompt
        and largest budget."""
        reqs = list(requests)
        if prompt_cap is None:
            prompt_cap = max((len(r.tokens) for r in reqs), default=64)
        if gen_cap is None:
            gen_cap = max((r.max_gen for r in reqs), default=32)
        sched = self.make_scheduler(
            max_slots=max_slots, prompt_cap=prompt_cap, gen_cap=gen_cap,
            block_steps=block_steps, eos_id=eos_id)
        return sched.run(reqs, max_blocks=max_blocks)

    def health_report(self) -> dict:
        """Engine-level outcome aggregation for the continuous-batching
        path: the scheduler's ``health_stats()`` (terminal statuses,
        retirement causes, preemption/readmit/shed/deadline counters,
        and the durability counters ``recoveries``/``replayed_tokens``),
        accumulated across ``generate`` calls.  Empty before the first
        ``generate``."""
        if self._scheduler is None:
            return {}
        return self._scheduler.health_stats()

    # -- durability (see launch/journal.py and scheduler recovery) ---------
    def save_state(self) -> str:
        """Snapshot the live scheduler's full serving state through the
        ``snapshot_dir`` checkpoint manager; returns the checkpoint
        path.  Requires a scheduler (a prior ``generate``/
        ``make_scheduler``) built with ``snapshot_dir`` set."""
        if self._scheduler is None:
            raise ValueError(
                "no scheduler to snapshot — call generate()/"
                "make_scheduler() first")
        return self._scheduler.save_state()

    def load_state(self, **scheduler_kw) -> int:
        """Restore the newest snapshot into a scheduler built with
        ``scheduler_kw`` (the same ``make_scheduler`` knobs the crashed
        run used — knob mismatches raise).  Returns the restored
        decode-block counter; follow with
        ``make_scheduler(...).resume_run()`` (or just ``resume()``
        below) to drive the run to completion."""
        return self.make_scheduler(**scheduler_kw).load_state()

    def recover(self, *, max_blocks: Optional[int] = None,
                **scheduler_kw) -> list:
        """Journal-replay crash recovery end to end: build the scheduler
        (same knobs as the crashed run; the engine must have been
        constructed with the crashed run's ``journal`` path) and drive
        its :meth:`SlotScheduler.recover` to completion.  Returns ALL
        completions of the logical run."""
        return self.make_scheduler(**scheduler_kw).recover(
            max_blocks=max_blocks)

    def resume(self, *, max_blocks: Optional[int] = None,
               **scheduler_kw) -> list:
        """Snapshot-mode recovery end to end: ``load_state`` then drive
        the restored run to completion."""
        sched = self.make_scheduler(**scheduler_kw)
        sched.load_state()
        return sched.resume_run(max_blocks=max_blocks)

    # -- single prompt -----------------------------------------------------
    def generate_one(self, tokens, gen: int, **kw) -> GenerationResult:
        """Serve ONE prompt — by delegating to ``generate_batch`` at
        B == 1, never by re-deriving its own prefill/decode steps: the
        single-prompt path runs the exact executables the batched path
        runs (same strategy, same cache sizing, same AOT compile), so it
        cannot drift from it.  ``tokens`` is a 1-D prompt (list/array);
        returns the usual :class:`GenerationResult` with ``tokens`` of
        shape (1, gen)."""
        toks = jnp.asarray(tokens, jnp.int32)
        if toks.ndim != 1:
            raise ValueError(
                f"generate_one takes a single 1-D prompt, got shape "
                f"{toks.shape} (use generate_batch for batches)")
        return self.generate_batch({"tokens": toks[None, :]}, gen, **kw)
