"""Write-ahead request journal: crash recovery without device snapshots.

The durability insight is the paper's §2 again: thresholds are calibrated
once and FROZEN, so the int8 KV cache is a pure function of the token
sequence — the same determinism the preemption re-admit path exploits
(tests pin it bit-exact).  That means a crashed serving process never
needs the gigabytes of device state back: a tiny host-side journal of
*tokens* is enough to rebuild every in-flight request with one ragged
prefill (the scheduler's ``resume`` executable) and continue decoding
bit-identically to an uninterrupted run.

Record schema (one JSON object per line, append-only)::

    begin     {"t": "begin", "epoch": N, "recovered": bool, "knobs": {...}}
              starts an epoch; replay reads only the LAST epoch (recovery
              rewrites the surviving state as a fresh epoch, so the
              journal stays replayable across repeated crashes)
    enqueue   {"t": "enqueue", "req": {rid, tokens, max_gen, priority,
              deadline_ms, arrive_ms}, "hash": sha}
              a request became known to the scheduler; ``hash`` is the
              prompt digest, verified at replay (torn-write detection)
    progress  {"t": "progress", "rid": r, "out": [...], "key": [k0, k1],
              "steps": n}
              a resident's full host state: every token generated so far
              (including the pending one not yet in the cache), the
              carried per-request sampling key, and the absolute decode
              step count.  ABSOLUTE, not a delta — the newest record per
              rid alone rebuilds the request, which makes replay
              idempotent across crash/recover cycles
    retire    {"t": "retire", "c": {rid, prompt_len, tokens, finished_by,
              status, reason}}
              a terminal completion (authoritative — replay re-emits it)
    block     {"t": "block", "n": n_blocks, "vclock": v}
              a decode-block boundary committed; ``vclock`` is the run
              clock at the boundary (virtual ms under a fault plan's
              ``ms_per_block``, else wall ms since run start).  Written
              LAST at each boundary, so a simulated crash
              (FaultPlan.crash) always leaves the boundary's
              retire/progress records durable

Write order at a boundary is retire -> progress -> block; every write is
flushed, so the only loss mode is a torn trailing line, which ``replay``
tolerates (any earlier corruption is a real error and raises).

Replay classifies the last epoch's requests: ``done`` (retired),
``inflight`` (progressed, not retired — resident or parked at the crash;
both rebuild identically through the resume prefill), and ``queued``
(enqueued, never admitted).  The scheduler turns that into a live run
state and drives it to completion (``SlotScheduler.recover``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

JOURNAL_VERSION = 1


def prompt_hash(tokens) -> str:
    """Digest of a prompt token sequence (journal integrity check)."""
    raw = ",".join(str(int(t)) for t in tokens).encode()
    return hashlib.sha256(raw).hexdigest()[:16]


def request_to_dict(req) -> dict:
    return {
        "rid": int(req.rid),
        "tokens": [int(t) for t in req.tokens],
        "max_gen": int(req.max_gen),
        "priority": int(req.priority),
        "deadline_ms": (None if req.deadline_ms is None
                        else float(req.deadline_ms)),
        "arrive_ms": float(req.arrive_ms),
    }


def request_from_dict(d: dict):
    import numpy as np

    from repro.launch.scheduler import Request

    return Request(
        rid=int(d["rid"]),
        tokens=np.asarray(d["tokens"], np.int32),
        max_gen=int(d["max_gen"]), priority=int(d["priority"]),
        deadline_ms=d["deadline_ms"], arrive_ms=float(d["arrive_ms"]))


def completion_to_dict(c) -> dict:
    return {"rid": int(c.rid), "prompt_len": int(c.prompt_len),
            "tokens": [int(t) for t in c.tokens],
            "finished_by": c.finished_by, "status": c.status,
            "reason": c.reason}


def completion_from_dict(d: dict):
    from repro.launch.scheduler import Completion

    return Completion(
        rid=int(d["rid"]), prompt_len=int(d["prompt_len"]),
        tokens=[int(t) for t in d["tokens"]],
        finished_by=d["finished_by"], status=d["status"],
        reason=d.get("reason"))


@dataclasses.dataclass
class JournalReplay:
    """The last epoch's surviving state (see module docstring)."""
    epoch: int
    recovered: bool             # the epoch itself was written by a recovery
    knobs: dict                 # scheduler knobs recorded at begin()
    done: list                  # retire payload dicts, in retirement order
    inflight: list              # {"req": dict, "out": [...], "key": [...],
    #                              "steps": n} — newest progress per rid,
    #                              ordered by last journal appearance
    queued: list                # request dicts, enqueue order
    n_blocks: int               # last committed decode-block boundary
    vclock: float               # run clock (virtual or wall ms) there


class RequestJournal:
    """Append-only JSONL write-ahead journal (one file per serving run).

    Writes are line-granular and flushed immediately: the journal is the
    durability root, so a record either fully lands or is a torn trailing
    line that replay drops.  ``close()`` (or context exit) releases the
    file handle; appending reopens lazily.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._f = None

    # -- writes ------------------------------------------------------------
    def _append(self, rec: dict):
        if self._f is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "a")
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def begin(self, epoch: int, knobs: dict, *, recovered: bool = False):
        self._append({"t": "begin", "v": JOURNAL_VERSION,
                      "epoch": int(epoch), "recovered": bool(recovered),
                      "knobs": knobs})

    def enqueue(self, req):
        d = request_to_dict(req)
        self._append({"t": "enqueue", "req": d,
                      "hash": prompt_hash(d["tokens"])})

    def progress(self, rid: int, out, key, steps: int):
        self._append({"t": "progress", "rid": int(rid),
                      "out": [int(t) for t in out],
                      "key": [int(k) for k in key], "steps": int(steps)})

    def retire(self, completion):
        self._append({"t": "retire", "c": completion_to_dict(completion)})

    def block(self, n_blocks: int, vclock: float):
        self._append({"t": "block", "n": int(n_blocks),
                      "vclock": float(vclock)})

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- replay ------------------------------------------------------------
    def last_epoch(self) -> int:
        """Newest epoch number in the journal (0 if none/absent)."""
        try:
            return self.replay().epoch
        except FileNotFoundError:
            return 0

    def replay(self) -> JournalReplay:
        """Parse the journal and rebuild the last epoch's state.  A torn
        TRAILING line (the only loss mode flushed line writes allow) is
        dropped; corruption anywhere else raises ``ValueError``."""
        with open(self.path) as f:
            lines = f.read().splitlines()
        while lines and not lines[-1].strip():
            lines.pop()
        records = []
        for i, line in enumerate(lines):
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break   # torn trailing write: the WAL's tolerated loss
                raise ValueError(
                    f"{self.path}: corrupt journal record at line {i + 1} "
                    "(only the trailing line may be torn)")
        # keep only the newest epoch
        starts = [i for i, r in enumerate(records) if r.get("t") == "begin"]
        if not starts:
            raise ValueError(
                f"{self.path}: no begin record — not a request journal "
                "(or the first write was torn)")
        begin = records[starts[-1]]
        epoch_recs = records[starts[-1] + 1:]

        enq: dict = {}
        enq_order: list = []
        prog: dict = {}
        prog_order: list = []
        done: list = []
        retired: set = set()
        n_blocks, vclock = 0, 0.0
        for r in epoch_recs:
            t = r.get("t")
            if t == "enqueue":
                d = r["req"]
                if r.get("hash") != prompt_hash(d["tokens"]):
                    raise ValueError(
                        f"{self.path}: prompt hash mismatch for rid "
                        f"{d['rid']} (journal corruption)")
                enq[d["rid"]] = d
                enq_order.append(d["rid"])
            elif t == "progress":
                rid = r["rid"]
                prog[rid] = r
                if rid in prog_order:
                    prog_order.remove(rid)
                prog_order.append(rid)
            elif t == "retire":
                done.append(r["c"])
                retired.add(r["c"]["rid"])
            elif t == "block":
                n_blocks, vclock = int(r["n"]), float(r["vclock"])
            elif t == "begin":      # unreachable (sliced off) — be safe
                raise AssertionError("begin inside epoch slice")
        inflight = []
        for rid in prog_order:
            if rid in retired:
                continue
            if rid not in enq:
                raise ValueError(
                    f"{self.path}: progress for rid {rid} without an "
                    "enqueue record")
            p = prog[rid]
            inflight.append({"req": enq[rid], "out": p["out"],
                             "key": p["key"], "steps": p["steps"]})
        queued = [enq[rid] for rid in enq_order
                  if rid not in retired and rid not in prog]
        return JournalReplay(
            epoch=int(begin["epoch"]), recovered=bool(begin["recovered"]),
            knobs=dict(begin.get("knobs") or {}), done=done,
            inflight=inflight, queued=queued, n_blocks=n_blocks,
            vclock=vclock)
