"""Serving driver: int8 FAT-quantized model, batched requests.

Pipeline: calibrate -> (optional FAT fine-tune) -> convert_to_int8 ->
prefill each request batch -> decode N tokens.  The whole resident
state is int8: weights (the paper's "ready to run on mobile phones"
artifact, here TPU-shaped) AND the KV cache (per-head static thresholds
from the same §2 calibration pass, frozen at finalize_calibration) — so
BOTH attention phases stream half the HBM bytes and nothing is computed
"on the fly".

The engine is two fused Pallas kernels over the same int8 cache
(``--pallas``): flash-prefill (kernels/prefill_attention.py — the prompt's
K/V quantize once and are attended AND appended as the same tiles) and
flash-decode (kernels/decode_attention.py).  The decode loop is a single
compiled ``jax.lax.scan`` over the generation (steps.make_decode_loop): N
tokens cost one dispatch instead of N, with (token, cache, position, PRNG
key) carried as scan state.  ``--loop`` keeps the legacy per-token Python
loop for comparison (benchmarks/serve_bench.py tracks the ratio).

``--prefill-chunk N`` switches prefill to the chunked ragged pipeline:
one lax.scan over fixed-size prompt chunks plus a per-request length
vector, so a single compiled executable serves any prompt length up to
the pad (no per-shape retrace).  ``--temperature`` / ``--top-p`` turn on
sampled decoding (greedy by default).

``--max-slots N`` switches the whole run to CONTINUOUS BATCHING
(launch/scheduler.py): the batch becomes N slots over one fixed-shape
int8 cache, requests stream in from a queue with ragged prompt lengths,
each admission runs the chunked prefill into a free slot's cache region,
and decode blocks advance every live slot at its own position — one
compiled decode executable for every admission pattern.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 4 --prompt-len 32 --gen 16
  Flags: --fp (bf16 weights baseline)  --no-kv-int8 (bf16 KV cache)
         --loop (per-token dispatch instead of the scanned loop)
         --pallas (fused kernels; defaults on for TPU backends)
         --prefill-chunk N (chunked ragged prefill)
         --temperature T --top-p P --seed S (sampled decoding)
         --max-slots N (continuous-batching scheduler)
         --block-steps N --eos-id T (scheduler decode-block / EOS knobs)
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model


def prepare_int8(model, cfg, policy, params, calib_batches, *,
                 convert: bool = True):
    """Calibration + int8 conversion (the paper's deployment pipeline).

    ``convert=False`` stops after calibration (bf16-weight ablations need
    the thresholds but not a second, immediately-discarded param pytree).
    """
    qparams = A.init_qparams(model, params, policy)
    calib = jax.jit(ST.make_calibrate_step(model, cfg, policy))
    for b in calib_batches:
        qparams = calib(params, qparams, b)
    qparams = A.finalize_calibration(qparams, policy)
    serve_params = (A.convert_to_int8(model, params, qparams, policy)
                    if convert else params)
    return serve_params, qparams


def ragged_requests(spec, n_requests, prompt_len, gen, *, seed=12345):
    """Build a ragged request queue from the data pipeline: request r's
    prompt keeps between half and all of ``prompt_len`` tokens (a
    deterministic mixed-length arrival pattern)."""
    from repro.launch.scheduler import Request

    batch = DP.make_batch(
        dataclasses.replace(spec, global_batch=n_requests), seed)
    toks = jax.device_get(batch["tokens"])[:, :prompt_len]
    reqs = []
    for r in range(n_requests):
        frac = (r % 4) / 6.0               # lengths cycle 1, 5/6, 2/3, 1/2
        length = max(1, prompt_len - int(frac * prompt_len))
        reqs.append(Request(rid=r, tokens=toks[r, :length].astype("int32"),
                            max_gen=gen))
    return reqs


def run_continuous(args, model, cfg, policy, serve_params, qparams, mode):
    """--max-slots path: stream --requests ragged requests through the
    slot scheduler and report aggregate throughput."""
    from repro.launch.scheduler import SlotScheduler

    sched = SlotScheduler(
        model, cfg, policy, serve_params, qparams, mode=mode,
        max_slots=args.max_slots, prompt_cap=args.prompt_len,
        gen_cap=args.gen, prefill_chunk=args.prefill_chunk,
        block_steps=args.block_steps, temperature=args.temperature,
        top_p=args.top_p, eos_id=args.eos_id, seed=args.seed)

    shape = ShapeSpec("cli", "train", args.prompt_len, args.requests)
    spec = DP.spec_for(cfg, shape)
    reqs = ragged_requests(spec, args.requests, args.prompt_len, args.gen)
    t0 = time.time()
    completions = sched.run(reqs)
    wall = time.time() - t0
    n_new = sum(len(c.tokens) for c in completions)
    n_prompt = sum(c.prompt_len for c in completions)
    print(f"[serve] continuous batching: {len(completions)} requests "
          f"through {args.max_slots} slots (block={args.block_steps}) | "
          f"prompt lens {sorted({c.prompt_len for c in completions})} | "
          f"{n_new} tokens in {wall*1e3:.1f} ms "
          f"({n_new/max(wall,1e-9):.0f} gen tok/s, "
          f"{(n_new+n_prompt)/max(wall,1e-9):.0f} total tok/s)")
    counts = sched.executable_counts()
    print(f"[serve] executables: prefill={counts['prefill']} "
          f"decode={counts['decode']} insert={counts['insert']} "
          "(1 each == no retrace across the whole ragged run)")
    for c in completions[:2]:
        print(f"  req{c.rid}: prompt_len={c.prompt_len} "
              f"finished_by={c.finished_by} -> {c.tokens}")
    return completions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--fp", action="store_true",
                    help="serve in bf16 instead of int8 (baseline)")
    ap.add_argument("--no-kv-int8", action="store_true",
                    help="keep the KV cache bf16 (kv ablation)")
    ap.add_argument("--loop", action="store_true",
                    help="legacy per-token Python loop (vs lax.scan)")
    ap.add_argument("--pallas", action="store_true", default=None,
                    help="fused Pallas kernels (prefill + decode attention, "
                         "int8 matmul); default: on for TPU backends, off "
                         "on CPU where interpret mode is emulation-slow")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked ragged prefill: scan fixed-size prompt "
                         "chunks with a per-request length vector (one "
                         "executable for every prompt length)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for sampled decoding")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="continuous batching: serve --requests ragged "
                         "requests through N cache slots with streaming "
                         "admission (launch/scheduler.py)")
    ap.add_argument("--block-steps", type=int, default=8,
                    help="scheduler decode-block length (admission happens "
                         "at block boundaries)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id for the scheduler (< 0 disables; a "
                         "slot stops generating when it emits this)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    kv_int8 = not args.no_kv_int8
    use_pallas = (jax.default_backend() == "tpu" if args.pallas is None
                  else args.pallas)
    policy = A.QuantPolicy(kv_int8=kv_int8, use_pallas=use_pallas)
    params = model.init(jax.random.PRNGKey(0))

    shape = ShapeSpec("cli", "train", args.prompt_len, args.requests)
    spec = DP.spec_for(cfg, shape)
    calib = DP.calibration_batches(spec, 2)
    for b in calib:
        b.pop("labels", None)

    mode = "none" if args.fp else "int8"
    if args.fp and not kv_int8:
        serve_params, qparams = params, A.finalize_calibration(
            A.init_qparams(model, params, policy), policy)
    else:
        # int8 weights and/or int8 KV both need the calibration pass;
        # bf16-weight ablations skip the weight conversion
        serve_params, qparams = prepare_int8(model, cfg, policy, params,
                                             calib, convert=not args.fp)
        if not args.fp:
            n_int8 = sum(1 for l in jax.tree.leaves(serve_params)
                         if l.dtype == jnp.int8)
            print(f"[serve] converted: {n_int8} int8 weight tensors resident")

    if args.max_slots:
        return run_continuous(args, model, cfg, policy, serve_params,
                              qparams, mode)

    # cache (arg 3) is donated: the decode carry reuses the input buffer
    # instead of keeping two copies of the (possibly huge) cache resident
    prefill = jax.jit(ST.make_prefill_step(model, cfg, policy, mode=mode,
                                           prefill_chunk=args.prefill_chunk),
                      donate_argnums=(3,))

    # batched requests from the pipeline (prompt = first prompt_len tokens)
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)
    prompt_cap = args.prompt_len
    if args.prefill_chunk:
        # the cache must hold the PADDED prompt: chunked prefill writes
        # whole chunks (garbage tail slots are masked by the length vector)
        prompt_cap = -(-args.prompt_len // args.prefill_chunk
                       ) * args.prefill_chunk
    max_len = prompt_cap + args.gen + (
        cfg.mm_patches if cfg.modality == "vlm" else 0)
    if use_pallas:
        # tile the cache length for the fused decode kernel — a non-tiling
        # length forces it to pad-copy the cache every step
        max_len = -(-max_len // 128) * 128
    cache = model.init_cache(args.requests, max_len, cfg.dtype,
                             kv_int8=kv_int8)
    if kv_int8:
        n_kv8 = sum(1 for l in jax.tree.leaves(cache)
                    if l.dtype == jnp.int8)
        print(f"[serve] kv cache: {n_kv8} int8 KV tensors resident")

    if args.prefill_chunk:
        # pad prompts to a chunk multiple; the per-request length vector
        # masks the tail, so THIS executable serves any prompt length
        batch["tokens"], lengths = ST.pad_for_chunked_prefill(
            batch["tokens"], args.prefill_chunk)
        prefill_args = (serve_params, qparams, batch, cache, lengths)
    else:
        prefill_args = (serve_params, qparams, batch, cache)

    # AOT-compile (lower().compile()) and time the resulting executables:
    # steady-state numbers with no warm-up execution — lowering never runs
    # the computation or consumes donated buffers, so the cache is not
    # copied or doubled during startup
    prefill_x = prefill.lower(*prefill_args).compile()
    t0 = time.time()
    logits, cache = prefill_x(*prefill_args)
    key = jax.random.PRNGKey(args.seed)
    key, sub = jax.random.split(key)
    next_tok = ST.sample_tokens(logits[:, -1, :], sub,
                                temperature=args.temperature,
                                top_p=args.top_p)
    next_tok.block_until_ready()
    prefill_s = time.time() - t0

    pos0 = args.prompt_len + (cfg.mm_patches if cfg.modality == "vlm" else 0)
    if args.loop:
        decode = jax.jit(ST.make_serve_step(model, cfg, policy, mode=mode),
                         donate_argnums=(3,))
        decode_x = decode.lower(serve_params, qparams, next_tok[:, None],
                                cache, pos0).compile()
        t0 = time.time()
        toks = [next_tok]
        for i in range(args.gen - 1):
            nxt, logits, cache = decode_x(
                serve_params, qparams, toks[-1][:, None], cache, pos0 + i)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = ST.sample_tokens(logits[:, -1, :], sub,
                                       temperature=args.temperature,
                                       top_p=args.top_p)
            toks.append(nxt)
        out = jnp.stack(toks, axis=1)
    else:
        decode_loop = jax.jit(
            ST.make_decode_loop(model, cfg, policy, mode=mode,
                                n_steps=args.gen,
                                temperature=args.temperature,
                                top_p=args.top_p),
            donate_argnums=(3,))
        loop_x = decode_loop.lower(serve_params, qparams, next_tok, cache,
                                   pos0, key).compile()
        t0 = time.time()
        out, cache = loop_x(serve_params, qparams, next_tok, cache, pos0, key)
    out.block_until_ready()
    decode_s = time.time() - t0
    kind = "loop" if args.loop else "scan"
    pf_kind = (f"chunked/{args.prefill_chunk}" if args.prefill_chunk
               else "one-shot")
    pf_tps = args.requests * args.prompt_len / max(prefill_s, 1e-9)
    print(f"[serve] {args.requests} requests | prefill ({pf_kind}) "
          f"{prefill_s*1e3:.1f} ms ({pf_tps:.0f} tok/s) "
          f"| {args.gen} tokens ({kind}) in {decode_s*1e3:.1f} ms "
          f"({decode_s/max(args.gen-1,1)*1e3:.1f} ms/tok)")
    for r in range(min(args.requests, 2)):
        print(f"  req{r}: prompt={batch['tokens'][r, :8].tolist()}... "
              f"-> generated={out[r].tolist()}")
    return out


if __name__ == "__main__":
    main()
