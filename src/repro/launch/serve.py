"""Serving CLI: int8 FAT-quantized model, batched requests, one Engine.

All assembly (calibrate -> convert_to_int8 -> step functions -> cache
layout) lives in ``launch/engine.py::Engine``; this module only parses
flags, builds requests, runs the engine, and prints.  The whole resident
state is int8: weights (the paper's "ready to run on mobile phones"
artifact, here TPU-shaped) AND the KV cache (per-head static thresholds
from the same §2 calibration pass, frozen at finalize_calibration) — so
BOTH attention phases stream half the HBM bytes and nothing is computed
"on the fly".

The engine is two fused Pallas kernels over the same int8 KV cache
(``--pallas``): flash-prefill and flash-decode, both reading KV tiles
through the cache's kernel view — an identity block table for the
dense/ring layouts, the page table for ``--cache-layout paged``.  The
decode loop is a single compiled ``jax.lax.scan`` over the generation
(steps.make_decode_loop): N tokens cost one dispatch instead of N.
``--loop`` keeps the legacy per-token Python loop for comparison
(benchmarks/serve_bench.py tracks the ratio).

``--prefill-chunk N`` switches prefill to the chunked ragged pipeline:
one lax.scan over fixed-size prompt chunks plus a per-request length
vector, so a single compiled executable serves any prompt length up to
the pad (no per-shape retrace).  ``--temperature`` / ``--top-p`` turn on
sampled decoding (greedy by default).

``--max-slots N`` switches the whole run to CONTINUOUS BATCHING
(launch/scheduler.py): the batch becomes N slots over one fixed-shape
int8 cache, requests stream in from a queue with ragged prompt lengths,
each admission runs the chunked prefill into a free slot's cache region,
and decode blocks advance every live slot at its own position — one
compiled decode executable for every admission pattern.

``--cache-layout {dense,ring,paged}`` picks the KV-cache layout behind
the ``repro.cache.KVCache`` protocol: ``ring`` (default) gives sliding-
window layers a window-sized ring buffer and everything else a dense
cache; ``dense`` forces absolute slots everywhere; ``paged`` switches to
a page pool + per-slot block tables (``--page-size``), which with
``--max-slots`` turns on prompt prefix sharing — a repeated prompt
admits with zero prefill FLOPs through the scheduler's prefix store.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 4 --prompt-len 32 --gen 16
  Flags: --fp (bf16 weights baseline)  --no-kv-int8 (bf16 KV cache)
         --kv-bits {8,4} (int4 packed KV cache at 4)
         --finetune-thresholds N (train KV thresholds by distillation)
         --loop (per-token dispatch instead of the scanned loop)
         --pallas (fused kernels; defaults on for TPU backends)
         --prefill-chunk N (chunked ragged prefill)
         --temperature T --top-p P --seed S (sampled decoding)
         --strategy {greedy,sample,speculative} (decode strategy; the
             DecodeStrategy protocol in launch/strategies.py —
             speculative = prompt-lookup drafting + one batched verify
             pass per window, bit-identical tokens to greedy)
         --spec-k N --spec-ngram N (speculative draft window / lookup
             n-gram; static, so no retrace across draft contents)
         --max-slots N (continuous-batching scheduler)
         --block-steps N --eos-id T (scheduler decode-block / EOS knobs)
         --cache-layout {dense,ring,paged} --page-size N (KV layout)
         --deadline-ms MS (per-request completion deadline; missed
             requests retire with status 'timeout' at block boundaries)
         --queue-cap N --shed-policy {shed,block} (bounded admission
             queue + overload behavior — graceful degradation)
         --fault-plan SPEC (inline JSON or a path to a JSON file; a
             launch/faults.py FaultPlan injecting deterministic faults —
             admission failures, NaN logits, forced preemptions, forced
             prefix-pool exhaustion, simulated crashes, virtual clock)
         --journal PATH (write-ahead request journal; a crashed run
             restarts with --restore journal and completes bit-identical
             — docs/serving.md "Durability & recovery")
         --snapshot-every N --snapshot-dir DIR (full state snapshot
             through the checkpoint manager every N decode blocks)
         --restore {journal,snapshot} (recover a crashed run instead of
             serving fresh requests)
         --strict (exit non-zero when any request retires non-'ok' —
             lets chaos CI and scripts gate on degraded runs)
         --ckpt-dir DIR (restore trained params instead of random init)
         --tp N / --sp N (sharded serving over N devices: tensor-
             parallel projections with int32 psum epilogues / sequence-
             parallel KV with exact partial-softmax merge — mutually
             exclusive, both token-identical to the unsharded engine;
             docs/serving.md "Sharded serving")
         --mesh {auto,dryrun} (auto = build the serving mesh and serve;
             dryrun = compile the sharded executables, print the HLO
             collective audit — every serving-path all-reduce must carry
             integer payload bytes — and exit, 1 if the audit fails)

Every request retires with a terminal ``Completion.status`` (ok |
rejected | timeout | preempted | shed | failed — docs/serving.md
"Failure semantics"); the run prints the scheduler's health report
(per-status counts, preemptions, re-admits, deadline misses, recovery
counters).  A simulated crash (fault plan ``crash``) exits with code 3
after its journal/snapshot state is durable; ``--strict`` failures exit
with code 1.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.data import pipeline as DP
# re-export: the deployment pipeline lives with the Engine now
from repro.launch.engine import Engine, prepare_int8  # noqa: F401


def ragged_requests(spec, n_requests, prompt_len, gen, *, seed=12345,
                    deadline_ms=None):
    """Build a ragged request queue from the data pipeline: request r's
    prompt keeps between half and all of ``prompt_len`` tokens (a
    deterministic mixed-length arrival pattern).  ``deadline_ms`` applies
    one completion deadline to every request (None = none)."""
    from repro.launch.scheduler import Request

    batch = DP.make_batch(
        dataclasses.replace(spec, global_batch=n_requests), seed)
    toks = jax.device_get(batch["tokens"])[:, :prompt_len]
    reqs = []
    for r in range(n_requests):
        frac = (r % 4) / 6.0               # lengths cycle 1, 5/6, 2/3, 1/2
        length = max(1, prompt_len - int(frac * prompt_len))
        reqs.append(Request(rid=r, tokens=toks[r, :length].astype("int32"),
                            max_gen=gen, deadline_ms=deadline_ms))
    return reqs


def run_continuous(args, engine: Engine):
    """--max-slots path: stream --requests ragged requests through the
    slot scheduler (or, with --restore, pick a crashed run back up from
    its journal/snapshot) and report aggregate throughput."""
    from repro.launch.faults import SimulatedCrash

    sched_kw = dict(max_slots=args.max_slots, prompt_cap=args.prompt_len,
                    gen_cap=args.gen, block_steps=args.block_steps,
                    eos_id=args.eos_id)
    t0 = time.time()
    try:
        if args.restore == "journal":
            completions = engine.recover(**sched_kw)
        elif args.restore == "snapshot":
            completions = engine.resume(**sched_kw)
        else:
            spec = DP.spec_for(
                engine.cfg, ShapeSpec("cli", "train", args.prompt_len,
                                      args.requests))
            reqs = ragged_requests(spec, args.requests, args.prompt_len,
                                   args.gen, deadline_ms=args.deadline_ms)
            completions = engine.generate(reqs, **sched_kw)
    except SimulatedCrash as e:
        # the boundary's journal records / snapshot landed BEFORE the
        # crash fired — the run is recoverable by a fresh process
        print(f"[serve] {e}")
        print("[serve] state is durable — restart with --restore journal "
              "(+ --journal PATH) or --restore snapshot "
              "(+ --snapshot-dir DIR) to finish the run bit-identically")
        raise SystemExit(3)
    wall = time.time() - t0
    sched = engine.make_scheduler(**sched_kw)
    n_new = sum(len(c.tokens) for c in completions)
    n_prompt = sum(c.prompt_len for c in completions)
    print(f"[serve] continuous batching ({sched.cache_layout}): "
          f"{len(completions)} requests "
          f"through {args.max_slots} slots (block={args.block_steps}) | "
          f"prompt lens {sorted({c.prompt_len for c in completions})} | "
          f"{n_new} tokens in {wall*1e3:.1f} ms "
          f"({n_new/max(wall,1e-9):.0f} gen tok/s, "
          f"{(n_new+n_prompt)/max(wall,1e-9):.0f} total tok/s)")
    counts = sched.executable_counts()
    print(f"[serve] executables: " +
          " ".join(f"{k}={v}" for k, v in counts.items()) +
          " (1 each == no retrace across the whole ragged run)")
    by_status: dict = {}
    for c in completions:
        by_status[c.status] = by_status.get(c.status, 0) + 1
    health = engine.health_report()
    print("[serve] statuses: " +
          " ".join(f"{k}={v}" for k, v in sorted(by_status.items())))
    print("[serve] health: " +
          " ".join(f"{k}={v}" for k, v in health.items() if v))
    if args.restore:
        print(f"[serve] recovered via {args.restore}: "
              f"recoveries={health.get('recoveries', 0)} "
              f"replayed_tokens={health.get('replayed_tokens', 0)}")
    if sched.cache_layout == "paged":
        stats = sched.prefix_stats()
        print(f"[serve] prefix store: {stats['hits']} hits / "
              f"{stats['misses']} misses | {stats['shared_tokens']} prompt "
              f"tokens served from shared pages (zero prefill FLOPs)")
    spec = sched.spec_stats()
    if spec:
        print(f"[serve] speculative: {spec['emitted_tokens']} tokens over "
              f"{spec['verify_windows']} verify windows "
              f"({spec['tokens_per_window']:.2f} tok/window, "
              f"draft acceptance {spec['acceptance_rate']:.2f})")
    for c in completions[:2]:
        print(f"  req{c.rid}: prompt_len={c.prompt_len} "
              f"finished_by={c.finished_by} -> {c.tokens}")
    if args.strict:
        bad = sorted({c.status for c in completions if c.status != "ok"})
        if bad:
            print(f"[serve] --strict: non-ok terminal statuses {bad}")
            raise SystemExit(1)
    return completions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    help="architecture preset to serve")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--requests", type=int, default=4,
                    help="number of synthetic requests to serve")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="tokens per synthetic prompt")
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--fp", action="store_true",
                    help="serve in bf16 instead of int8 (baseline)")
    ap.add_argument("--no-kv-int8", action="store_true",
                    help="keep the KV cache bf16 (kv ablation)")
    ap.add_argument("--kv-bits", type=int, default=8, choices=[8, 4],
                    help="quantized KV cache width: 8 (int8) or 4 (packed "
                         "int4 nibbles — quarter of the bf16 cache bytes)")
    ap.add_argument("--finetune-thresholds", type=int, default=0,
                    help="train the KV quantization thresholds by "
                         "distillation for N epochs (<= 8) before freezing "
                         "them (paper §3); 0 = static §2 calibration only")
    ap.add_argument("--loop", action="store_true",
                    help="legacy per-token Python loop (vs lax.scan)")
    ap.add_argument("--pallas", action="store_true", default=None,
                    help="fused Pallas kernels (prefill + decode attention, "
                         "int8 matmul); default: on for TPU backends, off "
                         "on CPU where interpret mode is emulation-slow")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked ragged prefill: scan fixed-size prompt "
                         "chunks with a per-request length vector (one "
                         "executable for every prompt length)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for sampled decoding")
    ap.add_argument("--strategy", default=None,
                    choices=["greedy", "sample", "speculative"],
                    help="decode strategy (launch/strategies.py); default "
                         "auto-picks sample when --temperature > 0, else "
                         "greedy.  speculative drafts --spec-k tokens by "
                         "prompt-lookup n-gram matching and verifies them "
                         "in ONE batched pass over the int8 cache — "
                         "bit-identical tokens to greedy, fewer decode "
                         "dispatches when the text repeats")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative draft-window length (tokens drafted "
                         "per verify pass)")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="prompt-lookup n-gram size for speculative "
                         "drafting")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="continuous batching: serve --requests ragged "
                         "requests through N cache slots with streaming "
                         "admission (launch/scheduler.py)")
    ap.add_argument("--block-steps", type=int, default=8,
                    help="scheduler decode-block length (admission happens "
                         "at block boundaries)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id for the scheduler (< 0 disables; a "
                         "slot stops generating when it emits this)")
    ap.add_argument("--cache-layout", default="ring",
                    choices=["dense", "ring", "paged"],
                    help="KV-cache layout (repro.cache): ring = SWA layers "
                         "ring-buffered, rest dense (default); dense = "
                         "absolute slots everywhere; paged = page pool + "
                         "block tables (enables prompt prefix sharing "
                         "under --max-slots)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per page for --cache-layout paged")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request completion deadline in ms (scheduler "
                         "path): requests that miss it retire with status "
                         "'timeout' at the next block boundary")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue for the scheduler: at "
                         "most N requests waiting (default: unbounded)")
    ap.add_argument("--shed-policy", default="shed",
                    choices=["shed", "block"],
                    help="what a full admission queue does with new "
                         "arrivals: shed = retire them immediately with "
                         "status 'shed'; block = hold them out until the "
                         "queue drains")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection (launch/faults.py): "
                         "inline JSON or a path to a JSON file, e.g. "
                         "'{\"reject\": [2], \"nan_decode\": [[3, 1]]}'")
    ap.add_argument("--journal", default=None,
                    help="write-ahead request journal path (scheduler "
                         "path): every admission / block boundary / "
                         "retirement is journaled, so a crashed run can "
                         "restart with --restore journal and finish "
                         "bit-identically (launch/journal.py)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="write a full serving-state snapshot every N "
                         "decode-block boundaries through the checkpoint "
                         "manager (0 = off; needs --snapshot-dir)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="checkpoint directory for serving-state "
                         "snapshots (enables --restore snapshot)")
    ap.add_argument("--restore", default=None,
                    choices=["journal", "snapshot"],
                    help="recover a crashed run instead of serving fresh "
                         "requests: journal = replay the --journal file "
                         "and rebuild in-flight state via the resume "
                         "prefill; snapshot = restore the newest "
                         "--snapshot-dir checkpoint and continue decoding")
    ap.add_argument("--strict", action="store_true",
                    help="exit with code 1 if any request retires with a "
                         "non-'ok' terminal status (CI gating)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params from a launch/train.py "
                         "checkpoint directory (default: random init)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shard count: projections split "
                         "across N devices, row epilogues all-reduce int32 "
                         "accumulators (requires int8 mode; token-identical "
                         "to --tp 1)")
    ap.add_argument("--sp", type=int, default=1,
                    help="sequence-parallel shard count: the KV cache's "
                         "sequence axis splits across N devices, decode "
                         "merges per-shard flash partials exactly (dense/"
                         "ring cache layouts; token-identical to --sp 1)")
    ap.add_argument("--mesh", default="auto", choices=["auto", "dryrun"],
                    help="sharded-serving mesh mode: auto = build "
                         "make_serving_mesh(max(tp, sp)) and serve; dryrun "
                         "= compile the sharded prefill/decode executables, "
                         "print the HLO collective audit (int8-on-the-wire "
                         "assertion) and exit without serving")
    args = ap.parse_args()
    if (args.tp > 1 or args.sp > 1) and args.fp:
        ap.error("--tp/--sp shard the int8 engine (--fp has no integer "
                 "accumulators to reduce exactly)")
    if args.mesh == "dryrun" and args.tp <= 1 and args.sp <= 1:
        ap.error("--mesh dryrun audits the sharded executables — give it "
                 "--tp N or --sp N")
    if (args.journal or args.snapshot_dir or args.restore
            or args.strict) and not args.max_slots:
        ap.error("--journal/--snapshot-dir/--restore/--strict need "
                 "--max-slots (the continuous-batching scheduler)")
    if args.restore == "journal" and not args.journal:
        ap.error("--restore journal needs --journal PATH")
    if args.restore == "snapshot" and not args.snapshot_dir:
        ap.error("--restore snapshot needs --snapshot-dir DIR")

    fault_plan = args.fault_plan
    if fault_plan is not None:
        from repro.launch.faults import FaultPlan
        fault_plan = FaultPlan.parse(fault_plan)
        if args.restore == "snapshot" and fault_plan.crash:
            # a snapshot may predate the crash boundary, so the restored
            # run would reach it and crash again on every restart;
            # journal replay resumes AT the boundary, so its plan keeps
            # later crash points live for multi-crash chains
            fault_plan = dataclasses.replace(fault_plan, crash=())

    use_pallas = (jax.default_backend() == "tpu" if args.pallas is None
                  else args.pallas)
    sharded = args.tp > 1 or args.sp > 1
    engine_cls = Engine
    shard_kw = {}
    if sharded:
        from repro.shard.engine import ShardedEngine

        engine_cls = ShardedEngine
        shard_kw = dict(tp=args.tp, sp=args.sp)
    engine = engine_cls.from_checkpoint(
        args.arch, checkpoint_dir=args.ckpt_dir, smoke=args.smoke,
        **shard_kw,
        fp=args.fp, kv_int8=not args.no_kv_int8, kv_bits=args.kv_bits,
        finetune_thresholds=args.finetune_thresholds, use_pallas=use_pallas,
        calib_batch=args.requests, calib_len=args.prompt_len,
        cache_layout=args.cache_layout, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk, temperature=args.temperature,
        top_p=args.top_p, seed=args.seed, decode_strategy=args.strategy,
        spec_k=args.spec_k, spec_ngram=args.spec_ngram,
        queue_cap=args.queue_cap, shed_policy=args.shed_policy,
        fault_plan=fault_plan, journal=args.journal,
        snapshot_every=args.snapshot_every,
        snapshot_dir=args.snapshot_dir)
    if not args.fp:
        print(f"[serve] converted: {engine.n_int8_weights()} int8 weight "
              "tensors resident")
    if sharded:
        print(f"[serve] sharded serving: tp={args.tp} sp={args.sp} over "
              f"{max(args.tp, args.sp)} devices")
        if args.mesh == "dryrun":
            import json

            report = engine.dry_run_report(batch=args.requests,
                                           prompt_len=args.prompt_len)
            print(json.dumps(report, indent=2, default=str))
            verdict = report["int8_all_reduces_ok"]
            print(f"[serve] dryrun: int8_all_reduces_ok={verdict}")
            raise SystemExit(0 if verdict else 1)

    if args.max_slots:
        return run_continuous(args, engine)

    # batched requests from the pipeline (prompt = first prompt_len tokens)
    spec = DP.spec_for(engine.cfg, ShapeSpec("cli", "train",
                                             args.prompt_len, args.requests))
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)
    if not args.no_kv_int8:
        # shape-only: count int8 KV leaves without allocating a cache
        abstract = jax.eval_shape(
            lambda: engine.init_cache(args.requests,
                                      engine._cache_len(args.prompt_len,
                                                        args.gen)))
        n_kv8 = sum(1 for l in jax.tree.leaves(abstract)
                    if l.dtype == jnp.int8)
        kind = ("packed-int4" if engine.policy.kv_bits == 4 else "int8")
        print(f"[serve] kv cache: {n_kv8} {kind} KV tensors resident "
              f"({engine.cache_layout} layout)")

    res = engine.generate_batch(batch, args.gen,
                                prompt_len=args.prompt_len, loop=args.loop)
    kind = "loop" if args.loop else "scan"
    pf_kind = (f"chunked/{args.prefill_chunk}" if args.prefill_chunk
               else "one-shot")
    pf_tps = res.prompt_tokens / max(res.prefill_s, 1e-9)
    print(f"[serve] {args.requests} requests | prefill ({pf_kind}) "
          f"{res.prefill_s*1e3:.1f} ms ({pf_tps:.0f} tok/s) "
          f"| {args.gen} tokens ({kind}) in {res.decode_s*1e3:.1f} ms "
          f"({res.decode_s/max(args.gen-1,1)*1e3:.1f} ms/tok)")
    out = res.tokens
    for r in range(min(args.requests, 2)):
        print(f"  req{r}: prompt={batch['tokens'][r, :8].tolist()}... "
              f"-> generated={out[r].tolist()}")
    return out


if __name__ == "__main__":
    main()
