"""Serving driver: int8 FAT-quantized model, batched requests.

Pipeline: calibrate -> (optional FAT fine-tune) -> convert_to_int8 ->
prefill each request batch -> greedy decode N tokens.  Weights live in
memory as int8 (the paper's "ready to run on mobile phones" artifact, here
TPU-shaped); activations quantize against the frozen calibrated+trained
thresholds, so nothing is computed "on the fly" (§2).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model


def prepare_int8(model, cfg, policy, params, calib_batches):
    """Calibration + int8 conversion (the paper's deployment pipeline)."""
    qparams = A.init_qparams(model, params, policy)
    calib = jax.jit(ST.make_calibrate_step(model, cfg, policy))
    for b in calib_batches:
        qparams = calib(params, qparams, b)
    qparams = A.finalize_calibration(qparams, policy)
    serve_params = A.convert_to_int8(model, params, qparams, policy)
    return serve_params, qparams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--fp", action="store_true",
                    help="serve in bf16 instead of int8 (baseline)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    policy = A.QuantPolicy()
    params = model.init(jax.random.PRNGKey(0))

    shape = ShapeSpec("cli", "train", args.prompt_len, args.requests)
    spec = DP.spec_for(cfg, shape)
    calib = DP.calibration_batches(spec, 2)
    for b in calib:
        b.pop("labels", None)

    mode = "none" if args.fp else "int8"
    if args.fp:
        serve_params, qparams = params, A.finalize_calibration(
            A.init_qparams(model, params, policy), policy)
    else:
        serve_params, qparams = prepare_int8(model, cfg, policy, params,
                                             calib)
        n_int8 = sum(1 for l in jax.tree.leaves(serve_params)
                     if l.dtype == jnp.int8)
        print(f"[serve] converted: {n_int8} int8 weight tensors resident")

    prefill = jax.jit(ST.make_prefill_step(model, cfg, policy, mode=mode))
    decode = jax.jit(ST.make_serve_step(model, cfg, policy, mode=mode))

    # batched requests from the pipeline (prompt = first prompt_len tokens)
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)
    max_len = args.prompt_len + args.gen + (
        cfg.mm_patches if cfg.modality == "vlm" else 0)
    cache = model.init_cache(args.requests, max_len, cfg.dtype)

    t0 = time.time()
    logits, cache = prefill(serve_params, qparams, batch, cache)
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    prefill_s = time.time() - t0

    toks = [next_tok]
    t0 = time.time()
    pos0 = args.prompt_len + (cfg.mm_patches if cfg.modality == "vlm" else 0)
    for i in range(args.gen - 1):
        next_tok, logits, cache = decode(
            serve_params, qparams, toks[-1][:, None], cache, pos0 + i)
        toks.append(next_tok)
    decode_s = time.time() - t0
    out = jnp.stack(toks, axis=1)
    print(f"[serve] {args.requests} requests | prefill {prefill_s*1e3:.1f} ms "
          f"| {args.gen} tokens in {decode_s*1e3:.1f} ms "
          f"({decode_s/max(args.gen-1,1)*1e3:.1f} ms/tok)")
    for r in range(min(args.requests, 2)):
        print(f"  req{r}: prompt={batch['tokens'][r, :8].tolist()}... "
              f"-> generated={out[r].tolist()}")
    return out


if __name__ == "__main__":
    main()
