"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; tests see 1 CPU).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods add a leading 'pod' axis
    (pure DP across DCN).

    Under the dry-run's 512 placeholder devices the single-pod mesh takes
    the first 256 — `devices=` keeps both variants constructible in one
    process."""
    import numpy as np

    from repro.dist.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before any jax import"
        )
    return make_mesh(shape, axes, devices=devs[:n])


def make_serving_mesh(n: int, *, axis: str = "model"):
    """1-axis mesh over the first ``n`` local devices for the sharded
    serving engine (repro.shard): tensor-parallel OR sequence-parallel
    shards both live on one ``model`` axis.  On a single-host CPU run
    the devices come from ``XLA_FLAGS=--xla_force_host_platform_device_
    count=N`` (the `sharded` CI lane sets 4)."""
    from repro.dist.compat import make_mesh

    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"serving mesh ({axis}={n}) needs {n} devices, found "
            f"{len(devs)} — set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before any jax import, or lower --tp/--sp"
        )
    return make_mesh((n,), (axis,), devices=devs[:n])


def make_host_mesh():
    """Single-device mesh for CPU examples/tests (same axis names)."""
    from repro.dist.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))
