"""Trip-count-corrected HLO accounting for the roofline.

XLA's HloCostAnalysis visits every while-loop body ONCE — a lax.scan over
32 layers reports the flops/bytes of a single layer (verified empirically
on this backend: a scanned 4-step matmul reports 1 step's flops).  All our
step functions are scan-heavy (layer scan, microbatch scan, flash q/kv
scans, loss chunk scan), so raw cost_analysis() under-counts by 1-3 orders
of magnitude.

This module re-derives the three roofline inputs from the *post-
optimization* HLO text (shapes there are per-device, post-SPMD):

  * dot FLOPs   : 2 * prod(output_dims) * prod(contracting_dims), each dot
                  weighted by the product of enclosing while trip counts;
  * collective bytes : per-device operand bytes of all-reduce/all-gather/
                  reduce-scatter/all-to-all/collective-permute, trip-
                  weighted the same way;
  * approx HBM bytes : sum of trip-weighted op *output* bytes over
                  non-trivial ops (proxy for HBM traffic — fusion makes
                  exact accounting impossible from text; documented as an
                  upper-ish bound in EXPERIMENTS.md);
  * cpu_upcast_artifact_bytes : top-level f32 copies of bf16 parameters
                  that the CPU backend materializes because its dot
                  lowering upcasts bf16->f32.  TPU MXU consumes bf16
                  natively, so the TPU peak-memory estimate subtracts
                  these.

Trip counts come from each while condition's `compare(iv, constant)`
pattern; unparseable conditions conservatively count 1.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3": 1, "f8e5m2": 1, "c64": 8,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY )?%([\w\.\-]+) \(.*\) -> .* \{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)"
)
_WHILE_RE2 = re.compile(
    r"while\(.*?\).*body=%?([\w\.\-]+).*condition=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_computations(hlo: str):
    """Returns ({name: lines}, entry_name)."""
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if "{" in line and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line.rstrip())
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    """Extract N from the while condition.

    Canonical lax.scan conditions compare the induction variable against a
    single positive s32 constant (the trip count), possibly via a called
    compare computation — the max positive s32 constant in the condition
    body is the bound.  Unparseable conditions conservatively return 1.
    """
    consts = []
    for l in cond_lines:
        m = re.search(r"s32\[\] constant\((\d+)\)", l)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    approx_bytes: float = 0.0
    cpu_upcast_artifact_bytes: float = 0.0


def _dot_flops(line: str, local_shapes: Dict[str, tuple]) -> float:
    """2 * prod(out_dims) * prod(lhs contracting dims).

    Post-optimization HLO prints operand *names* in dot(...); shapes are
    resolved from each computation's definition map.
    """
    out = _SHAPE_RE.search(line.split("=", 1)[1])
    if not out:
        return 0.0
    out_n = 1
    for d in out.group(2).split(","):
        if d:
            out_n *= int(d)
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    args = re.search(r"dot\(%?([\w\.\-]+), %?([\w\.\-]+)\)", line)
    if mcd and args:
        lhs = local_shapes.get(args.group(1))
        if lhs is not None:
            lhs_dims = [int(x) for x in lhs[1].split(",") if x]
            k = 1
            for ci in mcd.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    k *= lhs_dims[int(ci)]
            return 2.0 * out_n * k
    return 2.0 * out_n


def analyze(hlo: str, entry_hint: str | None = None) -> HloCosts:
    comps, entry = parse_computations(hlo)
    # map: body name -> trip count (from its while's condition)
    body_trips: Dict[str, int] = {}
    for name, lines in comps.items():
        for l in lines:
            if " while(" in l:
                m = re.search(r"condition=%?([\w\.\-]+)", l)
                b = re.search(r"body=%?([\w\.\-]+)", l)
                if m and b and m.group(1) in comps:
                    body_trips[b.group(1)] = _trip_count(comps[m.group(1)])

    if entry is None:
        for name in comps:
            if "main" in name or (entry_hint and entry_hint in name):
                entry = name
                break
    if entry is None and comps:
        entry = next(iter(comps))

    mult: Dict[str, float] = {}

    def visit(name: str, m: float, seen):
        if name in seen:
            return
        seen = seen | {name}
        mult[name] = mult.get(name, 0.0) + m
        for l in comps.get(name, ()):
            for callee in _CALL_RE.findall(l):
                if callee not in comps:
                    continue
                m2 = m * body_trips.get(callee, 1)
                visit(callee, m2, seen)

    if entry:
        visit(entry, 1.0, frozenset())

    costs = HloCosts(collective_by_kind={k: 0.0 for k in _COLLECTIVES})
    param_f32_convert = re.compile(
        r"f32\[[0-9,]+\][^=]*fusion\(%?(param[\w\.\-]*)\)"
    )
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        # local definition shapes (for dot operand lookup)
        local_shapes: Dict[str, tuple] = {}
        for l in lines:
            dm = re.match(r"\s*%?([\w\.\-]+) = \(?([a-z0-9]+)\[([0-9,]*)\]", l)
            if dm:
                local_shapes[dm.group(1)] = (dm.group(2), dm.group(3))
        for l in lines:
            s = l.strip()
            mm = re.match(r"%?[\w\.\-]+ = \(?([a-z0-9]+)\[([0-9,]*)\]", s)
            if not mm:
                continue
            out_bytes = _shape_bytes(mm.group(1), mm.group(2))
            op_m = re.search(r"\]\S*\s+([a-z0-9\-]+)\(", s)
            op = op_m.group(1) if op_m else ""
            if op in ("parameter", "get-tuple-element", "tuple", "bitcast",
                      "constant", "iota"):
                continue
            if op == "dynamic-update-slice":
                # in-place on TPU (donated buffers): traffic is the
                # updated slice (read+write), not the whole buffer —
                # decode-step KV writes would otherwise count the entire
                # cache per layer per token
                um = re.search(r"dynamic-update-slice\(%?[\w\.\-]+, "
                               r"%?([\w\.\-]+)", s)
                upd = local_shapes.get(um.group(1)) if um else None
                if upd is not None:
                    costs.approx_bytes += m * 2 * _shape_bytes(*upd)
                    continue
            costs.approx_bytes += m * out_bytes
            if op == "dot":
                costs.dot_flops += m * _dot_flops(s, local_shapes)
            for kind in _COLLECTIVES:
                if op == kind or op == kind + "-start":
                    costs.collective_bytes += m * out_bytes
                    costs.collective_by_kind[kind] += m * out_bytes
            # top-level f32 copies of bf16 params (CPU dot-upcast artifact)
            if name == entry and "wrapped_convert" in s and "f32[" in s:
                pm = param_f32_convert.search(s)
                if pm:
                    costs.cpu_upcast_artifact_bytes += out_bytes
    return costs


# ---------------------------------------------------------------------------
# Interconnect dtype contract (sharded serving)
# ---------------------------------------------------------------------------
#
# The sharded engine's exactness story (docs/serving.md "Sharded serving")
# rests on every ALL-REDUCE moving integer bytes: tensor-parallel epilogues
# psum the int8 matmul's int32 accumulators (exact integer addition), and
# the one sanctioned float collective is compressed_psum's scalar f32 pmax
# threshold.  These helpers prove that property on the post-optimization
# HLO — the same text the roofline parser reads — so the contract holds
# after every fusion/SPMD rewrite, not just in the jaxpr.

_INT_DTYPES = frozenset({"s8", "u8", "s16", "u16", "s32", "u32", "s64",
                         "u64", "pred"})
_AR_SPLIT = re.compile(r"=\s*(.*?)\s*(?:all-reduce|all-reduce-start)\(")


def all_reduce_payloads(hlo: str) -> List[tuple]:
    """Every all-reduce payload in the module as (dtype, elems) tuples.

    Tuple-result all-reduces (variadic reduction) contribute one entry
    per element; `all-reduce-done` lines repeat the `-start` shape and
    are skipped so a payload is never double-counted.
    """
    out = []
    for line in hlo.splitlines():
        s = line.strip()
        if ("all-reduce(" not in s and "all-reduce-start(" not in s) \
                or "all-reduce-done" in s:
            continue
        m = _AR_SPLIT.search(s)
        if not m:
            continue
        for dtype, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out.append((dtype, n))
    return out


def check_integer_all_reduces(hlo: str, *, allow_f32_scalars: int = 1):
    """(ok, findings): every all-reduce payload must be integer-typed,
    excepting up to ``allow_f32_scalars`` SCALAR f32 payloads (the
    compressed_psum shared-threshold pmax).  A float tensor payload is
    always a violation — that is exactly the hole the drift.collective
    rule exists to keep closed."""
    findings = []
    scalars_seen = 0
    for dtype, elems in all_reduce_payloads(hlo):
        if dtype in _INT_DTYPES:
            continue
        if elems <= 1 and scalars_seen < allow_f32_scalars:
            scalars_seen += 1
            continue
        findings.append(
            f"all-reduce moves {dtype}[{elems}] — serving-path reduces "
            "must carry integer payloads (route them through "
            "dist/collectives.py::compressed_psum)")
    return (not findings), findings
