"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

Everything here is abstract: no device allocation ever happens.  The same
spec builders feed ``jax.jit(...).lower()`` for all three step kinds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.core import api as A


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_abstract(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Training/prefill batch structure (matches data.pipeline.make_batch)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        s_text = max(s // cfg.dec_ratio, 4)
        batch = {
            "frames": sds((b, s, cfg.frame_dim), cfg.dtype),
            "tokens": sds((b, s_text), jnp.int32),
            "labels": sds((b, s_text), jnp.int32),
        }
    elif cfg.modality == "vlm":
        s_text = s - cfg.mm_patches
        batch = {
            "patches": sds((b, cfg.mm_patches, cfg.mm_dim), cfg.dtype),
            "tokens": sds((b, s_text), jnp.int32),
            "labels": sds((b, s_text), jnp.int32),
        }
    else:
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
    return batch


def model_state_abstract(model, cfg: ModelConfig, policy: A.QuantPolicy):
    """(params, qparams) ShapeDtypeStructs via eval_shape (no allocation)."""

    def build(key):
        params = model.init(key)
        qparams = A.init_qparams(model, params, policy)
        # training consumes *post-calibration* threshold state (floats
        # only): §3.1.3 — thresholds init from calibration, then trained
        qparams = A.finalize_calibration(qparams, policy)
        return params, qparams

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def serve_state_abstract(model, cfg: ModelConfig, policy: A.QuantPolicy):
    """(serve_params int8, qparams) ShapeDtypeStructs."""

    def build(key):
        params = model.init(key)
        qparams = A.init_qparams(model, params, policy)
        qparams = A.finalize_calibration(qparams, policy)
        serve_params = A.convert_to_int8(model, params, qparams, policy)
        return serve_params, qparams

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def cache_abstract(model, cfg: ModelConfig, batch: int, max_len: int,
                   kv_int8: bool = False):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, cfg.dtype, kv_int8=kv_int8)
    )


def opt_state_abstract(qparams_abstract):
    from repro.optim.adam import adam_init

    return jax.eval_shape(adam_init, qparams_abstract)
