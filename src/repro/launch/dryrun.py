import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

MUST be run as a script/module — the two lines above execute before any
other import (jax locks the device count on first init).

Per cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract (ShapeDtypeStruct) params/qparams/batch/cache,
  3. assigns shardings from repro.dist.sharding rules,
  4. jit(...).lower(...).compile()  — proving the distribution config is
     coherent (sharding mismatches, compile-time OOM, unsupported
     collectives all fail HERE),
  5. prints memory_analysis() / cost_analysis(),
  6. derives the three roofline terms (compute/memory/collective) with
     v5e constants and writes a JSON report for benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.core import api as A
from repro.dist import sharding as SH
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh

# --- TPU v5e hardware constants (roofline denominators) ---
PEAK_FLOPS_BF16 = 197e12     # per chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 4.5e10              # ~50 GB/s usable per link, 1 link per hop

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=?\s*\(?([a-z0-9]+)\[([0-9,]*)\]"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "c64": 8,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective in the
    post-partitioning HLO (shapes in the SPMD module are per-device)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r".*= *(?:\([^)]*\) )?([a-z0-9]+)\[([0-9,]*)\][^ ]* (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            # tuple-result collectives: "... = (f32[..], f32[..]) all-reduce"
            m2 = re.match(r".*= *\((.*?)\) (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)", s)
            if not m2:
                continue
            shapes = _SHAPE_RE.findall(m2.group(1))
            kind = m2.group(2)
            nbytes = 0
            for dt, dims in shapes:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES.get(dt, 4)
        else:
            dt, dims, kind = m.group(1), m.group(2), m.group(3)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes = n * _DTYPE_BYTES.get(dt, 4)
        if "start" in s and f"{kind}-start" in s:
            pass  # async start counted; matching -done carries no payload
        out[kind] += nbytes
        out["count"] += 1
    return out


def build_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               rules: SH.ShardingRules | None = None, verbose: bool = True):
    shape = SHAPES[shape_name]
    cfg = get_config(arch).replace(scan_layers=True)
    from repro.models import build_model

    model = build_model(cfg)
    policy = A.QuantPolicy()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    if rules is None:
        rules = SH.ShardingRules()
    # hillclimb knobs (per-cell overrides without code edits)
    import dataclasses as _dc

    if os.environ.get("REPRO_ACT_SEQ", "") == "none":
        rules = _dc.replace(rules, act_seq=None)
    if os.environ.get("REPRO_KV_LAYOUT"):
        rules = _dc.replace(rules, kv_cache_layout=os.environ["REPRO_KV_LAYOUT"])
    if multi_pod:
        rules = SH.multipod(rules)

    from repro.dist import constraints as CONSTR

    CONSTR.install(rules)

    t0 = time.time()
    if shape.kind == "train":
        params_a, qparams_a = SP.model_state_abstract(model, cfg, policy)
        opt_a = SP.opt_state_abstract(qparams_a)
        batch_a = SP.batch_specs_abstract(cfg, shape)
        p_spec = SH.param_specs(model, params_a, rules)
        q_spec = SH.qparam_specs(model, params_a, qparams_a, rules)
        from repro.optim.adam import AdamState

        o_spec = AdamState(step=SH.P(), mu=q_spec, nu=q_spec)
        b_spec = SH.batch_specs(batch_a, rules)
        # microbatch count: keep one microbatch's activations within HBM;
        # scales with width (activation bytes ~ B*S*d); hillclimb knob
        n_micro = (1 if cfg.d_model < 2048 else
                   4 if cfg.d_model < 5000 else
                   8 if cfg.d_model < 6000 else 16)
        if cfg.ffn == "moe":
            # expert dispatch holds top_k copies of the token stream
            # (hillclimbed: granite-moe top-8 needs the full factor)
            n_micro *= max(2, cfg.top_k)
        if cfg.kind == "hybrid":
            # parallel attn+SSM branches double the per-layer activations
            n_micro *= 4
        if os.environ.get("REPRO_N_MICRO"):
            n_micro = int(os.environ["REPRO_N_MICRO"])
        step_fn = ST.make_fat_train_step(model, cfg, policy, n_micro=n_micro)
        jitted = jax.jit(
            step_fn,
            in_shardings=(
                SH.to_shardings(p_spec, mesh, params_a),
                SH.to_shardings(q_spec, mesh, qparams_a),
                SH.to_shardings(o_spec, mesh, opt_a),
                SH.to_shardings(b_spec, mesh, batch_a),
            ),
            donate_argnums=(1, 2),
        )
        args = (params_a, qparams_a, opt_a, batch_a)
    elif shape.kind == "prefill":
        serve_a, qparams_a = SP.serve_state_abstract(model, cfg, policy)
        batch_a = SP.batch_specs_abstract(cfg, shape)
        batch_a.pop("labels", None)
        cache_len = shape.seq_len if cfg.family != "encdec" else max(
            shape.seq_len // cfg.dec_ratio, 4)
        cache_a = SP.cache_abstract(model, cfg, shape.global_batch, cache_len)
        p_spec = SH.param_specs(model, serve_a, rules)
        q_spec = SH.qparam_specs(model, serve_a, qparams_a, rules)
        b_spec = SH.batch_specs(batch_a, rules)
        c_spec = SH.cache_specs(cache_a, rules, mesh.shape["model"])
        step_fn = ST.make_prefill_step(model, cfg, policy)
        jitted = jax.jit(
            step_fn,
            in_shardings=(
                SH.to_shardings(p_spec, mesh, serve_a),
                SH.to_shardings(q_spec, mesh, qparams_a),
                SH.to_shardings(b_spec, mesh, batch_a),
                SH.to_shardings(c_spec, mesh, cache_a),
            ),
            donate_argnums=(3,),
        )
        args = (serve_a, qparams_a, batch_a, cache_a)
    else:  # decode
        serve_a, qparams_a = SP.serve_state_abstract(model, cfg, policy)
        cache_a = SP.cache_abstract(model, cfg, shape.global_batch,
                                    shape.seq_len)
        tok_a = SP.sds((shape.global_batch, 1), jnp.int32)
        pos_a = SP.sds((), jnp.int32)
        p_spec = SH.param_specs(model, serve_a, rules)
        q_spec = SH.qparam_specs(model, serve_a, qparams_a, rules)
        c_spec = SH.cache_specs(cache_a, rules, mesh.shape["model"])
        t_spec = SH.batch_specs(tok_a, rules)
        step_fn = ST.make_serve_step(model, cfg, policy)
        jitted = jax.jit(
            step_fn,
            in_shardings=(
                SH.to_shardings(p_spec, mesh, serve_a),
                SH.to_shardings(q_spec, mesh, qparams_a),
                SH.to_shardings(t_spec, mesh, tok_a),
                SH.to_shardings(c_spec, mesh, cache_a),
                SH.to_shardings(SH.P(), mesh),
            ),
            donate_argnums=(3,),
        )
        args = (serve_a, qparams_a, tok_a, cache_a, pos_a)

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    from repro.launch import hlo_analysis as HA

    hlo = HA.analyze(compiled.as_text())

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    # trip-count-corrected per-device numbers (see hlo_analysis docstring;
    # raw cost_analysis counts every while body once)
    flops = hlo.dot_flops
    bytes_hbm = hlo.approx_bytes
    coll_total = hlo.collective_bytes

    arg_b = getattr(mem, "argument_size_in_bytes", 0) or 0
    temp_b = getattr(mem, "temp_size_in_bytes", 0) or 0
    peak = arg_b + temp_b
    # TPU peak estimate: the CPU backend lowers bf16 dots via f32 upcasts,
    # materializing f32 copies of bf16 weights the MXU never needs
    peak_tpu = peak - hlo.cpu_upcast_artifact_bytes

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_hbm / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens  # classic 6ND (fwd+bwd)
        # FAT QAT actual budget: teacher fwd 2ND + student fwd 2ND +
        # student bwd 2ND + remat recompute 2ND ≈ 8ND; noted in §Roofline
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2 * n_active * tokens
    useful_ratio = model_flops / max(flops * n_chips, 1.0)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": arg_b,
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": temp_b,
            "peak_bytes_per_device": peak,
            "cpu_f32_upcast_artifact_bytes": hlo.cpu_upcast_artifact_bytes,
            "peak_bytes_per_device_tpu_estimate": peak_tpu,
        },
        "cost_analysis_raw": {"flops_per_device": flops_raw,
                              "bytes_per_device": bytes_raw},
        "hlo_corrected": {
            "dot_flops_per_device": flops,
            "approx_bytes_per_device": bytes_hbm,
            "collective_bytes_per_device": coll_total,
            "collective_by_kind": hlo.collective_by_kind,
        },
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_global": model_flops,
            "useful_flops_ratio": useful_ratio,
        },
        "params": n,
        "active_params": n_active,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {report['mesh']}] "
              f"compile {t_compile:.0f}s | peak {peak/1e9:.2f} GB/dev "
              f"(tpu-est {peak_tpu/1e9:.2f}) | flops/dev {flops:.3e} | "
              f"coll {coll_total/1e6:.1f} MB | dominant={dominant}")
        print("  memory_analysis:", report["memory_analysis"])
        print("  cost_analysis(raw): flops=%.3e bytes=%.3e"
              % (flops_raw, bytes_raw))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture preset to plan (configs.get_config)")
    ap.add_argument("--shape", default=None,
                    help="explicit shape spec name (overrides --arch)")
    ap.add_argument("--all", action="store_true",
                    help="plan every registered architecture")
    ap.add_argument("--multi-pod", action="store_true",
                    help="include the multi-pod mesh variants")
    ap.add_argument("--both-meshes", action="store_true",
                    help="plan both the serving and training meshes")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="directory for the emitted plan JSON files")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                ok, why = cell_applicable(a, s)
                if ok:
                    cells.append((a, s))
                else:
                    print(f"SKIP {a} x {s}: {why}")
    else:
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[cached] {tag}")
                continue
            try:
                rep = build_cell(arch, shape, multi_pod=mp)
                with open(out_path, "w") as f:
                    json.dump(rep, f, indent=1)
            except Exception as e:  # noqa: BLE001 — report all cell failures
                traceback.print_exc()
                failures.append((tag, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nDRY-RUN: all requested cells lowered+compiled OK")


if __name__ == "__main__":
    main()
