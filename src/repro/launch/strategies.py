"""``DecodeStrategy`` — one decode-loop API, many decoding schemes.

PRs 1 and 3 each grew a bespoke scan loop (single-stream and slotted),
and the carry, the sampling rule, and the EOS/slot bookkeeping were
hardwired twice — any new decoding scheme meant forking a third loop.
This module makes the decode loop a PROTOCOL, the same "one API, many
implementations" move PR 4 made for cache layouts:

  * the **carry** is an explicit ``DecodeState`` pytree — (token, cache,
    per-slot position, active mask, PRNG key, history buffer);
  * a **strategy** supplies four hooks over that carry:

      - ``propose(tok, pos, hist)``   -> draft tokens (B, W-1)
      - ``verify(params, qp, tok, drafts, cache, pos, active)``
                                      -> (logits (B, W, V), cache)
      - ``accept(tok, drafts, logits, active, key)``
                                      -> (next tok, toks (B, W),
                                          emitted (B, W), key)
      - ``update_hist(hist, pos, toks, emitted)`` -> hist

    where W = ``emit_width`` is the (static) number of tokens a step can
    emit;
  * the **loops** (``make_strategy_decode_loop`` single-stream,
    ``make_strategy_slot_loop`` slotted) own the scan, the capacity
    guard, EOS freezing, and position accounting — once, for every
    strategy.

Three strategies ship:

  * ``GreedyStrategy`` / ``SamplingStrategy`` — bit-exact ports of the
    pre-redesign loops (W == 1; verify IS the fused one-token decode
    step, accept is argmax / ``sample_tokens`` with the same per-step
    key split).
  * ``SpeculativeStrategy`` — prompt-lookup speculative decoding
    (draft-model-free): ``propose`` drafts ``k`` tokens by matching the
    trailing ``ngram`` of the token history against earlier history and
    copying what followed; ``verify`` runs the pending token + drafts as
    ONE batched window through ``model.verify_step`` — exactly a short
    per-slot chunked prefill over the int8 cache, reusing the Pallas
    flash-prefill kernel via its per-request ``q_start`` vector (the
    int8 cache halves precisely the bytes this pass streams, which is
    where speculation pays); ``accept`` keeps the longest matching draft
    prefix plus the model's own next token (1..k+1 tokens per step).
    Under this deterministic accept rule every emitted token equals what
    greedy would emit — a WRONG draft costs only wasted verify work,
    never a wrong token — so speculative output is bit-identical to
    ``GreedyStrategy``.  Rejected drafts leave dead cache entries beyond
    the accepted position; ``KVCache.rollback`` documents why no
    physical erase is needed (masks never read them, the next window
    overwrites them) and how the paged layout protects shared prefix
    pages.

Shape discipline (the no-retrace contract): ``k``/``ngram`` are static
— draft CONTENT, match positions, and acceptance counts are data, so
one compiled executable per loop serves every draft length and
admission pattern (pinned by the trace-counter tests in
tests/test_strategies.py).
"""
from __future__ import annotations

import abc
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import api as A


class DecodeState(NamedTuple):
    """The decode-loop carry, made explicit (the protocol's data half).

    ``tok`` is the pending token — sampled/accepted but not yet cached;
    it enters the model at position ``pos`` on the next step.  ``pos``
    counts valid cache entries per slot.  ``hist`` is the strategy's
    state (token history for prompt lookup; a (B, 0) placeholder for
    stateless strategies)."""
    tok: jax.Array       # (B,) int32 pending token
    cache: object        # KVCache pytree
    pos: jax.Array       # (B,) int32 valid cache entries per slot
    active: jax.Array    # (B,) bool live slots
    key: jax.Array       # PRNG key
    hist: jax.Array      # (B, H) int32 strategy state


def _serve_ctx(mode: str, policy: A.QuantPolicy, qparams):
    """Serving ctx.  A ctx is built even for mode='none' when the policy
    quantizes the KV cache or enables the Pallas kernels (Dense layers
    still run full precision — enabled() is False): the
    int8-KV-over-bf16-weights ablation needs the KV thresholds in qparams
    to reach attention, and the fused bf16-KV attention kernels (unit
    scales) need the policy flag to reach it."""
    if mode == "none" and not (policy.kv_int8 or policy.use_pallas):
        return None
    return A.make_ctx(mode, policy, qparams)


def _attn_cache_len(cache):
    """Logical sequence capacity of the first attention cache in a cache
    pytree — a ``repro.cache.KVCache`` object (any layout, stacked or
    per-layer; paged capacity is blocks * page_size) or, for stub caches
    in tests, a plain dict with a (..., S, KV, D) "k" leaf."""
    from repro.cache import KVCache

    if isinstance(cache, KVCache):
        return cache.capacity
    if isinstance(cache, dict):
        if "attn" in cache and isinstance(cache["attn"], dict) \
                and "k" in cache["attn"]:
            return cache["attn"]["k"].shape[-3]
        for sub in cache.values():
            n = _attn_cache_len(sub)
            if n is not None:
                return n
    return None


def _rollback(cache, pos):
    """Thread ``KVCache.rollback`` through a cache pytree (identity for
    non-KVCache test stubs).  In-loop this is the LOGICAL rewind — every
    shipped layout makes it free (see the protocol docstring); the call
    keeps the contract explicit and gives future layouts the hook."""
    from repro.cache import KVCache

    return jax.tree.map(
        lambda c: c.rollback(pos) if isinstance(c, KVCache) else c,
        cache, is_leaf=lambda x: isinstance(x, KVCache))


def sample_tokens(logits, key, *, temperature: float = 1.0,
                  top_p: float = 1.0):
    """Temperature / nucleus (top-p) sampling over (B, V) logits.

    ``temperature <= 0`` is greedy argmax.  ``top_p < 1`` keeps the
    smallest prefix of probability-sorted tokens whose mass reaches
    top_p (always at least the argmax) and renormalizes over it.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_p < 1.0:
        sorted_l = jnp.sort(l, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        # exclusive cumulative mass: a token stays while the mass BEFORE
        # it is < top_p, so the argmax always survives
        cum = jnp.cumsum(probs, axis=-1) - probs
        keep = cum < top_p
        thresh = jnp.min(jnp.where(keep, sorted_l, jnp.inf), axis=-1,
                         keepdims=True)
        l = jnp.where(l >= thresh, l, -jnp.inf)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


def _check_attn_only(cfg, what: str):
    kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
    if kinds - {"attn", "attn_local"} or cfg.modality != "text":
        raise ValueError(
            f"{what} covers attention-only text stacks: SSM state "
            "stepping has no per-slot freeze/rewind yet "
            f"(got kinds={sorted(kinds)}, modality={cfg.modality})")


class DecodeStrategy(abc.ABC):
    """One decoding scheme behind the propose/verify/accept hooks.

    ``emit_width`` (static) is the number of token lanes a step emits
    into — 1 for one-token-at-a-time schemes, draft_k + 1 for
    speculative windows.  ``stateful`` marks strategies that carry a
    history buffer through the loop.
    """

    emit_width: int = 1
    stateful: bool = False

    def __init__(self, model, cfg, policy: A.QuantPolicy,
                 mode: str = "int8"):
        self.model, self.cfg = model, cfg
        self.policy, self.mode = policy, mode

    # -- hooks -------------------------------------------------------------
    def propose(self, tok, pos, hist):
        """Draft tokens (B, emit_width - 1) to verify this step.  Draft
        content never affects WHICH tokens are emitted (verify/accept
        correct wrong drafts) — only how many per step."""
        return jnp.zeros((tok.shape[0], 0), jnp.int32)

    @abc.abstractmethod
    def verify(self, serve_params, qparams, tok, drafts, cache, pos,
               active):
        """Run the model over the pending token (+ drafts) and return
        (logits (B, emit_width, V), new cache).  ``active`` is a (B,)
        slot mask or None (single-stream, scalar ``pos``)."""

    @abc.abstractmethod
    def accept(self, tok, drafts, logits, active, key):
        """Turn verify logits into emissions: (next pending token (B,),
        toks (B, W), emitted (B, W) bool, new key).  ``emitted[b, j]``
        marks real tokens; positions advance by the emitted count."""

    def update_hist(self, hist, pos, toks, emitted):
        return hist


class GreedyStrategy(DecodeStrategy):
    """Argmax decoding — a bit-exact port of the pre-redesign loops: the
    verify pass IS the fused one-token decode step (flash-decode kernel
    under policy.use_pallas), accept is its argmax."""

    emit_width = 1

    def verify(self, serve_params, qparams, tok, drafts, cache, pos,
               active):
        ctx = _serve_ctx(self.mode, self.policy, qparams)
        logits, cache = self.model.decode_step(
            serve_params, tok[:, None], cache, pos, ctx, slot_mask=active)
        return logits, cache

    def accept(self, tok, drafts, logits, active, key):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        if active is None:
            emitted = jnp.ones(nxt.shape + (1,), bool)
        else:
            emitted = active[:, None]
        return nxt, nxt[:, None], emitted, key


class SamplingStrategy(GreedyStrategy):
    """Temperature / nucleus sampling (absorbs ``sample_tokens``).

    Two key schedules, dispatched (at trace time) on the carried key's
    shape:

      * scalar key — split once per step, shared across slots: the
        pre-redesign schedule, so same seed -> same tokens for the
        legacy loops and the steps-module wrapper;
      * per-slot keys, a (B, 2) uint32 matrix — each slot splits its OWN
        key, and a slot's key advances only on its active steps.  A
        request's sample stream then depends only on its admission key
        and how many tokens it has emitted — not on arrival order, slot
        placement, batch composition, or preemption — which is what lets
        the scheduler promise same-seed reproducibility across admission
        patterns (tests/test_resilience.py pins it).
    """

    def __init__(self, model, cfg, policy, mode: str = "int8", *,
                 temperature: float = 1.0, top_p: float = 1.0):
        super().__init__(model, cfg, policy, mode)
        self.temperature, self.top_p = temperature, top_p

    def accept(self, tok, drafts, logits, active, key):
        last = logits[:, -1, :]
        if key.ndim == 2:
            ks = jax.vmap(jax.random.split)(key)     # (B, 2, 2)
            sub, nxt_key = ks[:, 0], ks[:, 1]
            nxt = jax.vmap(
                lambda l, k: sample_tokens(l[None], k,
                                           temperature=self.temperature,
                                           top_p=self.top_p)[0])(last, sub)
            if active is None:
                emitted = jnp.ones(nxt.shape + (1,), bool)
                key = nxt_key
            else:
                emitted = active[:, None]
                # frozen slots hold their key: the stream position stays
                # a pure function of tokens emitted
                key = jnp.where(active[:, None], nxt_key, key)
            return nxt, nxt[:, None], emitted, key
        key, sub = jax.random.split(key)
        nxt = sample_tokens(last, sub,
                            temperature=self.temperature, top_p=self.top_p)
        if active is None:
            emitted = jnp.ones(nxt.shape + (1,), bool)
        else:
            emitted = active[:, None]
        return nxt, nxt[:, None], emitted, key


class SpeculativeStrategy(DecodeStrategy):
    """Prompt-lookup speculative decoding (no second model).

    ``propose`` matches the trailing ``ngram`` tokens of the history
    (prompt + everything emitted, including the pending token) against
    earlier history; the most recent match's continuation becomes the
    ``k`` drafts (the pending token repeated when nothing matches — a
    always-rejected placebo, so a lookup miss degrades to greedy rate,
    never to wrong output).  ``verify`` runs [pending, drafts] as one
    (B, k+1) window through ``model.verify_step``; ``accept`` keeps the
    longest prefix of drafts matching the model's own argmax plus the
    model's next token after it.  Deterministic accept == greedy accept,
    token for token.

    The history buffer ``hist`` maps absolute position -> token (the
    caller sizes it to the cache capacity and seeds it with the prompt
    at admission); all matching is fixed-shape: the n-gram, window
    starts, and draft gathers are data-indexed into (B, H), so one
    compiled loop serves every match/acceptance pattern.
    """

    stateful = True

    def __init__(self, model, cfg, policy, mode: str = "int8", *,
                 draft_k: int = 4, ngram: int = 2):
        super().__init__(model, cfg, policy, mode)
        _check_attn_only(cfg, "speculative decoding")
        if draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {draft_k}")
        if ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {ngram}")
        self.draft_k, self.ngram = draft_k, ngram
        self.emit_width = draft_k + 1

    def propose(self, tok, pos, hist):
        b, h = hist.shape
        g, k = self.ngram, self.draft_k
        if h < g + 1:
            raise ValueError(
                f"history buffer ({h}) shorter than ngram+1 ({g + 1})")
        pos = jnp.asarray(pos, jnp.int32).reshape(-1)
        j = jnp.arange(g, dtype=jnp.int32)
        # the trailing n-gram ends at the pending token (hist[pos])
        gram_idx = jnp.clip(pos[:, None] - (g - 1) + j[None], 0, h - 1)
        gram = jnp.take_along_axis(hist, gram_idx, axis=1)       # (B, g)
        starts = jnp.arange(h - g + 1, dtype=jnp.int32)
        wins = hist[:, starts[:, None] + j[None]]                # (B, n, g)
        hit = (wins == gram[:, None, :]).all(-1)
        # a usable match must END before the trailing gram starts, so its
        # continuation (the draft source) is known history
        usable = hit & (starts[None, :] <= pos[:, None] - g)
        best = jnp.max(jnp.where(usable, starts[None, :], -1), axis=1)
        found = best >= 0
        src = jnp.maximum(best, 0) + g                           # (B,)
        didx = src[:, None] + jnp.arange(k, dtype=jnp.int32)[None]
        # clamp draft reads to known history (a short continuation pads
        # with the newest tokens — correctness never depends on drafts)
        didx = jnp.clip(jnp.minimum(didx, pos[:, None]), 0, h - 1)
        drafts = jnp.take_along_axis(hist, didx, axis=1)         # (B, k)
        return jnp.where(found[:, None], drafts, tok[:, None])

    def verify(self, serve_params, qparams, tok, drafts, cache, pos,
               active):
        ctx = _serve_ctx(self.mode, self.policy, qparams)
        window = jnp.concatenate([tok[:, None], drafts], axis=1)
        logits, cache = self.model.verify_step(
            serve_params, window, cache, pos, ctx, slot_mask=active)
        return logits, cache

    def accept(self, tok, drafts, logits, active, key):
        w = self.emit_width
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, W)
        # accept the longest draft prefix the model agrees with; accepted
        # drafts EQUAL the model's argmax at their position, so the
        # emitted tokens are simply pred[:n_match + 1] — drafts only
        # decide how far one verify pass advances
        match = (drafts == pred[:, :-1]).astype(jnp.int32)
        n_match = jnp.sum(jnp.cumprod(match, axis=1), axis=1)    # (B,)
        lanes = jnp.arange(w, dtype=jnp.int32)[None]
        emitted = lanes <= n_match[:, None]
        if active is not None:
            emitted = emitted & active[:, None]
        nxt = jnp.take_along_axis(
            pred, jnp.clip(n_match, 0, w - 1)[:, None], axis=1)[:, 0]
        return nxt, pred, emitted, key

    def update_hist(self, hist, pos, toks, emitted):
        """Record the step's emissions at their absolute positions
        (``pos`` is the PRE-step position; emitted lane j landed at
        ``pos + 1 + j``)."""
        b, h = hist.shape
        idx = pos[:, None] + 1 + jnp.arange(toks.shape[1],
                                            dtype=jnp.int32)[None]
        idx = jnp.where(emitted, idx, h)    # out-of-range -> dropped
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]
        return hist.at[rows, idx].set(toks, mode="drop")


STRATEGIES = ("greedy", "sample", "speculative")


def make_strategy(name: str, model, cfg, policy: A.QuantPolicy,
                  mode: str = "int8", *, temperature: float = 0.0,
                  top_p: float = 1.0, spec_k: int = 4,
                  spec_ngram: int = 2) -> DecodeStrategy:
    """Build a strategy by name; ``name=None`` auto-picks from the
    sampling knobs (sample when temperature > 0, else greedy) — the
    pre-redesign behavior."""
    if name is None:
        name = "sample" if temperature > 0.0 else "greedy"
    if name == "greedy":
        if temperature > 0.0:
            raise ValueError(
                "greedy decoding ignores temperature — drop the "
                "temperature or use strategy='sample'")
        return GreedyStrategy(model, cfg, policy, mode)
    if name == "sample":
        return SamplingStrategy(model, cfg, policy, mode,
                                temperature=temperature, top_p=top_p)
    if name == "speculative":
        if temperature > 0.0:
            raise ValueError(
                "speculative decoding uses the deterministic (greedy) "
                "accept rule; temperature must be 0")
        return SpeculativeStrategy(model, cfg, policy, mode,
                                   draft_k=spec_k, ngram=spec_ngram)
    raise ValueError(f"unknown decode strategy {name!r} (use one of "
                     f"{STRATEGIES})")


# -- the loops (own the scan; strategies own the scheme) ---------------------

def make_strategy_decode_loop(model, cfg, policy: A.QuantPolicy,
                              strategy: DecodeStrategy, mode: str = "int8",
                              n_steps: int = 16):
    """Single-stream whole-generation decode as ONE compiled call.

    ``emit_width == 1`` strategies keep the pre-redesign carry exactly —
    (token, cache, scalar position, key), n_steps - 1 scanned steps,
    tokens[:, 0] == tok0 — so greedy/sampling are bit-identical to the
    old ``make_decode_loop``.  Windowed strategies (speculative) carry
    per-slot positions, a write cursor, and an output buffer instead:
    each step scatters its accepted tokens at the cursor, rows freeze
    when their budget fills, and the loop still returns a dense
    (B, n_steps) token matrix.  Callers jit with ``donate_argnums=(3,)``
    either way (serve/engine do).
    """
    w = strategy.emit_width

    if w == 1:
        def decode_loop(serve_params, qparams, tok0, cache, pos0, key=None):
            if key is None:
                key = jax.random.PRNGKey(0)
            no_drafts = jnp.zeros((tok0.shape[0], 0), jnp.int32)

            def body(carry, _):
                tok, cache, pos, key = carry
                logits, cache = strategy.verify(
                    serve_params, qparams, tok, no_drafts, cache, pos, None)
                nxt, _, _, key = strategy.accept(tok, no_drafts, logits,
                                                 None, key)
                return (nxt, cache, pos + 1, key), nxt

            carry0 = (tok0, cache, jnp.asarray(pos0, jnp.int32), key)
            (_, cache, _, _), toks = jax.lax.scan(body, carry0, None,
                                                  length=n_steps - 1)
            toks = jnp.concatenate(
                [tok0[:, None], jnp.moveaxis(toks, 0, 1)], axis=1)
            return toks, cache

        return decode_loop

    def decode_loop(serve_params, qparams, tok0, cache, pos0, key=None,
                    hist=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        b = tok0.shape[0]
        if hist is None:
            raise ValueError(
                "a stateful strategy needs its history buffer (seed it "
                "with the prompt tokens + tok0; see Engine.generate_batch)")
        cache_len = _attn_cache_len(cache)
        pos_v = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32).reshape(-1),
                                 (b,))
        out0 = jnp.zeros((b, n_steps), jnp.int32).at[:, 0].set(tok0)
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]

        def body(carry, _):
            tok, cache, pos, n_out, out, key, hist = carry
            active = n_out < n_steps
            if cache_len is not None:
                active = active & (pos + w <= cache_len)
            drafts = strategy.propose(tok, pos, hist)
            logits, cache = strategy.verify(serve_params, qparams, tok,
                                            drafts, cache, pos, active)
            nxt, toks, emitted, key = strategy.accept(tok, drafts, logits,
                                                      active, key)
            nxt = jnp.where(active, nxt, tok)
            toks = jnp.where(emitted, toks, tok[:, None])
            # compact this step's emissions at each row's write cursor;
            # lanes past the budget scatter out of range and drop
            off = jnp.cumsum(emitted, axis=1) - emitted
            idx = jnp.where(emitted, n_out[:, None] + off, n_steps)
            out = out.at[rows, idx].set(toks, mode="drop")
            n_acc = jnp.sum(emitted, axis=1)
            hist = strategy.update_hist(hist, pos, toks, emitted)
            pos = pos + n_acc
            cache = _rollback(cache, pos)
            return (nxt, cache, pos, n_out + n_acc, out, key, hist), None

        carry0 = (tok0, cache, pos_v, jnp.ones((b,), jnp.int32), out0, key,
                  hist)
        # worst case one token per step: n_steps - 1 windows always fill
        # the budget; high acceptance just freezes the tail steps early
        (_, cache, _, _, out, _, _), _ = jax.lax.scan(
            body, carry0, None, length=n_steps - 1)
        return out, cache

    return decode_loop


def make_strategy_slot_loop(model, cfg, policy: A.QuantPolicy,
                            strategy: DecodeStrategy, mode: str = "int8",
                            n_steps: int = 8, eos_id: int = -1):
    """One continuous-batching decode BLOCK under any strategy.

    The carry is a ``DecodeState``: per-slot (tok, cache, pos, active,
    key, hist).  Each of the ``n_steps`` scanned steps runs
    propose -> verify -> accept and then the loop-owned bookkeeping:

      * capacity guard BEFORE the write — a slot without room for a full
        ``emit_width`` window freezes instead of clamp-writing;
      * EOS (``eos_id >= 0``): the EOS lane itself is emitted, every
        later lane in the window is cut, and the slot freezes — mid-scan,
        without touching the rest of the batch;
      * positions advance by each slot's emitted count (slots DRAIN AT
        DIFFERENT RATES under speculation — that raggedness is data);
      * ``KVCache.rollback`` records the logical rewind of rejected
        draft entries;
      * NON-FINITE LOGITS freeze only the slot that produced them: the
        slot emits nothing from that step on and comes back flagged in
        ``bad``, so the scheduler can retire just that request as
        ``failed`` while the rest of the batch decodes on.  The optional
        ``nan_step`` vector ((B,) int32, -1 = never) forces a slot's
        logits non-finite at a chosen scan step — the fault-injection
        hook (``launch/faults.py``); it is DATA, so faulted and clean
        runs share one executable.

    Returns ``(toks (B, n_steps * W), emitted (B, n_steps * W), cache,
    pos, active, key, hist, bad)`` with W = ``emit_width``; lane j of
    step i sits at column i * W + j.  Under speculation emissions are
    ragged WITHIN a window, so consumers skip un-emitted lanes rather
    than stopping at the first (the scheduler does).  All shapes are
    fixed by (max_slots, cache_len, n_steps, W): one compiled executable
    serves every admission pattern, every draft/acceptance pattern, and
    every fault plan.  Callers jit with ``donate_argnums=(3,)``.
    """
    _check_attn_only(cfg, "slot decode")
    w = strategy.emit_width

    def slot_loop(serve_params, qparams, tok0, cache, pos0, active0,
                  key=None, hist=None, nan_step=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        cache_len = _attn_cache_len(cache)
        if strategy.stateful and hist is None:
            raise ValueError(
                "a stateful strategy needs its history buffer (the "
                "scheduler seeds it with each prompt at admission)")
        pos0 = jnp.asarray(pos0, jnp.int32)
        if nan_step is None:
            nan_step = jnp.full(pos0.reshape(-1).shape, -1, jnp.int32)

        def body(carry, step_i):
            st = DecodeState(*carry[0])
            bad_acc = carry[1]
            tok, cache, pos, active, key, hist = st
            # capacity guard BEFORE the write: a slot without room for a
            # whole window freezes instead of clamping over valid entries
            if cache_len is not None:
                active = active & (pos + w <= cache_len)
            drafts = strategy.propose(tok, pos, hist)
            logits, cache = strategy.verify(serve_params, qparams, tok,
                                            drafts, cache, pos, active)
            # injected fault: scheduled slots' logits turn NaN here, then
            # flow through the same detection as a real model fault
            hit = (nan_step == step_i) & active
            logits = jnp.where(hit[:, None, None],
                               jnp.asarray(jnp.nan, logits.dtype), logits)
            nxt, toks, emitted, key = strategy.accept(tok, drafts, logits,
                                                      active, key)
            nxt = jnp.where(active, nxt, tok)      # frozen slots hold
            toks = jnp.where(emitted, toks, tok[:, None])
            # fault isolation: a slot with non-finite verify logits emits
            # nothing this step and freezes; the batch decodes on
            finite = jnp.all(jnp.isfinite(logits.astype(jnp.float32)),
                             axis=(1, 2))
            bad = active & ~finite
            emitted = emitted & ~bad[:, None]
            nxt = jnp.where(bad, tok, nxt)
            active = active & ~bad
            bad_acc = bad_acc | bad
            if eos_id >= 0:
                # the EOS lane itself is emitted; later lanes in the
                # window are cut and the slot freezes after
                iseos = (toks == eos_id) & emitted
                before = (jnp.cumsum(iseos.astype(jnp.int32), axis=1)
                          - iseos.astype(jnp.int32))
                emitted = emitted & (before == 0)
                eos_hit = jnp.any(iseos & emitted, axis=1)
                active = active & ~eos_hit
                if w > 1:
                    # the held token of a frozen slot is its last
                    # emission (the EOS), matching the one-token loops
                    last = jnp.clip(jnp.sum(emitted, axis=1) - 1, 0, w - 1)
                    held = jnp.take_along_axis(toks, last[:, None],
                                               axis=1)[:, 0]
                    nxt = jnp.where(eos_hit, held, nxt)
            n_acc = jnp.sum(emitted, axis=1)
            hist = strategy.update_hist(hist, pos, toks, emitted)
            pos = pos + n_acc
            cache = _rollback(cache, pos)
            return ((nxt, cache, pos, active, key, hist), bad_acc), \
                (toks, emitted)

        active0 = jnp.asarray(active0, bool)
        if hist is None:
            hist = jnp.zeros((pos0.shape[0], 0), jnp.int32)
        carry0 = ((jnp.asarray(tok0, jnp.int32), cache, pos0, active0, key,
                   hist), jnp.zeros(active0.shape, bool))
        ((tok, cache, pos, active, key, hist), bad), (toks, emitted) = \
            jax.lax.scan(body, carry0, jnp.arange(n_steps, dtype=jnp.int32))
        # (n_steps, B, W) -> (B, n_steps * W): lane j of step i at i*W+j
        b = pos.shape[0]
        toks = jnp.moveaxis(toks, 0, 1).reshape(b, n_steps * w)
        emitted = jnp.moveaxis(emitted, 0, 1).reshape(b, n_steps * w)
        return toks, emitted, cache, pos, active, key, hist, bad

    return slot_loop
