"""Training driver: FAT QAT (paper mode) or pretrain (substrate mode).

Runs on anything from 1 CPU to the production mesh; fault-tolerant:
checkpoints atomically every N steps (threshold state + optimizer +
data-pipeline position) and auto-resumes from the newest complete
checkpoint on restart — kill it mid-run and rerun the same command to
verify.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 200 --mode fat_qat --ckpt-dir /tmp/fat_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model
from repro.optim.adam import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    help="architecture preset to train")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--mode", default="fat_qat",
                    choices=["fat_qat", "pretrain"],
                    help="fat_qat: calibrate + train threshold scale "
                         "factors; pretrain: plain LM training")
    ap.add_argument("--steps", type=int, default=100,
                    help="training steps")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch size")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length")
    ap.add_argument("--calib-batches", type=int, default=4,
                    help="batches for threshold calibration (paper s3.1)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (None disables saving)")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="steps between checkpoints")
    ap.add_argument("--lr", type=float, default=1e-3,
                    help="peak learning rate")
    ap.add_argument("--finetune-thresholds", action="store_true",
                    help="fat_qat: also calibrate the per-head KV cache "
                         "thresholds and train them as log2-domain scale "
                         "factors (TQT) alongside the activation alphas")
    ap.add_argument("--log-every", type=int, default=10,
                    help="steps between loss prints")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    # training KV thresholds needs the KV observers in the qparams tree
    policy = A.QuantPolicy(kv_int8=args.finetune_thresholds)
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    spec = DP.spec_for(cfg, shape)
    hp = ST.TrainHParams(base_lr=args.lr)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    params = qparams = opt = None
    if mgr:
        tree, meta = mgr.restore_latest()
        if tree is not None:
            print(f"[train] resuming from step {meta['step']}")
            start_step = meta["step"]
            params = tree["params"]
            qparams = tree.get("qparams")
            opt = jax.tree.map(jnp.asarray, tree.get("opt"))

    if params is None:
        params = model.init(jax.random.PRNGKey(0))

    if args.mode == "fat_qat":
        if qparams is None:
            qparams = A.init_qparams(model, params, policy)
            calib = jax.jit(ST.make_calibrate_step(model, cfg, policy))
            for i, b in enumerate(
                DP.calibration_batches(spec, args.calib_batches)
            ):
                qparams = calib(params, qparams, b)
            qparams = A.finalize_calibration(
                qparams, policy,
                train_thresholds=args.finetune_thresholds)
            print(f"[train] calibrated {len(qparams)} quant points on "
                  f"{args.calib_batches} unlabeled batches")
        if opt is None:
            opt = adam_init(qparams)
        else:
            from repro.optim.adam import AdamState
            opt = AdamState(step=opt["step"], mu=opt["mu"], nu=opt["nu"])
        step_fn = jax.jit(ST.make_fat_train_step(model, cfg, policy, hp))
    else:
        if opt is None:
            opt = adam_init(params)
        else:
            from repro.optim.adam import AdamState
            opt = AdamState(step=opt["step"], mu=opt["mu"], nu=opt["nu"])
        step_fn = jax.jit(ST.make_pretrain_step(model, cfg, hp))

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = DP.make_batch(spec, step)
        if args.mode == "fat_qat":
            qparams, opt, metrics = step_fn(params, qparams, opt, batch)
        else:
            params, opt, metrics = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.5f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {
                "params": params,
                "qparams": qparams if args.mode == "fat_qat" else {},
                "opt": {"step": opt.step, "mu": opt.mu, "nu": opt.nu},
            })
            print(f"[train] checkpointed step {step + 1}")
    print("[train] done")
    return params, qparams


if __name__ == "__main__":
    main()
