"""Slot-based continuous-batching scheduler over the int8 serving engine.

The paper's frozen static thresholds (§2) are what make this possible:
K/V dequant scales never change at serve time, so a request can be
admitted into — or evicted from — a shared int8 KV cache without any
recalibration.  The cache is one fixed-shape (max_slots, cache_len)
region per layer behind the ``repro.cache.KVCache`` protocol (dense by
default, paged with ``cache_layout="paged"``); requests stream through
slots while the COMPILED executables never change:

  * admission runs the batch-1 chunked ragged prefill (one executable for
    every prompt length: tokens pad to ``prompt_cap``, the length vector
    does the ragged masking) and splices the resulting cache region into
    the free slot — a batch-axis dynamic-update-slice for the dense
    layout, a page-pool scatter + block-table row write for paged;
  * decode runs ``steps.make_slot_decode_loop`` blocks: every slot at its
    own position (vector ``cur_pos`` through the fused decode kernel),
    inactive slots masked in attention, sampling, and cache writes;
  * eviction is pure bookkeeping — a finished slot's region is dead data
    that the next admission's prefill overwrites (slots [0, prompt) and
    per-step decode writes cover every position a future mask can see).

Prefix sharing (paged layout)
-----------------------------
Because the int8 scales are frozen and request-independent, a page
written for one request is bit-valid for every other — so the paged
scheduler keeps a host-side :class:`repro.cache.PrefixStore`: after a
prompt prefills, its full pages are snapshotted into the pool's shared
region (device copies, keyed by the prompt token hash) together with the
prompt's last-position logits.  A later request with the SAME prompt
admits with ZERO prefill FLOPs: its block-table row points at the shared
pages, the partial tail page (the one decode will append into) is copied
into the slot's private page, and the first token samples from the
stored logits.  ``prefix_stats()`` / ``call_counts()`` expose the hit
and skipped-prefill counters the acceptance test pins.

Slot lifecycle (see docs/serving.md for the full diagram)::

    FREE --admit(prefill into slot region | attach shared prefix)--> ACTIVE
    ACTIVE --EOS token / gen budget / cache full--> DRAINED
    DRAINED --collect output--> FREE

Which slots are live, at which positions, with which arrival order —
and, for paged, which pages a slot's table points at — is DATA
(pos/active vectors, block tables), never SHAPE: one compiled executable
per piece serves every admission pattern (verified by the
jit-cache-miss-counting tests in tests/test_scheduler.py and
tests/test_cache.py).

The host loop (``SlotScheduler.run``) interleaves admission and decode
blocks: admit into every free slot, decode ``block_steps`` tokens, retire
finished slots, repeat until the queue drains.  Raggedness across
requests costs masked lanes within a block, not recompiles.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import (KVCache, PrefixEntry, PrefixStore, copy_pages,
                         set_table_row, splice_dense_into_pages)
from repro.core import api as A
from repro.launch import steps as ST
from repro.launch import strategies as SG


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + a generation budget."""
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_gen: int = 16           # generated-token budget (incl. first token)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list                # generated tokens (includes EOS if hit)
    finished_by: str            # 'eos' | 'budget' | 'capacity'


def _cache_map(fn, *trees):
    """tree.map over cache pytrees with ``KVCache`` objects as leaves."""
    return jax.tree.map(fn, *trees,
                        is_leaf=lambda x: isinstance(x, KVCache))


def _slot_cache_insert(cache, slot_cache, slot):
    """Splice a batch-1 cache pytree into slot ``slot`` of the batch
    cache via each layer's ``KVCache.splice_slot`` (dense layout: one
    dynamic-update-slice along the batch axis per layer; scale leaves
    come from the slot cache — frozen calibration, identical for every
    admission)."""
    slot = jnp.asarray(slot, jnp.int32)
    return _cache_map(lambda big, small: big.splice_slot(small, slot),
                      cache, slot_cache)


class SlotScheduler:
    """Continuous batching: admit/evict requests through a fixed slot batch.

    Parameters
    ----------
    model, cfg, policy, mode : the serving stack (same objects the Engine
        builds); attention-only text configs only — the same restriction
        as chunked prefill, checked at construction.
    serve_params, qparams : converted weights + finalized thresholds.
    max_slots : decode batch size (concurrent requests).
    prompt_cap : maximum prompt length; every prompt pads to this, the
        length vector masks the tail (one prefill executable).  Rounded up
        to a ``prefill_chunk`` multiple.
    gen_cap : per-slot generation headroom reserved in the cache.
    prefill_chunk : chunk size of the admission prefill scan; None picks
        ``max(8, min(16, prompt_cap))`` — the single home of that default
        (serve.py and serve_bench.py both inherit it, so the benchmark
        measures the executable the CLI serves).
    block_steps : decode-block length; admission happens at block
        boundaries, so smaller blocks = lower admission latency, larger
        blocks = fewer dispatches.
    cache_layout : "dense" (default) or "paged"; paged turns on
        prompt-prefix sharing through the page pool ("ring" is accepted
        as an alias of dense — the scheduler requires absolute slots).
    page_size : paged-layout page length (tokens per page).
    prefix_pages : size of the pool's shared prefix region, in pages
        (None = room for two full-capacity prompts).
    temperature, top_p, seed : sampling (greedy when temperature == 0).
    eos_id : generation stops for a slot when it emits this token
        (< 0 disables).
    strategy : decode strategy — a name from ``strategies.STRATEGIES``
        ("greedy" | "sample" | "speculative"), a ``DecodeStrategy``
        instance, or None (auto: sample when temperature > 0, else
        greedy — the pre-redesign behavior).  Speculative slots drain at
        different rates (1..spec_k+1 tokens per verify window); their
        raggedness is data, so the no-retrace contract is unchanged.
    spec_k, spec_ngram : speculative knobs — draft window length and the
        prompt-lookup n-gram size (both static: one compiled decode
        executable serves every draft/acceptance pattern).
    """

    def __init__(self, model, cfg, policy: A.QuantPolicy, serve_params,
                 qparams, *, mode: str = "int8", max_slots: int = 4,
                 prompt_cap: int = 64, gen_cap: int = 32,
                 prefill_chunk: int | None = None, block_steps: int = 8,
                 cache_layout: str = "dense", page_size: int = 64,
                 prefix_pages: int | None = None,
                 temperature: float = 0.0, top_p: float = 1.0,
                 eos_id: int = -1, seed: int = 0,
                 strategy=None, spec_k: int = 4, spec_ngram: int = 2):
        kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
        wins = {cfg.attn_window(i) for i in range(cfg.n_layers)}
        if kinds - {"attn", "attn_local"} or cfg.modality != "text":
            raise ValueError(
                "slot scheduler covers attention-only text stacks "
                f"(got kinds={sorted(kinds)}, modality={cfg.modality})")
        if wins != {None}:
            raise ValueError(
                "slot scheduler needs dense caches: SWA ring buffers drop "
                f"absolute slots (got windows={sorted(map(str, wins))})")
        if cache_layout == "ring":
            cache_layout = "dense"   # no windows here: ring == dense
        if cache_layout not in ("dense", "paged"):
            raise ValueError(
                f"slot scheduler cache_layout must be dense or paged, got "
                f"{cache_layout!r}")
        self.model, self.cfg = model, cfg
        self.policy, self.mode = policy, mode
        self.serve_params, self.qparams = serve_params, qparams
        self.max_slots = max_slots
        if prefill_chunk is None:
            prefill_chunk = max(8, min(16, prompt_cap))
        self.prefill_chunk = prefill_chunk
        self.prompt_cap = -(-prompt_cap // prefill_chunk) * prefill_chunk
        self.block_steps = block_steps
        self.temperature, self.top_p = temperature, top_p
        self.eos_id = eos_id
        self.cache_layout = cache_layout
        self.page_size = page_size
        if isinstance(strategy, SG.DecodeStrategy):
            self._strategy = strategy
        else:
            self._strategy = SG.make_strategy(
                strategy, model, cfg, policy, mode,
                temperature=temperature, top_p=top_p, spec_k=spec_k,
                spec_ngram=spec_ngram)
        self._emit_w = self._strategy.emit_width
        # a speculative window appends emit_width entries before the
        # accept — reserve headroom so a slot can still fill its whole
        # generation budget (greedy: emit_width == 1, zero extra)
        cache_len = self.prompt_cap + gen_cap + (self._emit_w - 1)
        if policy.use_pallas:
            # tile the cache length for the fused decode kernel — a
            # non-tiling length pad-copies the cache every step
            cache_len = -(-cache_len // 128) * 128
        if cache_layout == "paged":
            # capacity must equal n_blocks * page_size so the dense
            # batch-1 prefill result reshapes into whole pages
            cache_len = -(-cache_len // page_size) * page_size
        self.cache_len = cache_len
        self._key = jax.random.PRNGKey(seed)

        kv_int8 = bool(policy.kv_int8)
        self._kv_int8 = kv_int8
        self._n_blocks = cache_len // page_size if cache_layout == "paged" \
            else 0
        if prefix_pages is None:
            prefix_pages = 2 * self._n_blocks
        self._prefix_pages = prefix_pages if cache_layout == "paged" else 0
        # batch-1 slot cache template for admissions: DENSE regardless of
        # the batch layout — one compiled prefill executable serves every
        # layout, and the splice re-homes the tiles (prefill never donates
        # the template, so one allocation serves every admission)
        self._slot_cache0 = model.init_cache(1, cache_len, cfg.dtype,
                                             kv_int8=kv_int8,
                                             layout="dense")
        # the resident batch cache lives on the instance so page contents
        # (and the prefix store pointing into them) survive across run()s
        self._cache = model.init_cache(
            max_slots, cache_len, cfg.dtype, kv_int8=kv_int8,
            layout=cache_layout, page_size=page_size,
            extra_pages=self._prefix_pages)

        # paged bookkeeping: slot-private page rows + the shared-region
        # prefix store (host-side; device content lives in the pool)
        if cache_layout == "paged":
            nb = self._n_blocks
            self._private_rows = [
                np.arange(b * nb, (b + 1) * nb, dtype=np.int32)
                for b in range(max_slots)]
            self._prefix = PrefixStore(max_slots * nb, self._prefix_pages,
                                       page_size)
        else:
            self._private_rows = None
            self._prefix = None

        # trace counting: the counter bumps inside the to-be-jitted Python
        # body, which only runs when the jit cache misses — so the count
        # IS the number of compiled variants, measured on public jit
        # behavior (and per instance: each wrapper is a fresh closure).
        # call counts tick on every invocation (host-side): the prefix-
        # sharing acceptance pins prefill CALLS, not just traces.
        pieces = ["prefill", "decode", "insert"]
        if cache_layout == "paged":
            pieces += ["set_row", "copy_page"]
        self._trace_counts = {p: 0 for p in pieces}
        self._call_counts = {p: 0 for p in pieces}

        def counted(name, fn):
            def wrapper(*args):
                self._trace_counts[name] += 1
                return fn(*args)
            return wrapper

        self._prefill_fn = jax.jit(counted("prefill", ST.make_prefill_step(
            model, cfg, policy, mode=mode, prefill_chunk=prefill_chunk)))
        self._decode_fn = jax.jit(counted("decode", SG.make_strategy_slot_loop(
            model, cfg, policy, self._strategy, mode=mode,
            n_steps=block_steps, eos_id=eos_id)),
            donate_argnums=(3,))
        # strategy state: absolute-position -> token history for prompt
        # lookup (seeded per slot at admission); empty for stateless
        # strategies.  Host-resident between blocks, device during.
        hist_w = cache_len if self._strategy.stateful else 0
        self._hist = np.zeros((max_slots, hist_w), np.int32)
        # speculative observability: emitted tokens per verify window
        self._spec_emitted = 0
        self._spec_windows = 0
        if cache_layout == "paged":
            self._insert_fn = jax.jit(
                counted("insert", lambda c, sc, row: _cache_map(
                    lambda big, small: splice_dense_into_pages(big, small,
                                                               row),
                    c, sc)),
                donate_argnums=(0,))
            self._set_row_fn = jax.jit(
                counted("set_row", lambda c, slot, row: _cache_map(
                    lambda big: set_table_row(big, slot, row), c)),
                donate_argnums=(0,))
            self._copy_page_fn = jax.jit(
                counted("copy_page", lambda c, src, dst: _cache_map(
                    lambda big: copy_pages(big, src, dst), c)),
                donate_argnums=(0,))
        else:
            self._insert_fn = jax.jit(counted("insert", _slot_cache_insert),
                                      donate_argnums=(0,))

    # -- observability ----------------------------------------------------
    def executable_counts(self) -> dict:
        """Number of times each jitted piece was TRACED (== number of
        compiled variants) — the no-retrace contract says each stays at 1
        across every admission pattern (including shared-prefix
        admissions: block-table rows and page ids are data)."""
        return dict(self._trace_counts)

    def call_counts(self) -> dict:
        """Host-side invocation counts per piece.  ``prefill`` is the
        number of admissions that actually ran the model — a prefix-store
        hit admits without bumping it (the zero-prefill-FLOPs counter)."""
        return dict(self._call_counts)

    def prefix_stats(self) -> dict:
        """Prefix-sharing counters (paged layout; empty dict for dense)."""
        return self._prefix.stats() if self._prefix is not None else {}

    def spec_stats(self) -> dict:
        """Speculative-decoding counters (empty dict for one-token
        strategies).  ``acceptance_rate`` is accepted drafts per drafted
        token: a verify window emits 1 + accepted tokens, so the rate is
        (emitted/windows - 1) / spec_k in [0, 1]."""
        if self._emit_w == 1:
            return {}
        k = self._emit_w - 1
        wins = max(self._spec_windows, 1)
        return {
            "emitted_tokens": int(self._spec_emitted),
            "verify_windows": int(self._spec_windows),
            "draft_k": k,
            "tokens_per_window": self._spec_emitted / wins,
            "acceptance_rate": max(self._spec_emitted / wins - 1.0, 0.0) / k,
        }

    # -- counted invocation helpers ---------------------------------------
    def _prefill(self, *args):
        self._call_counts["prefill"] += 1
        return self._prefill_fn(*args)

    def _decode(self, *args):
        self._call_counts["decode"] += 1
        return self._decode_fn(*args)

    # -- one serving session ----------------------------------------------
    def run(self, requests: Iterable[Request],
            max_blocks: Optional[int] = None) -> list[Completion]:
        """Serve ``requests`` to completion through the slot batch.

        Admission is streaming: requests queue up and enter whenever a
        slot frees, so the number of concurrent residents never exceeds
        ``max_slots`` while raggedness (arrival time, prompt length,
        budget) stays data.  Returns completions in finish order.
        ``max_blocks`` bounds the decode blocks (None = drain fully).
        """
        queue = deque(requests)
        B = self.max_slots
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        last_tok = np.zeros((B,), np.int32)
        slot_req: list[Optional[Request]] = [None] * B
        slot_out: list[list] = [[] for _ in range(B)]
        done: list[Completion] = []
        n_blocks = 0

        def retire(slot: int, why: str):
            req = slot_req[slot]
            done.append(Completion(req.rid, len(req.tokens),
                                   slot_out[slot], why))
            slot_req[slot] = None
            slot_out[slot] = []
            active[slot] = False
            if self._prefix is not None:
                self._prefix.release(slot)

        while queue or active.any():
            # -- admission: fill every free slot from the queue ------------
            for slot in range(B):
                if slot_req[slot] is not None or not queue:
                    continue
                req = queue.popleft()
                t0 = self._admit(slot, req)
                if self._strategy.stateful:
                    # seed the prompt-lookup history: absolute position ->
                    # token, prompt then the pending first generation
                    L = len(req.tokens)
                    self._hist[slot] = 0
                    self._hist[slot, :L] = np.asarray(req.tokens, np.int32)
                    if L < self._hist.shape[1]:
                        self._hist[slot, L] = int(t0)
                slot_req[slot] = req
                slot_out[slot] = [int(t0)]
                pos[slot] = len(req.tokens)
                last_tok[slot] = int(t0)
                active[slot] = True
                if self.eos_id >= 0 and int(t0) == self.eos_id:
                    retire(slot, "eos")
                elif req.max_gen <= 1:
                    retire(slot, "budget")
            if not active.any():
                continue

            # -- one decode block over the slot batch ----------------------
            toks, emitted, self._cache, pos_d, active_d, self._key, hist = \
                self._decode(
                    self.serve_params, self.qparams, jnp.asarray(last_tok),
                    self._cache, jnp.asarray(pos), jnp.asarray(active),
                    self._key, jnp.asarray(self._hist))
            toks = np.asarray(toks)
            emitted = np.asarray(emitted)
            pos_new = np.asarray(pos_d)
            active_new = np.asarray(active_d)
            # host copy: admission mutates rows in place (np.asarray of a
            # device buffer is read-only)
            self._hist = np.array(hist)
            if self._emit_w > 1:
                # a window with any emission ran a live verify pass
                win = emitted.reshape(B, self.block_steps, self._emit_w)
                self._spec_windows += int(win.any(-1).sum())
                self._spec_emitted += int(emitted.sum())

            # -- collect emissions, retire finished slots ------------------
            # emission lanes are RAGGED within a speculative window (a
            # partial accept leaves un-emitted tail lanes, then the next
            # window emits again) — skip gaps instead of stopping at one
            for slot in range(B):
                req = slot_req[slot]
                if req is None or not active[slot]:
                    continue
                for i in range(self.block_steps * self._emit_w):
                    if len(slot_out[slot]) >= req.max_gen:
                        break
                    if not emitted[slot, i]:
                        continue
                    slot_out[slot].append(int(toks[slot, i]))
                pos[slot] = pos_new[slot]
                last_tok[slot] = (slot_out[slot][-1]
                                  if slot_out[slot] else last_tok[slot])
                # finish reason from what was actually COLLECTED: an EOS
                # beyond the budget cut was never part of the output, so
                # that request finished by budget, not eos — and a
                # device-side freeze without a collected EOS and with
                # budget to spare can only be the capacity guard
                hit_eos = (self.eos_id >= 0 and bool(slot_out[slot])
                           and slot_out[slot][-1] == self.eos_id)
                budget_done = len(slot_out[slot]) >= req.max_gen
                if hit_eos:
                    retire(slot, "eos")
                elif budget_done:
                    retire(slot, "budget")
                elif not active_new[slot]:
                    retire(slot, "capacity")
                else:
                    active[slot] = active_new[slot]
            n_blocks += 1
            if max_blocks is not None and n_blocks >= max_blocks:
                break
        # no resident remains (or the run was cut): drop any prefix-store
        # references this run's slots held so unused entries stay evictable
        if self._prefix is not None:
            for slot in range(B):
                self._prefix.release(slot)
        return done

    # -- admission ---------------------------------------------------------
    def _check(self, req: Request):
        L = int(len(req.tokens))
        if L > self.prompt_cap:
            raise ValueError(
                f"request {req.rid}: prompt length {L} exceeds prompt_cap "
                f"{self.prompt_cap}")
        if L < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_gen < 1:
            # admission always yields the prefill's first token, so a
            # 0-token budget cannot be honored
            raise ValueError(
                f"request {req.rid}: max_gen must be >= 1 (the first "
                "token is sampled at admission)")
        return L

    def _sample_t0(self, logits) -> int:
        self._key, sub = jax.random.split(self._key)
        t0 = ST.sample_tokens(jnp.asarray(logits)[:, -1, :], sub,
                              temperature=self.temperature, top_p=self.top_p)
        return int(t0[0])

    def _admit(self, slot: int, req: Request) -> int:
        """Admit ``req`` into ``slot`` and return its first generated
        token.  Dense: chunked-prefill the prompt into the batch-1
        template and splice it into the slot's region.  Paged: try the
        prefix store first — a full-prompt hit attaches the shared pages
        (block-table row write + one tail-page copy) and samples from the
        stored logits, running ZERO prefill FLOPs; a miss prefills,
        scatters into the slot's private pages, and registers the prompt
        for future sharers."""
        L = self._check(req)
        key = tuple(int(t) for t in np.asarray(req.tokens))

        if self._prefix is not None:
            entry = self._prefix.lookup(key, slot)
            if entry is not None:
                return self._attach_prefix(slot, entry)

        toks = np.zeros((1, self.prompt_cap), np.int32)
        toks[0, :L] = np.asarray(req.tokens, np.int32)
        lengths = jnp.asarray([L], jnp.int32)
        logits, slot_cache = self._prefill(
            self.serve_params, self.qparams, {"tokens": jnp.asarray(toks)},
            self._slot_cache0, lengths)
        if self._prefix is None:
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(slot, jnp.int32))
        else:
            row = self._private_rows[slot]
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(row))
            self._set_row(slot, row)
            self._register_prefix(key, L, row, logits)
        return self._sample_t0(logits)

    # -- paged plumbing ----------------------------------------------------
    def _set_row(self, slot: int, row: np.ndarray):
        self._call_counts["set_row"] += 1
        self._cache = self._set_row_fn(self._cache,
                                       jnp.asarray(slot, jnp.int32),
                                       jnp.asarray(row, jnp.int32))

    def _copy_pages(self, pairs: Sequence[tuple]):
        """One fixed-shape copy dispatch for up to n_blocks (src, dst)
        page pairs: unused entries repeat the first pair (duplicate dst
        with identical src — a deterministic re-write), so every
        registration/attach shares ONE compiled executable regardless of
        how many pages the prompt spans."""
        if not pairs:
            return
        self._call_counts["copy_page"] += 1
        padded = list(pairs) + [pairs[0]] * (self._n_blocks - len(pairs))
        src = jnp.asarray([p[0] for p in padded], jnp.int32)
        dst = jnp.asarray([p[1] for p in padded], jnp.int32)
        self._cache = self._copy_page_fn(self._cache, src, dst)

    def _register_prefix(self, key, L, private_row, logits):
        """Snapshot the freshly-prefilled prompt pages into the shared
        region (device page copies — no model FLOPs) and store the
        last-position logits so a future identical prompt skips prefill
        entirely.  Opportunistic: silently skipped when the shared region
        is full of in-use entries."""
        alloc = self._prefix.reserve(key, L)
        if alloc is None:
            return
        pages, tail = alloc
        n_full = len(pages)
        pairs = [(int(private_row[j]), int(dst))
                 for j, dst in enumerate(pages)]
        if tail is not None:
            pairs.append((int(private_row[n_full]), int(tail)))
        self._copy_pages(pairs)
        self._prefix.register(key, PrefixEntry(
            pages=pages, tail_page=tail, length=L,
            logits=np.asarray(logits)))

    def _attach_prefix(self, slot: int, entry: PrefixEntry) -> int:
        """Full-prompt hit: point the slot's table row at the shared
        pages; the partial tail page (decode's first append target) is
        copied into the slot's private page so shared pages stay
        immutable.  No prefill executable runs."""
        row = self._private_rows[slot].copy()
        n_full = len(entry.pages)
        row[:n_full] = entry.pages
        self._set_row(slot, row)
        if entry.tail_page is not None:
            self._copy_pages([(int(entry.tail_page),
                               int(self._private_rows[slot][n_full]))])
        return self._sample_t0(entry.logits)
