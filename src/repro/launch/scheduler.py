"""Slot-based continuous-batching scheduler over the int8 serving engine.

The paper's frozen static thresholds (§2) are what make this possible:
K/V dequant scales never change at serve time, so a request can be
admitted into — or evicted from — a shared int8 KV cache without any
recalibration.  The cache is one fixed-shape (max_slots, cache_len)
region per layer behind the ``repro.cache.KVCache`` protocol (dense by
default, paged with ``cache_layout="paged"``); requests stream through
slots while the COMPILED executables never change:

  * admission runs the batch-1 chunked ragged prefill (one executable for
    every prompt length: tokens pad to ``prompt_cap``, the length vector
    does the ragged masking) and splices the resulting cache region into
    the free slot — a batch-axis dynamic-update-slice for the dense
    layout, a page-pool scatter + block-table row write for paged;
  * decode runs ``steps.make_slot_decode_loop`` blocks: every slot at its
    own position (vector ``cur_pos`` through the fused decode kernel),
    inactive slots masked in attention, sampling, and cache writes;
  * eviction is pure bookkeeping — a finished slot's region is dead data
    that the next admission's prefill overwrites (slots [0, prompt) and
    per-step decode writes cover every position a future mask can see).

Prefix sharing (paged layout)
-----------------------------
Because the int8 scales are frozen and request-independent, a page
written for one request is bit-valid for every other — so the paged
scheduler keeps a host-side :class:`repro.cache.PrefixStore`: after a
prompt prefills, its full pages are snapshotted into the pool's shared
region (device copies, keyed by the prompt token hash) together with the
prompt's last-position logits.  A later request with the SAME prompt
admits with ZERO prefill FLOPs: its block-table row points at the shared
pages, the partial tail page (the one decode will append into) is copied
into the slot's private page, and the first token samples from the
stored logits.  ``prefix_stats()`` / ``call_counts()`` expose the hit
and skipped-prefill counters the acceptance test pins.

Resilience (fault isolation, deadlines, preemption, degradation)
----------------------------------------------------------------
``run()`` never aborts because one request is bad.  Every request
retires with a terminal ``Completion.status``:

    ok         finished normally (``finished_by``: eos | budget | capacity)
    rejected   failed admission validation (never touched the device)
    failed     prefill raised, or produced/decoded non-finite logits
    timeout    missed its ``deadline_ms`` (resident or still queued)
    preempted  evicted for a higher-priority request and the run ended
               before it could be re-admitted
    shed       dropped by the bounded admission queue under overload

Deadlines are checked at block boundaries against a per-request arrival
time.  A higher-priority waiter preempts the lowest-priority resumable
resident: the victim's host state (tokens generated so far, sampling
key, position) is parked on a re-admit queue and its slot is handed
over; re-admission rebuilds the victim's KV state with ONE ragged
prefill over prompt + generated-so-far tokens (the ``resume``
executable) — cheap and bit-valid precisely because the paper's frozen
thresholds make int8 cache state a pure function of the token sequence.
Under the paged layout the victim's shared-prefix references are
released and its block-table row is reclaimed onto its private pages
before the new resident moves in.

Per-request PRNG keys (``fold_in(seed_key, rid)``, advanced only on a
request's own active steps) make sampled outputs a function of (seed,
rid, tokens emitted) — independent of arrival order, slot placement,
and preemption.

All degraded paths are driveable deterministically through a
:class:`repro.launch.faults.FaultPlan` (see launch/faults.py); injection
is host-driven or data-driven, so faulted and clean runs share the same
compiled executables.  ``health_stats()`` exposes per-status and
per-event counters.

Slot lifecycle (see docs/serving.md for the full diagram)::

    FREE --admit(prefill into slot region | attach shared prefix)--> ACTIVE
    ACTIVE --EOS token / gen budget / cache full / deadline / NaN--> DRAINED
    ACTIVE --preempted (park host state, free the slot)--> PARKED
    PARKED --re-admit (one ``resume`` ragged prefill)--> ACTIVE
    DRAINED --collect output + terminal status--> FREE

Which slots are live, at which positions, with which arrival order —
and, for paged, which pages a slot's table points at — is DATA
(pos/active vectors, block tables), never SHAPE: one compiled executable
per piece serves every admission pattern (verified by the
jit-cache-miss-counting tests in tests/test_scheduler.py and
tests/test_cache.py).

The host loop (``SlotScheduler.run``) interleaves admission and decode
blocks: admit into every free slot, decode ``block_steps`` tokens, retire
finished slots, repeat until the queue drains.  Raggedness across
requests costs masked lanes within a block, not recompiles.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import (KVCache, PrefixEntry, PrefixStore, copy_pages,
                         set_table_row, splice_dense_into_pages)
from repro.checkpoint.manager import CheckpointManager
from repro.core import api as A
from repro.launch import steps as ST
from repro.launch import strategies as SG
from repro.launch.faults import FaultPlan, InjectedFault, SimulatedCrash
from repro.launch.journal import (RequestJournal, completion_from_dict,
                                  completion_to_dict, request_from_dict,
                                  request_to_dict)


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + a generation budget.

    ``priority`` orders admission and picks preemption victims (higher
    wins; residents only yield to strictly higher waiters).
    ``deadline_ms`` is a completion deadline relative to ``arrive_ms``
    (None = none).  ``arrive_ms`` places the request on the run's clock
    (wall ms from run start, or virtual ms under a fault plan's
    ``ms_per_block``); requests are invisible to the scheduler before
    they arrive."""
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_gen: int = 16           # generated-token budget (incl. first token)
    priority: int = 0
    deadline_ms: Optional[float] = None
    arrive_ms: float = 0.0


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list                # generated tokens (includes EOS if hit)
    finished_by: str            # 'eos' | 'budget' | 'capacity' when ok,
                                # else mirrors ``status``
    status: str = "ok"          # ok | rejected | timeout | preempted |
                                # shed | failed
    reason: Optional[str] = None    # human-readable failure detail


@dataclasses.dataclass
class _Parked:
    """A preempted resident awaiting re-admission: everything needed to
    rebuild its device state (the tokens) plus the host state that must
    survive verbatim (sampling key carry, decode-step count)."""
    req: Request
    out: list                   # generated so far (incl. pending token)
    key: np.ndarray             # (2,) uint32 per-request key carry
    steps: int                  # decode scan steps consumed so far
    recovered: bool = False     # parked by crash recovery, not preemption


_STATUSES = ("ok", "rejected", "timeout", "preempted", "shed", "failed")
_HEALTH_KEYS = _STATUSES + (
    "eos", "budget", "capacity",            # ok retirement causes
    "preemptions", "readmits", "deadline_misses", "prefix_exhausted",
    "recoveries", "replayed_tokens")        # durability counters


@dataclasses.dataclass
class _RunState:
    """Everything ``run()`` used to keep in closure-local variables,
    hoisted into one object so a decode-block boundary can be snapshotted
    (``save_state``) and a crashed run can be rebuilt (``recover``)
    without the driver loop changing shape."""
    pos: np.ndarray             # (B,) int32 absolute positions
    active: np.ndarray          # (B,) bool
    last_tok: np.ndarray        # (B,) int32 pending token per slot
    slot_req: list              # per-slot Request (None = free)
    slot_out: list              # per-slot generated tokens (incl. pending)
    slot_steps: list            # per-slot decode scan steps consumed
    done: list                  # Completions, finish order
    n_blocks: int               # committed decode-block boundaries
    arrivals: deque             # not-yet-arrived Requests (by arrive_ms)
    pending: deque              # arrived, waiting for a slot
    readmit: deque              # _Parked preemption victims
    vclock: float               # virtual ms when plan.ms_per_block > 0
    t_start: float              # wall-clock run origin


def _cache_map(fn, *trees):
    """tree.map over cache pytrees with ``KVCache`` objects as leaves."""
    return jax.tree.map(fn, *trees,
                        is_leaf=lambda x: isinstance(x, KVCache))


def _slot_cache_insert(cache, slot_cache, slot):
    """Splice a batch-1 cache pytree into slot ``slot`` of the batch
    cache via each layer's ``KVCache.splice_slot`` (dense layout: one
    dynamic-update-slice along the batch axis per layer; scale leaves
    come from the slot cache — frozen calibration, identical for every
    admission)."""
    slot = jnp.asarray(slot, jnp.int32)
    return _cache_map(lambda big, small: big.splice_slot(small, slot),
                      cache, slot_cache)


class SlotScheduler:
    """Continuous batching: admit/evict requests through a fixed slot batch.

    Parameters
    ----------
    model, cfg, policy, mode : the serving stack (same objects the Engine
        builds); attention-only text configs only — the same restriction
        as chunked prefill, checked at construction.
    serve_params, qparams : converted weights + finalized thresholds.
    max_slots : decode batch size (concurrent requests).
    prompt_cap : maximum prompt length; every prompt pads to this, the
        length vector masks the tail (one prefill executable).  Rounded up
        to a ``prefill_chunk`` multiple.
    gen_cap : per-slot generation headroom reserved in the cache.
    prefill_chunk : chunk size of the admission prefill scan; None picks
        ``max(8, min(16, prompt_cap))`` — the single home of that default
        (serve.py and serve_bench.py both inherit it, so the benchmark
        measures the executable the CLI serves).
    block_steps : decode-block length; admission happens at block
        boundaries, so smaller blocks = lower admission latency, larger
        blocks = fewer dispatches.
    cache_layout : "dense" (default) or "paged"; paged turns on
        prompt-prefix sharing through the page pool ("ring" is accepted
        as an alias of dense — the scheduler requires absolute slots).
    page_size : paged-layout page length (tokens per page).
    prefix_pages : size of the pool's shared prefix region, in pages
        (None = room for two full-capacity prompts).
    temperature, top_p, seed : sampling (greedy when temperature == 0).
        Sampled requests draw from per-request key streams
        (``fold_in(PRNGKey(seed), rid)``), so outputs are reproducible
        across arrival orders and preemptions.
    eos_id : generation stops for a slot when it emits this token
        (< 0 disables).
    strategy : decode strategy — a name from ``strategies.STRATEGIES``
        ("greedy" | "sample" | "speculative"), a ``DecodeStrategy``
        instance, or None (auto: sample when temperature > 0, else
        greedy — the pre-redesign behavior).  Speculative slots drain at
        different rates (1..spec_k+1 tokens per verify window); their
        raggedness is data, so the no-retrace contract is unchanged.
    spec_k, spec_ngram : speculative knobs — draft window length and the
        prompt-lookup n-gram size (both static: one compiled decode
        executable serves every draft/acceptance pattern).
    queue_cap : bound on the admission queue (None = unbounded).  When
        full, ``shed_policy`` decides: "shed" retires the newest arrival
        immediately with status 'shed'; "block" leaves arrivals waiting
        upstream until the queue drains.
    shed_policy : "shed" (default) or "block" — see ``queue_cap``.
    fault_plan : a :class:`repro.launch.faults.FaultPlan` injecting
        deterministic faults (and/or the virtual clock); None = no
        faults, wall clock.  ``crash=(k, ...)`` raises
        :class:`repro.launch.faults.SimulatedCrash` after the k-th
        decode-block boundary commits — the recovery tests' crash point.
    journal : a :class:`repro.launch.journal.RequestJournal` (or a path
        string) enabling the write-ahead request journal: admissions,
        per-boundary progress, and retirements are journaled, and
        ``recover()`` on a FRESH scheduler replays the journal to
        continue a crashed run bit-identically (journal-replay mode —
        no device state is ever saved).
    snapshot_every : > 0 writes a full state snapshot (``save_state``)
        every N decode-block boundaries through a
        ``repro.checkpoint.CheckpointManager`` at ``snapshot_dir``
        (full-snapshot mode); requires ``snapshot_dir``.
    snapshot_dir : checkpoint directory for snapshots; setting it alone
        enables on-demand ``save_state()``/``load_state()`` without the
        periodic cadence.
    """

    def __init__(self, model, cfg, policy: A.QuantPolicy, serve_params,
                 qparams, *, mode: str = "int8", max_slots: int = 4,
                 prompt_cap: int = 64, gen_cap: int = 32,
                 prefill_chunk: int | None = None, block_steps: int = 8,
                 cache_layout: str = "dense", page_size: int = 64,
                 prefix_pages: int | None = None,
                 temperature: float = 0.0, top_p: float = 1.0,
                 eos_id: int = -1, seed: int = 0,
                 strategy=None, spec_k: int = 4, spec_ngram: int = 2,
                 queue_cap: int | None = None, shed_policy: str = "shed",
                 fault_plan: FaultPlan | None = None,
                 journal=None, snapshot_every: int = 0,
                 snapshot_dir: str | None = None):
        kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
        wins = {cfg.attn_window(i) for i in range(cfg.n_layers)}
        if kinds - {"attn", "attn_local"} or cfg.modality != "text":
            raise ValueError(
                "slot scheduler covers attention-only text stacks "
                f"(got kinds={sorted(kinds)}, modality={cfg.modality})")
        if wins != {None}:
            raise ValueError(
                "slot scheduler needs dense caches: SWA ring buffers drop "
                f"absolute slots (got windows={sorted(map(str, wins))})")
        if cache_layout == "ring":
            cache_layout = "dense"   # no windows here: ring == dense
        if cache_layout not in ("dense", "paged"):
            raise ValueError(
                f"slot scheduler cache_layout must be dense or paged, got "
                f"{cache_layout!r}")
        if shed_policy not in ("shed", "block"):
            raise ValueError(
                f"shed_policy must be 'shed' or 'block', got "
                f"{shed_policy!r}")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap}")
        if snapshot_every < 0:
            raise ValueError(
                f"snapshot_every must be >= 0, got {snapshot_every}")
        if snapshot_every > 0 and snapshot_dir is None:
            raise ValueError(
                "snapshot_every > 0 needs a snapshot_dir to write to")
        self.model, self.cfg = model, cfg
        self.policy, self.mode = policy, mode
        self.serve_params, self.qparams = serve_params, qparams
        self.max_slots = max_slots
        if prefill_chunk is None:
            prefill_chunk = max(8, min(16, prompt_cap))
        self.prefill_chunk = prefill_chunk
        self.prompt_cap = -(-prompt_cap // prefill_chunk) * prefill_chunk
        self.block_steps = block_steps
        self.temperature, self.top_p = temperature, top_p
        self.eos_id = eos_id
        self.cache_layout = cache_layout
        self.page_size = page_size
        self.queue_cap = queue_cap
        self.shed_policy = shed_policy
        self._plan = fault_plan if fault_plan is not None else FaultPlan()
        self._seed = int(seed)
        # durability plumbing: write-ahead journal and/or full snapshots
        if isinstance(journal, (str, os.PathLike)):
            journal = RequestJournal(journal)
        self._journal: RequestJournal | None = journal
        self._snapshot_every = int(snapshot_every)
        self._snap_mgr = (CheckpointManager(snapshot_dir, keep=3)
                          if snapshot_dir is not None else None)
        self._snap_step = 0         # monotonic snapshot counter
        self._rs: _RunState | None = None   # live run state (None = idle)
        self._epoch = 0             # journal epoch counter
        if isinstance(strategy, SG.DecodeStrategy):
            self._strategy = strategy
        else:
            self._strategy = SG.make_strategy(
                strategy, model, cfg, policy, mode,
                temperature=temperature, top_p=top_p, spec_k=spec_k,
                spec_ngram=spec_ngram)
        self._emit_w = self._strategy.emit_width
        # a speculative window appends emit_width entries before the
        # accept — reserve headroom so a slot can still fill its whole
        # generation budget (greedy: emit_width == 1, zero extra)
        cache_len = self.prompt_cap + gen_cap + (self._emit_w - 1)
        if policy.use_pallas:
            # tile the cache length for the fused decode kernel — a
            # non-tiling length pad-copies the cache every step
            cache_len = -(-cache_len // 128) * 128
        if cache_layout == "paged":
            # capacity must equal n_blocks * page_size so the dense
            # batch-1 prefill result reshapes into whole pages
            cache_len = -(-cache_len // page_size) * page_size
        self.cache_len = cache_len
        # per-request sampling keys: each admission folds its rid into
        # the seed key, so a request's stream is independent of arrival
        # order and slot placement; the carried halves live per slot
        self._base_key = jax.random.PRNGKey(seed)
        self._slot_keys = np.zeros((max_slots, 2), np.uint32)
        # re-admission ragged prefill covers positions [0, resume_cap):
        # chunked prefill writes whole chunks, so the widest resumable
        # state is the largest chunk multiple that fits the cache
        self._resume_cap = (cache_len // prefill_chunk) * prefill_chunk

        kv_int8 = bool(policy.kv_int8)
        self._kv_int8 = kv_int8
        self._n_blocks = cache_len // page_size if cache_layout == "paged" \
            else 0
        if prefix_pages is None:
            prefix_pages = 2 * self._n_blocks
        self._prefix_pages = prefix_pages if cache_layout == "paged" else 0
        # batch-1 slot cache template for admissions: DENSE regardless of
        # the batch layout — one compiled prefill executable serves every
        # layout, and the splice re-homes the tiles (prefill never donates
        # the template, so one allocation serves every admission)
        self._slot_cache0 = model.init_cache(1, cache_len, cfg.dtype,
                                             kv_int8=kv_int8,
                                             layout="dense",
                                             kv_bits=policy.kv_bits)
        # the resident batch cache lives on the instance so page contents
        # (and the prefix store pointing into them) survive across run()s
        self._cache = model.init_cache(
            max_slots, cache_len, cfg.dtype, kv_int8=kv_int8,
            layout=cache_layout, page_size=page_size,
            extra_pages=self._prefix_pages, kv_bits=policy.kv_bits)

        # paged bookkeeping: slot-private page rows + the shared-region
        # prefix store (host-side; device content lives in the pool)
        if cache_layout == "paged":
            nb = self._n_blocks
            self._private_rows = [
                np.arange(b * nb, (b + 1) * nb, dtype=np.int32)
                for b in range(max_slots)]
            self._prefix = PrefixStore(max_slots * nb, self._prefix_pages,
                                       page_size)
        else:
            self._private_rows = None
            self._prefix = None

        # trace counting: the counter bumps inside the to-be-jitted Python
        # body, which only runs when the jit cache misses — so the count
        # IS the number of compiled variants, measured on public jit
        # behavior (and per instance: each wrapper is a fresh closure).
        # call counts tick on every invocation (host-side): the prefix-
        # sharing acceptance pins prefill CALLS, not just traces.
        pieces = ["prefill", "decode", "insert", "resume"]
        if cache_layout == "paged":
            pieces += ["set_row", "copy_page"]
        self._trace_counts = {p: 0 for p in pieces}
        self._call_counts = {p: 0 for p in pieces}
        self._health = {k: 0 for k in _HEALTH_KEYS}

        def counted(name, fn):
            def wrapper(*args):
                self._trace_counts[name] += 1
                return fn(*args)
            return wrapper

        self._prefill_fn = jax.jit(counted("prefill", ST.make_prefill_step(
            model, cfg, policy, mode=mode, prefill_chunk=prefill_chunk)))
        # the re-admission prefill is the same maker at the resume buffer
        # width — its own jitted piece so a preempting run still leaves
        # "prefill" at one trace (widths are shape)
        self._resume_fn = jax.jit(counted("resume", ST.make_prefill_step(
            model, cfg, policy, mode=mode, prefill_chunk=prefill_chunk)))
        self._decode_fn = jax.jit(counted("decode", SG.make_strategy_slot_loop(
            model, cfg, policy, self._strategy, mode=mode,
            n_steps=block_steps, eos_id=eos_id)),
            donate_argnums=(3,))
        # strategy state: absolute-position -> token history for prompt
        # lookup (seeded per slot at admission); empty for stateless
        # strategies.  Host-resident between blocks, device during.
        hist_w = cache_len if self._strategy.stateful else 0
        self._hist = np.zeros((max_slots, hist_w), np.int32)
        # speculative observability: emitted tokens per verify window
        self._spec_emitted = 0
        self._spec_windows = 0
        if cache_layout == "paged":
            self._insert_fn = jax.jit(
                counted("insert", lambda c, sc, row: _cache_map(
                    lambda big, small: splice_dense_into_pages(big, small,
                                                               row),
                    c, sc)),
                donate_argnums=(0,))
            self._set_row_fn = jax.jit(
                counted("set_row", lambda c, slot, row: _cache_map(
                    lambda big: set_table_row(big, slot, row), c)),
                donate_argnums=(0,))
            self._copy_page_fn = jax.jit(
                counted("copy_page", lambda c, src, dst: _cache_map(
                    lambda big: copy_pages(big, src, dst), c)),
                donate_argnums=(0,))
        else:
            self._insert_fn = jax.jit(counted("insert", _slot_cache_insert),
                                      donate_argnums=(0,))

    # -- observability ----------------------------------------------------
    def executable_counts(self) -> dict:
        """Number of times each jitted piece was TRACED (== number of
        compiled variants) — the no-retrace contract says each stays at 1
        across every admission pattern AND every fault plan (including
        shared-prefix admissions and preemption re-admissions: block-table
        rows, masks, and nan-step vectors are data).  ``resume`` stays 0
        until a preemption actually re-admits."""
        return dict(self._trace_counts)

    def call_counts(self) -> dict:
        """Host-side invocation counts per piece.  ``prefill`` is the
        number of admissions that actually ran the model — a prefix-store
        hit admits without bumping it (the zero-prefill-FLOPs counter);
        ``resume`` counts preemption re-admissions."""
        return dict(self._call_counts)

    def check_budgets(self):
        """The no-retrace contract as findings: this scheduler's live
        trace counts against the declared per-piece budgets
        (repro.analysis.budgets.SCHEDULER_BUDGETS).  Empty list == within
        budget; the analysis CI lane runs this after a real mixed-
        admission session, and operators can call it on a production
        scheduler at any point."""
        from repro.analysis.budgets import check_executable_budgets
        return check_executable_budgets(self.executable_counts(),
                                        entry_point="scheduler")

    def prefix_stats(self) -> dict:
        """Prefix-sharing counters (paged layout; empty dict for dense)."""
        return self._prefix.stats() if self._prefix is not None else {}

    def health_stats(self) -> dict:
        """Resilience counters: terminal statuses (``ok``/``rejected``/
        ``timeout``/``preempted``/``shed``/``failed``), ok retirement
        causes (``eos``/``budget``/``capacity``), events (``preemptions``,
        ``readmits``, ``deadline_misses``, ``prefix_exhausted`` — prefix
        registrations skipped because the shared pool had no evictable
        pages), and durability counters (``recoveries`` — completed
        ``recover()`` calls; ``replayed_tokens`` — prompt+generated
        tokens re-prefilled through the ``resume`` executable during
        journal-replay recovery).

        Semantics are CUMULATIVE over the scheduler's lifetime: counters
        accumulate across every ``run()``/``recover()`` on this instance
        and are never reset implicitly (pinned by
        tests/test_recovery.py).  Call :meth:`reset_health` for a
        per-window view; snapshot restore (``load_state``) REPLACES the
        counters with the snapshot's, journal recovery re-derives
        terminal-status counts from the replayed retirements."""
        return dict(self._health)

    def reset_health(self):
        """Zero the cumulative ``health_stats`` counters (explicit reset
        is the only reset — see ``health_stats`` semantics)."""
        self._health = {k: 0 for k in _HEALTH_KEYS}

    def spec_stats(self) -> dict:
        """Speculative-decoding counters (empty dict for one-token
        strategies).  ``acceptance_rate`` is accepted drafts per drafted
        token: a verify window emits 1 + accepted tokens, so the rate is
        (emitted/windows - 1) / spec_k in [0, 1]."""
        if self._emit_w == 1:
            return {}
        k = self._emit_w - 1
        wins = max(self._spec_windows, 1)
        return {
            "emitted_tokens": int(self._spec_emitted),
            "verify_windows": int(self._spec_windows),
            "draft_k": k,
            "tokens_per_window": self._spec_emitted / wins,
            "acceptance_rate": max(self._spec_emitted / wins - 1.0, 0.0) / k,
        }

    # -- counted invocation helpers ---------------------------------------
    def _prefill(self, *args):
        self._call_counts["prefill"] += 1
        return self._prefill_fn(*args)

    def _resume(self, *args):
        self._call_counts["resume"] += 1
        return self._resume_fn(*args)

    def _decode(self, *args):
        self._call_counts["decode"] += 1
        return self._decode_fn(*args)

    # -- one serving session ----------------------------------------------
    def _fresh_rs(self, requests: Iterable[Request]) -> _RunState:
        B = self.max_slots
        return _RunState(
            pos=np.zeros((B,), np.int32), active=np.zeros((B,), bool),
            last_tok=np.zeros((B,), np.int32), slot_req=[None] * B,
            slot_out=[[] for _ in range(B)], slot_steps=[0] * B, done=[],
            n_blocks=0,
            arrivals=deque(sorted(requests, key=lambda r: r.arrive_ms)),
            pending=deque(), readmit=deque(), vclock=0.0,
            t_start=time.monotonic())

    def _knobs(self) -> dict:
        """The scheduler knobs a recovered run must match for replay to
        be bit-valid (recorded in journal ``begin`` records and snapshot
        metadata; checked by ``recover``/``load_state``)."""
        return {
            "max_slots": self.max_slots, "prompt_cap": self.prompt_cap,
            "block_steps": self.block_steps,
            "cache_layout": self.cache_layout,
            "page_size": (self.page_size if self.cache_layout == "paged"
                          else None),
            "cache_len": self.cache_len,
            "prefill_chunk": self.prefill_chunk, "mode": self.mode,
            "temperature": self.temperature, "top_p": self.top_p,
            "seed": self._seed, "eos_id": self.eos_id,
            "emit_width": self._emit_w,
        }

    def _check_knobs(self, knobs: dict):
        want = self._knobs()
        got = {k: knobs.get(k) for k in want}
        bad = {k: (got[k], want[k]) for k in want if got[k] != want[k]}
        if bad:
            raise ValueError(
                "recovery scheduler knobs do not match the crashed run's "
                "(replay would not be bit-valid): " +
                ", ".join(f"{k}: saved={s!r} vs live={l!r}"
                          for k, (s, l) in sorted(bad.items())))

    def run(self, requests: Iterable[Request],
            max_blocks: Optional[int] = None) -> list[Completion]:
        """Serve ``requests`` to completion through the slot batch.

        Admission is streaming: requests become visible at their
        ``arrive_ms``, wait in a (optionally bounded) pending queue, and
        enter whenever a slot frees — highest priority first, FIFO within
        a priority, parked re-admissions preferred on ties.  The number
        of concurrent residents never exceeds ``max_slots`` while
        raggedness (arrival time, prompt length, budget) stays data.

        Every request retires with a terminal ``status`` (see the module
        docstring's taxonomy); a bad request never aborts the run.
        Returns completions in finish order.  ``max_blocks`` bounds the
        decode blocks (None = drain fully); parked preemption victims
        still waiting at the cut retire as 'preempted'.

        With a ``journal``, the run is write-ahead journaled (a new epoch
        per run); a ``FaultPlan.crash`` boundary raises
        :class:`~repro.launch.faults.SimulatedCrash` out of this method —
        recover on a FRESH scheduler via :meth:`recover` (journal replay)
        or :meth:`load_state` + :meth:`resume_run` (snapshot).
        """
        rs = self._fresh_rs(requests)
        self._rs = rs
        if self._journal is not None:
            self._epoch = max(self._epoch,
                              self._journal.last_epoch()) + 1
            self._journal.begin(self._epoch, self._knobs())
            for req in rs.arrivals:
                self._journal.enqueue(req)
        return self._drive(max_blocks)

    def resume_run(self,
                   max_blocks: Optional[int] = None) -> list[Completion]:
        """Continue driving a restored run state (``load_state`` or a
        prior interrupted drive) to completion.  Returns ALL completions
        of the logical run — the pre-crash ones restored with the state
        plus everything finished after.  ``max_blocks`` counts TOTAL
        decode blocks of the logical run (it compares against the
        restored block counter)."""
        if self._rs is None:
            raise ValueError(
                "no run state to resume (call load_state(), recover(), "
                "or run() first)")
        return self._drive(max_blocks)

    def _drive(self, max_blocks: Optional[int] = None) -> list[Completion]:
        """The scheduler host loop over ``self._rs`` (see ``run``)."""
        plan = self._plan
        B = self.max_slots
        rs = self._rs

        def now_ms() -> float:
            if plan.ms_per_block > 0:
                return rs.vclock
            return (time.monotonic() - rs.t_start) * 1e3

        def finish(req: Request, out: list, why: str, status: str = "ok",
                   reason: Optional[str] = None):
            c = Completion(req.rid, len(req.tokens), out, why,
                           status=status, reason=reason)
            rs.done.append(c)
            self._health[status] += 1
            if status == "ok":
                self._health[why] += 1
            if self._journal is not None:
                self._journal.retire(c)

        def retire(slot: int, why: str, status: str = "ok",
                   reason: Optional[str] = None):
            req = rs.slot_req[slot]
            finish(req, rs.slot_out[slot], why, status, reason)
            rs.slot_req[slot] = None
            rs.slot_out[slot] = []
            rs.active[slot] = False
            if self._prefix is not None:
                self._prefix.release(slot)

        def overdue(req: Request) -> bool:
            return (req.deadline_ms is not None
                    and now_ms() - req.arrive_ms >= req.deadline_ms)

        def resumable(slot: int) -> bool:
            # the parked state (prompt + generated minus the pending
            # token) must fit the resume prefill's buffer
            return int(rs.pos[slot]) <= self._resume_cap

        def preempt(slot: int):
            req = rs.slot_req[slot]
            rs.readmit.append(_Parked(req=req, out=rs.slot_out[slot],
                                      key=self._slot_keys[slot].copy(),
                                      steps=rs.slot_steps[slot]))
            self._health["preemptions"] += 1
            rs.slot_req[slot] = None
            rs.slot_out[slot] = []
            rs.active[slot] = False
            if self._prefix is not None:
                # drop shared-page references and reclaim the table row
                # onto the slot's private pages before a new resident
                # moves in
                self._prefix.release(slot)
                self._set_row(slot, self._private_rows[slot])

        def reap_deadlines():
            for slot in range(B):
                req = rs.slot_req[slot]
                if req is not None and overdue(req):
                    self._health["deadline_misses"] += 1
                    retire(slot, "timeout", status="timeout",
                           reason=f"deadline {req.deadline_ms:g} ms "
                                  "exceeded while decoding")
            for q in (rs.pending, rs.readmit):
                kept = []
                for item in q:
                    req = item.req if isinstance(item, _Parked) else item
                    if overdue(req):
                        self._health["deadline_misses"] += 1
                        out = item.out if isinstance(item, _Parked) else []
                        finish(req, out, "timeout", status="timeout",
                               reason=f"deadline {req.deadline_ms:g} ms "
                                      "exceeded while queued")
                    else:
                        kept.append(item)
                q.clear()
                q.extend(kept)

        def ingest():
            while rs.arrivals and rs.arrivals[0].arrive_ms <= now_ms():
                if (self.queue_cap is not None
                        and len(rs.pending) >= self.queue_cap):
                    if self.shed_policy == "shed":
                        req = rs.arrivals.popleft()
                        finish(req, [], "shed", status="shed",
                               reason=f"admission queue full "
                                      f"(queue_cap={self.queue_cap})")
                        continue
                    break   # "block": arrivals wait upstream
                rs.pending.append(rs.arrivals.popleft())

        def next_waiter():
            """Highest-priority waiter; FIFO within a priority, parked
            re-admissions preferred on ties (their device work is already
            partly spent).  Plain FIFO when every priority is equal —
            the pre-resilience admission order."""
            best = None     # (source, index, priority)
            for i, p in enumerate(rs.readmit):
                if best is None or p.req.priority > best[2]:
                    best = ("readmit", i, p.req.priority)
            for i, r in enumerate(rs.pending):
                if best is None or r.priority > best[2]:
                    best = ("pending", i, r.priority)
            if best is None:
                return None
            src, i, _ = best
            q = rs.readmit if src == "readmit" else rs.pending
            item = q[i]
            del q[i]
            return item

        def force_preempts():
            for rid in plan.preempts_at(rs.n_blocks):
                for slot in range(B):
                    req = rs.slot_req[slot]
                    if (req is not None and req.rid == rid
                            and resumable(slot)):
                        preempt(slot)

        def priority_preempt():
            """One preemption per boundary: when no slot is free and a
            waiter strictly outranks the lowest-priority resumable
            resident, evict that resident."""
            if not (rs.pending or rs.readmit):
                return
            if any(rs.slot_req[s] is None for s in range(B)):
                return
            waiter_pri = max(
                [p.req.priority for p in rs.readmit]
                + [r.priority for r in rs.pending])
            victims = [s for s in range(B)
                       if rs.slot_req[s] is not None and resumable(s)]
            if not victims:
                return
            s = min(victims, key=lambda s: (rs.slot_req[s].priority, s))
            if rs.slot_req[s].priority < waiter_pri:
                preempt(s)

        def seed_host_state(slot: int, req: Request, out: list,
                            key: np.ndarray, steps: int):
            L = len(req.tokens)
            if self._strategy.stateful:
                seq = list(np.asarray(req.tokens, np.int32)) + list(out)
                self._hist[slot] = 0
                self._hist[slot, :len(seq)] = np.asarray(seq, np.int32)
            rs.slot_req[slot] = req
            rs.slot_out[slot] = out
            rs.pos[slot] = L + len(out) - 1
            rs.last_tok[slot] = int(out[-1])
            rs.active[slot] = True
            rs.slot_steps[slot] = steps
            self._slot_keys[slot] = key

        def admit_free_slots():
            for slot in range(B):
                if rs.slot_req[slot] is not None:
                    continue
                while True:
                    item = next_waiter()
                    if item is None:
                        return
                    if isinstance(item, _Parked):
                        self._readmit(slot, item.req, item.out,
                                      recovered=item.recovered)
                        seed_host_state(slot, item.req, item.out,
                                        item.key, item.steps)
                        break
                    req = item
                    err = self._check(req)
                    if err is not None:
                        finish(req, [], "rejected", status="rejected",
                               reason=err)
                        continue
                    try:
                        t0, key = self._admit(slot, req)
                    except Exception as e:  # noqa: BLE001 — isolation:
                        # one bad admission (injected or real prefill
                        # failure, non-finite logits) rejects THIS
                        # request; the run keeps serving
                        finish(req, [], "failed", status="failed",
                               reason=f"{type(e).__name__}: {e}")
                        continue
                    seed_host_state(slot, req, [int(t0)], key, steps=0)
                    if self.eos_id >= 0 and int(t0) == self.eos_id:
                        retire(slot, "eos")
                    elif req.max_gen <= 1:
                        retire(slot, "budget")
                    break

        while rs.arrivals or rs.pending or rs.readmit or rs.active.any():
            reap_deadlines()
            ingest()
            force_preempts()
            priority_preempt()
            admit_free_slots()
            if not rs.active.any():
                if rs.arrivals and not rs.pending and not rs.readmit:
                    # nothing runnable until the next arrival: advance
                    # the clock to it instead of spinning
                    if plan.ms_per_block > 0:
                        rs.vclock = max(rs.vclock,
                                        rs.arrivals[0].arrive_ms)
                    else:
                        time.sleep(min(
                            1e-3, max(0.0, (rs.arrivals[0].arrive_ms
                                            - now_ms()) * 1e-3)))
                continue

            # -- one decode block over the slot batch ----------------------
            # nan_step: per-slot in-block scan step at which a scheduled
            # decode fault fires (-1 = none) — data, not shape
            nan_step = np.full((B,), -1, np.int32)
            for slot in range(B):
                req = rs.slot_req[slot]
                if req is None or not rs.active[slot]:
                    continue
                step = plan.nan_decode_step(req.rid)
                if step is not None:
                    rel = step - rs.slot_steps[slot]
                    if 0 <= rel < self.block_steps:
                        nan_step[slot] = rel
            ran = rs.active.copy()
            toks, emitted, self._cache, pos_d, active_d, keys_d, hist, \
                bad_d = self._decode(
                    self.serve_params, self.qparams,
                    jnp.asarray(rs.last_tok),
                    self._cache, jnp.asarray(rs.pos),
                    jnp.asarray(rs.active),
                    jnp.asarray(self._slot_keys), jnp.asarray(self._hist),
                    jnp.asarray(nan_step))
            toks = np.asarray(toks)
            emitted = np.asarray(emitted)
            pos_new = np.asarray(pos_d)
            active_new = np.asarray(active_d)
            bad = np.asarray(bad_d)
            # host copies: admission mutates rows in place (np.asarray of
            # a device buffer is read-only)
            self._hist = np.array(hist)
            self._slot_keys = np.array(keys_d)
            for slot in range(B):
                if ran[slot]:
                    rs.slot_steps[slot] += self.block_steps
            if self._emit_w > 1:
                # a window with any emission ran a live verify pass
                win = emitted.reshape(B, self.block_steps, self._emit_w)
                self._spec_windows += int(win.any(-1).sum())
                self._spec_emitted += int(emitted.sum())

            # -- collect emissions, retire finished slots ------------------
            # emission lanes are RAGGED within a speculative window (a
            # partial accept leaves un-emitted tail lanes, then the next
            # window emits again) — skip gaps instead of stopping at one
            for slot in range(B):
                req = rs.slot_req[slot]
                if req is None or not rs.active[slot]:
                    continue
                for i in range(self.block_steps * self._emit_w):
                    if len(rs.slot_out[slot]) >= req.max_gen:
                        break
                    if not emitted[slot, i]:
                        continue
                    rs.slot_out[slot].append(int(toks[slot, i]))
                rs.pos[slot] = pos_new[slot]
                rs.last_tok[slot] = (rs.slot_out[slot][-1]
                                     if rs.slot_out[slot]
                                     else rs.last_tok[slot])
                # finish reason from what was actually COLLECTED: an EOS
                # beyond the budget cut was never part of the output, so
                # that request finished by budget, not eos — and a
                # device-side freeze without a collected EOS and with
                # budget to spare can only be the NaN guard (flagged in
                # ``bad``) or the capacity guard
                hit_eos = (self.eos_id >= 0 and bool(rs.slot_out[slot])
                           and rs.slot_out[slot][-1] == self.eos_id)
                budget_done = len(rs.slot_out[slot]) >= req.max_gen
                if hit_eos:
                    retire(slot, "eos")
                elif budget_done:
                    retire(slot, "budget")
                elif bad[slot]:
                    retire(slot, "failed", status="failed",
                           reason="non-finite logits during decode")
                elif not active_new[slot]:
                    retire(slot, "capacity")
                else:
                    rs.active[slot] = active_new[slot]
            rs.n_blocks += 1
            if plan.ms_per_block > 0:
                rs.vclock += plan.ms_per_block
            # -- boundary commit: WAL flush, snapshot cadence, crash ------
            self._boundary_commit(rs, now_ms())
            if max_blocks is not None and rs.n_blocks >= max_blocks:
                break
        # parked victims the run never got back to are terminal too —
        # with their generated-so-far tokens, so nothing is silently lost
        while rs.readmit:
            p = rs.readmit.popleft()
            finish(p.req, p.out, "preempted", status="preempted",
                   reason="preempted; run ended before re-admission")
        # no resident remains (or the run was cut): drop any prefix-store
        # references this run's slots held so unused entries stay evictable
        if self._prefix is not None:
            for slot in range(B):
                self._prefix.release(slot)
        return rs.done

    def _boundary_commit(self, rs: _RunState, clock_ms: float):
        """Everything that makes a decode-block boundary DURABLE, in the
        WAL order the journal module documents: retire records were
        already flushed as they happened, so write progress (absolute
        host state per in-flight request — residents then parked), then
        the ``block`` marker, then the optional periodic snapshot, and
        only THEN fire a scheduled simulated crash — a crash can never
        observe a boundary whose records are not durable."""
        if self._journal is not None:
            for slot in range(self.max_slots):
                req = rs.slot_req[slot]
                if req is not None:
                    self._journal.progress(
                        req.rid, rs.slot_out[slot],
                        self._slot_keys[slot], rs.slot_steps[slot])
            for p in rs.readmit:
                self._journal.progress(p.req.rid, p.out, p.key, p.steps)
            self._journal.block(rs.n_blocks, clock_ms)
        if (self._snap_mgr is not None and self._snapshot_every > 0
                and rs.n_blocks % self._snapshot_every == 0):
            self.save_state()
        if self._plan.crash_at(rs.n_blocks):
            raise SimulatedCrash(
                f"simulated crash at decode-block boundary {rs.n_blocks} "
                "(recover on a fresh scheduler: recover() replays the "
                "journal, load_state() restores the last snapshot)")

    # -- crash recovery ----------------------------------------------------
    def recover(self,
                max_blocks: Optional[int] = None) -> list[Completion]:
        """Journal-replay crash recovery, on a FRESH scheduler pointed at
        the crashed run's journal: no device state is read back at all.
        The journal's last epoch classifies every request — retired
        completions are re-emitted verbatim, in-flight requests
        (resident or parked at the crash) park on the re-admit queue and
        rebuild their int8 KV state with one ``resume`` ragged prefill
        over prompt + generated-so-far tokens (bit-valid because the
        paper's frozen §2 thresholds make cache state a pure function of
        the token sequence), and never-admitted requests re-enter the
        arrival queue.  The surviving state is re-written as a fresh
        journal epoch first, so repeated crash/recover cycles stay
        replayable.  Greedy completions are bit-identical to an
        uninterrupted run (tests/test_recovery.py pins it); sampled ones
        too, because each request's carried PRNG key rides the journal.

        Returns ALL completions of the logical run (pre-crash retirees
        included).  ``max_blocks`` counts total decode blocks (the block
        counter resumes from the crash boundary)."""
        if self._journal is None:
            raise ValueError(
                "recover() needs a journal: construct the scheduler with "
                "journal=<path of the crashed run's journal>")
        rp = self._journal.replay()
        self._check_knobs(rp.knobs)
        rs = self._fresh_rs([])
        rs.n_blocks = rp.n_blocks
        # resume the run clock where the crash left it: virtual clocks
        # restore exactly; wall clocks restart offset by the journaled
        # elapsed ms (deadline fidelity across recovery needs the
        # virtual clock — wall time lost to the outage is invisible)
        if self._plan.ms_per_block > 0:
            rs.vclock = rp.vclock
        rs.t_start = time.monotonic() - rp.vclock * 1e-3
        for d in rp.done:
            c = completion_from_dict(d)
            rs.done.append(c)
            # re-derive the terminal-status counters the crash erased
            self._health[c.status] += 1
            if c.status == "ok":
                self._health[c.finished_by] += 1
        for item in rp.inflight:
            req = request_from_dict(item["req"])
            out = [int(t) for t in item["out"]]
            if len(req.tokens) + len(out) - 1 <= self._resume_cap:
                rs.readmit.append(_Parked(
                    req=req, out=out,
                    key=np.asarray(item["key"], np.uint32),
                    steps=int(item["steps"]), recovered=True))
            else:
                # parked state too wide for the resume executable (its
                # buffer covers whole prefill chunks only — the same
                # bound ``resumable()`` enforces before preempting, but
                # a crash cannot refuse): re-serve from scratch.  Still
                # bit-identical — greedy tokens are a pure function of
                # the prompt, and the sampling stream restarts from the
                # same fold_in(seed, rid) key — at the cost of
                # re-decoding what was already generated
                self._health["replayed_tokens"] += (len(req.tokens)
                                                    + len(out))
                rs.pending.append(req)
        rs.arrivals = deque(sorted(
            (request_from_dict(d) for d in rp.queued),
            key=lambda r: r.arrive_ms))
        self._health["recoveries"] += 1
        self._rs = rs
        self._epoch = max(self._epoch, rp.epoch)
        self._rewrite_epoch(rs)
        return self._drive(max_blocks)

    def _rewrite_epoch(self, rs: _RunState):
        """Start a fresh journal epoch that re-states the surviving run
        state (retirees, in-flight progress, queued requests), so replay
        after a SECOND crash still sees one complete epoch."""
        j = self._journal
        if j is None:
            return
        self._epoch = max(self._epoch, j.last_epoch()) + 1
        j.begin(self._epoch, self._knobs(), recovered=True)
        for c in rs.done:
            j.retire(c)
        for slot in range(self.max_slots):
            req = rs.slot_req[slot]
            if req is not None:
                j.enqueue(req)
                j.progress(req.rid, rs.slot_out[slot],
                           self._slot_keys[slot], rs.slot_steps[slot])
        for p in rs.readmit:
            j.enqueue(p.req)
            j.progress(p.req.rid, p.out, p.key, p.steps)
        for req in list(rs.pending) + list(rs.arrivals):
            j.enqueue(req)
        j.block(rs.n_blocks, rs.vclock)

    # -- full-state snapshot (save/load through CheckpointManager) ---------
    def save_state(self) -> str:
        """Write a full snapshot of the serving state through the
        checkpoint manager at ``snapshot_dir``: every cache layer's
        ``state_dict()`` (int8 pages/slots + frozen scales), the host
        decode vectors (positions, active mask, pending tokens, per-slot
        PRNG keys, strategy history), the run bookkeeping (residents,
        queues, parked victims, completions, block counter, clock), the
        prefix store (entries, refcounts, stored logits), and the health
        counters.  Atomic (temp dir + rename) and keep-N via
        ``CheckpointManager`` — the same fault-tolerance contract
        training checkpoints get.  Returns the checkpoint path."""
        if self._snap_mgr is None:
            raise ValueError(
                "save_state() needs a snapshot_dir (construct the "
                "scheduler with snapshot_dir=...)")
        rs = self._rs if self._rs is not None else self._fresh_rs([])
        leaves = [c for c in jax.tree.leaves(
            self._cache, is_leaf=lambda x: isinstance(x, KVCache))]
        tree = {
            "cache": {str(i): c.state_dict()
                      for i, c in enumerate(leaves)},
            "host": {"pos": rs.pos, "active": rs.active,
                     "last_tok": rs.last_tok,
                     "slot_keys": self._slot_keys, "hist": self._hist},
        }
        clock_ms = (rs.vclock if self._plan.ms_per_block > 0
                    else (time.monotonic() - rs.t_start) * 1e3)

        def parked_d(p: _Parked) -> dict:
            return {"req": request_to_dict(p.req),
                    "out": [int(t) for t in p.out],
                    "key": [int(k) for k in p.key],
                    "steps": int(p.steps), "recovered": bool(p.recovered)}

        state = {
            "knobs": self._knobs(),
            "slot_req": [None if r is None else request_to_dict(r)
                         for r in rs.slot_req],
            "slot_out": [[int(t) for t in out] for out in rs.slot_out],
            "slot_steps": [int(s) for s in rs.slot_steps],
            "done": [completion_to_dict(c) for c in rs.done],
            "arrivals": [request_to_dict(r) for r in rs.arrivals],
            "pending": [request_to_dict(r) for r in rs.pending],
            "readmit": [parked_d(p) for p in rs.readmit],
            "n_blocks": int(rs.n_blocks), "clock_ms": float(clock_ms),
            "health": {k: int(v) for k, v in self._health.items()},
            "epoch": int(self._epoch),
        }
        if self._prefix is not None:
            psd = self._prefix.state_dict()
            # logits are arrays — route them through the npz tree, keep
            # the rest JSON (entry i's logits live at prefix_logits[i])
            tree["prefix_logits"] = {
                str(i): e.pop("logits")
                for i, e in enumerate(psd["entries"])}
            state["prefix"] = psd
        self._snap_step = max([self._snap_step]
                              + self._snap_mgr.list_steps()) + 1
        return self._snap_mgr.save(self._snap_step, tree,
                                   metadata={"state": state})

    def load_state(self) -> int:
        """Restore the newest committed snapshot from ``snapshot_dir``
        into THIS scheduler (typically a fresh instance standing in for
        a crashed process), rebuilding the device cache bit-exactly from
        the saved int8 arrays — full-snapshot recovery, the
        gigabytes-back alternative to journal replay.  Knobs must match
        the saving scheduler's.  Follow with :meth:`resume_run` to drive
        the restored run to completion; decode continues at the
        snapshot's block boundary, so completions are bit-identical to
        an uninterrupted run.  Returns the restored block counter."""
        if self._snap_mgr is None:
            raise ValueError(
                "load_state() needs a snapshot_dir (construct the "
                "scheduler with snapshot_dir=...)")
        tree, meta = self._snap_mgr.restore_latest()
        if tree is None:
            raise FileNotFoundError(
                f"no committed snapshot under {self._snap_mgr.dir}")
        st = meta["state"]
        self._check_knobs(st["knobs"])
        tmpl, treedef = jax.tree.flatten(
            self._cache, is_leaf=lambda x: isinstance(x, KVCache))
        saved = tree["cache"]
        if len(saved) != len(tmpl):
            raise ValueError(
                f"snapshot has {len(saved)} cache layers, scheduler has "
                f"{len(tmpl)} (wrong snapshot for this config?)")
        leaves = []
        for i, t in enumerate(tmpl):
            c = KVCache.from_state_dict(saved[str(i)])
            for n in type(t)._child_names():
                a, b = getattr(c, n), getattr(t, n)
                if a.shape != b.shape or a.dtype != b.dtype:
                    raise ValueError(
                        f"snapshot cache layer {i} child {n!r} is "
                        f"{a.shape}/{a.dtype}, scheduler expects "
                        f"{b.shape}/{b.dtype}")
            leaves.append(c)
        self._cache = jax.tree.unflatten(treedef, leaves)
        host = tree["host"]
        self._slot_keys = np.array(host["slot_keys"], np.uint32)
        self._hist = np.array(host["hist"], np.int32)
        rs = self._fresh_rs([])
        rs.pos = np.array(host["pos"], np.int32)
        rs.active = np.array(host["active"], bool)
        rs.last_tok = np.array(host["last_tok"], np.int32)
        rs.slot_req = [None if d is None else request_from_dict(d)
                       for d in st["slot_req"]]
        rs.slot_out = [[int(t) for t in out] for out in st["slot_out"]]
        rs.slot_steps = [int(s) for s in st["slot_steps"]]
        rs.done = [completion_from_dict(d) for d in st["done"]]
        rs.arrivals = deque(request_from_dict(d) for d in st["arrivals"])
        rs.pending = deque(request_from_dict(d) for d in st["pending"])
        rs.readmit = deque(
            _Parked(req=request_from_dict(p["req"]),
                    out=[int(t) for t in p["out"]],
                    key=np.asarray(p["key"], np.uint32),
                    steps=int(p["steps"]),
                    recovered=bool(p.get("recovered", False)))
            for p in st["readmit"])
        rs.n_blocks = int(st["n_blocks"])
        clock_ms = float(st["clock_ms"])
        if self._plan.ms_per_block > 0:
            rs.vclock = clock_ms
        rs.t_start = time.monotonic() - clock_ms * 1e-3
        self._health = {k: int(st["health"].get(k, 0))
                        for k in _HEALTH_KEYS}
        self._health["recoveries"] += 1
        self._epoch = int(st["epoch"])
        if self._prefix is not None and "prefix" in st:
            psd = dict(st["prefix"])
            logits = tree.get("prefix_logits", {})
            psd["entries"] = [
                {**e, "logits": np.asarray(logits[str(i)])}
                for i, e in enumerate(psd["entries"])]
            self._prefix.load_state_dict(psd)
        self._rs = rs
        # restored state supersedes whatever epoch the journal holds
        self._rewrite_epoch(rs)
        return rs.n_blocks

    # -- admission ---------------------------------------------------------
    def _check(self, req: Request) -> Optional[str]:
        """Validate a request; returns a rejection reason or None.  Bad
        requests retire with status 'rejected' instead of aborting the
        run — per-request fault isolation."""
        L = int(len(req.tokens))
        if L > self.prompt_cap:
            return (f"prompt length {L} exceeds prompt_cap "
                    f"{self.prompt_cap}")
        if L < 1:
            return "empty prompt"
        if req.max_gen < 1:
            # admission always yields the prefill's first token, so a
            # 0-token budget cannot be honored
            return ("max_gen must be >= 1 (the first token is sampled "
                    "at admission)")
        return None

    def _request_keys(self, rid: int):
        """Per-request key pair: (first-token sample key, carried slot
        key).  Folding the rid into the seed key makes the request's
        entire sample stream independent of arrival order and slot
        placement."""
        k = jax.random.fold_in(self._base_key, int(rid))
        ks = jax.random.split(k)
        return ks[0], np.asarray(ks[1], np.uint32)

    def _sample_t0(self, logits, key) -> int:
        t0 = ST.sample_tokens(jnp.asarray(logits)[:, -1, :], key,
                              temperature=self.temperature, top_p=self.top_p)
        return int(t0[0])

    def _admit(self, slot: int, req: Request):
        """Admit ``req`` into ``slot``; returns (first generated token,
        carried per-request key).  Dense: chunked-prefill the prompt into
        the batch-1 template and splice it into the slot's region.
        Paged: try the prefix store first — a full-prompt hit attaches
        the shared pages (block-table row write + one tail-page copy) and
        samples from the stored logits, running ZERO prefill FLOPs; a
        miss prefills, scatters into the slot's private pages, and
        registers the prompt for future sharers.  Raises on injected
        admission faults and non-finite prefill logits — BEFORE anything
        is spliced into the resident cache, so a failed admission leaves
        no trace."""
        L = int(len(req.tokens))
        if self._plan.rejects(req.rid):
            raise InjectedFault(
                f"request {req.rid}: injected admission failure")
        k_t0, k_carry = self._request_keys(req.rid)
        key = tuple(int(t) for t in np.asarray(req.tokens))

        if self._prefix is not None:
            entry = self._prefix.lookup(key, slot)
            if entry is not None:
                return self._attach_prefix(slot, entry, k_t0), k_carry

        toks = np.zeros((1, self.prompt_cap), np.int32)
        toks[0, :L] = np.asarray(req.tokens, np.int32)
        lengths = jnp.asarray([L], jnp.int32)
        logits, slot_cache = self._prefill(
            self.serve_params, self.qparams, {"tokens": jnp.asarray(toks)},
            self._slot_cache0, lengths)
        last_row = np.asarray(logits)[:, -1, :]
        if self._plan.nans_prefill(req.rid):
            last_row = np.full_like(last_row, np.nan)
        if not np.isfinite(last_row).all():
            raise FloatingPointError(
                f"request {req.rid}: non-finite prefill logits")
        if self._prefix is None:
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(slot, jnp.int32))
        else:
            row = self._private_rows[slot]
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(row))
            self._set_row(slot, row)
            self._register_prefix(key, L, row, logits)
        return self._sample_t0(logits, k_t0), k_carry

    def _readmit(self, slot: int, req: Request, out: list,
                 recovered: bool = False):
        """Rebuild a preempted request's device state in ``slot``: one
        ragged prefill (the ``resume`` executable) over prompt +
        generated-so-far tokens minus the pending one — FAT's frozen
        scales make the recomputed int8 cache bit-valid, so decode
        continues exactly where it left off.  The slot's private pages
        receive the state; prefix pages are not consulted (the sequence
        includes generated tokens no other request shares).  Crash
        recovery rides the same executable (``recovered=True``) but
        counts re-prefilled tokens as ``replayed_tokens`` instead of a
        preemption re-admission."""
        L = len(req.tokens)
        resume = L + len(out) - 1   # pending token is NOT yet in cache
        toks = np.zeros((1, self._resume_cap), np.int32)
        seq = list(np.asarray(req.tokens, np.int32)) + [int(t) for t in
                                                        out[:-1]]
        toks[0, :resume] = np.asarray(seq, np.int32)
        lengths = jnp.asarray([resume], jnp.int32)
        _, slot_cache = self._resume(
            self.serve_params, self.qparams, {"tokens": jnp.asarray(toks)},
            self._slot_cache0, lengths)
        if self._prefix is None:
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(slot, jnp.int32))
        else:
            row = self._private_rows[slot]
            self._call_counts["insert"] += 1
            self._cache = self._insert_fn(self._cache, slot_cache,
                                          jnp.asarray(row))
            self._set_row(slot, row)
        if recovered:
            self._health["replayed_tokens"] += resume
        else:
            self._health["readmits"] += 1

    # -- paged plumbing ----------------------------------------------------
    def _set_row(self, slot: int, row: np.ndarray):
        self._call_counts["set_row"] += 1
        self._cache = self._set_row_fn(self._cache,
                                       jnp.asarray(slot, jnp.int32),
                                       jnp.asarray(row, jnp.int32))

    def _copy_pages(self, pairs: Sequence[tuple]):
        """One fixed-shape copy dispatch for up to n_blocks (src, dst)
        page pairs: unused entries repeat the first pair (duplicate dst
        with identical src — a deterministic re-write), so every
        registration/attach shares ONE compiled executable regardless of
        how many pages the prompt spans."""
        if not pairs:
            return
        self._call_counts["copy_page"] += 1
        padded = list(pairs) + [pairs[0]] * (self._n_blocks - len(pairs))
        src = jnp.asarray([p[0] for p in padded], jnp.int32)
        dst = jnp.asarray([p[1] for p in padded], jnp.int32)
        self._cache = self._copy_page_fn(self._cache, src, dst)

    def _register_prefix(self, key, L, private_row, logits):
        """Snapshot the freshly-prefilled prompt pages into the shared
        region (device page copies — no model FLOPs) and store the
        last-position logits so a future identical prompt skips prefill
        entirely.  Opportunistic: skipped — and counted in
        ``health_stats()['prefix_exhausted']`` — when the shared region
        is full of in-use entries (or a fault plan forces exhaustion);
        the admission itself already lives in private pages, so serving
        degrades to no-sharing instead of failing."""
        alloc = (None if self._plan.exhaust_prefix
                 else self._prefix.reserve(key, L))
        if alloc is None:
            self._health["prefix_exhausted"] += 1
            return
        pages, tail = alloc
        n_full = len(pages)
        pairs = [(int(private_row[j]), int(dst))
                 for j, dst in enumerate(pages)]
        if tail is not None:
            pairs.append((int(private_row[n_full]), int(tail)))
        self._copy_pages(pairs)
        self._prefix.register(key, PrefixEntry(
            pages=pages, tail_page=tail, length=L,
            logits=np.asarray(logits)))

    def _attach_prefix(self, slot: int, entry: PrefixEntry, k_t0) -> int:
        """Full-prompt hit: point the slot's table row at the shared
        pages; the partial tail page (decode's first append target) is
        copied into the slot's private page so shared pages stay
        immutable.  No prefill executable runs."""
        row = self._private_rows[slot].copy()
        n_full = len(entry.pages)
        row[:n_full] = entry.pages
        self._set_row(slot, row)
        if entry.tail_page is not None:
            self._copy_pages([(int(entry.tail_page),
                               int(self._private_rows[slot][n_full]))])
        return self._sample_t0(entry.logits, k_t0)
