"""Slot-based continuous-batching scheduler over the int8 serving engine.

The paper's frozen static thresholds (§2) are what make this possible:
K/V dequant scales never change at serve time, so a request can be
admitted into — or evicted from — a shared int8 KV cache without any
recalibration.  The cache is one fixed-shape (max_slots, cache_len) int8
region per layer; requests stream through slots while the COMPILED
executables never change:

  * admission runs the batch-1 chunked ragged prefill (one executable for
    every prompt length: tokens pad to ``prompt_cap``, the length vector
    does the ragged masking) and splices the resulting cache region into
    the free slot with one dynamic-update-slice along the batch axis;
  * decode runs ``steps.make_slot_decode_loop`` blocks: every slot at its
    own position (vector ``cur_pos`` through the fused decode kernel),
    inactive slots masked in attention, sampling, and cache writes;
  * eviction is pure bookkeeping — a finished slot's region is dead data
    that the next admission's prefill overwrites (slots [0, prompt) and
    per-step decode writes cover every position a future mask can see).

Slot lifecycle (see docs/serving.md for the full diagram)::

    FREE --admit(prefill into slot region)--> ACTIVE
    ACTIVE --EOS token / gen budget / cache full--> DRAINED
    DRAINED --collect output--> FREE

Which slots are live, at which positions, with which arrival order is
DATA (pos/active vectors), never SHAPE — so one compiled decode
executable serves every admission pattern (verified by the
jit-cache-miss-counting test in tests/test_scheduler.py).

The host loop (``SlotScheduler.run``) interleaves admission and decode
blocks: admit into every free slot, decode ``block_steps`` tokens, retire
finished slots, repeat until the queue drains.  Raggedness across
requests costs masked lanes within a block, not recompiles.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as A
from repro.launch import steps as ST


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens + a generation budget."""
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_gen: int = 16           # generated-token budget (incl. first token)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list                # generated tokens (includes EOS if hit)
    finished_by: str            # 'eos' | 'budget' | 'capacity'


def _slot_cache_insert(cache, slot_cache, slot):
    """Splice a batch-1 cache pytree into slot ``slot`` of the batch cache.

    KV leaves are (..., B, S, KV, D) — batch axis at ndim-4 in both the
    per-layer and the stacked-scanned layout — and get a dynamic-update-
    slice along it.  Lower-rank leaves (per-head dequant scales) are
    request-independent (frozen calibration), identical for every
    admission: take the slot cache's copy wholesale, which also fixes up
    the ones-initialized scales of a never-admitted batch cache.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def write(big, small):
        if big.ndim < 4:
            return small
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, big.ndim - 4)

    return jax.tree.map(write, cache, slot_cache)


class SlotScheduler:
    """Continuous batching: admit/evict requests through a fixed slot batch.

    Parameters
    ----------
    model, cfg, policy, mode : the serving stack (same objects serve.py
        builds); attention-only text configs with dense caches only — the
        same restriction as chunked prefill, checked at construction.
    serve_params, qparams : converted weights + finalized thresholds.
    max_slots : decode batch size (concurrent requests).
    prompt_cap : maximum prompt length; every prompt pads to this, the
        length vector masks the tail (one prefill executable).  Rounded up
        to a ``prefill_chunk`` multiple.
    gen_cap : per-slot generation headroom reserved in the cache.
    prefill_chunk : chunk size of the admission prefill scan; None picks
        ``max(8, min(16, prompt_cap))`` — the single home of that default
        (serve.py and serve_bench.py both inherit it, so the benchmark
        measures the executable the CLI serves).
    block_steps : decode-block length; admission happens at block
        boundaries, so smaller blocks = lower admission latency, larger
        blocks = fewer dispatches.
    temperature, top_p, seed : sampling (greedy when temperature == 0).
    eos_id : generation stops for a slot when it emits this token
        (< 0 disables).
    """

    def __init__(self, model, cfg, policy: A.QuantPolicy, serve_params,
                 qparams, *, mode: str = "int8", max_slots: int = 4,
                 prompt_cap: int = 64, gen_cap: int = 32,
                 prefill_chunk: int | None = None, block_steps: int = 8,
                 temperature: float = 0.0, top_p: float = 1.0,
                 eos_id: int = -1, seed: int = 0):
        kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
        wins = {cfg.attn_window(i) for i in range(cfg.n_layers)}
        if kinds - {"attn", "attn_local"} or cfg.modality != "text":
            raise ValueError(
                "slot scheduler covers attention-only text stacks "
                f"(got kinds={sorted(kinds)}, modality={cfg.modality})")
        if wins != {None}:
            raise ValueError(
                "slot scheduler needs dense caches: SWA ring buffers drop "
                f"absolute slots (got windows={sorted(map(str, wins))})")
        self.model, self.cfg = model, cfg
        self.policy, self.mode = policy, mode
        self.serve_params, self.qparams = serve_params, qparams
        self.max_slots = max_slots
        if prefill_chunk is None:
            prefill_chunk = max(8, min(16, prompt_cap))
        self.prefill_chunk = prefill_chunk
        self.prompt_cap = -(-prompt_cap // prefill_chunk) * prefill_chunk
        self.block_steps = block_steps
        self.temperature, self.top_p = temperature, top_p
        self.eos_id = eos_id
        cache_len = self.prompt_cap + gen_cap
        if policy.use_pallas:
            # tile the cache length for the fused decode kernel — a
            # non-tiling length pad-copies the cache every step
            cache_len = -(-cache_len // 128) * 128
        self.cache_len = cache_len
        self._key = jax.random.PRNGKey(seed)

        kv_int8 = bool(policy.kv_int8)
        self._kv_int8 = kv_int8
        # batch-1 slot cache template for admissions (prefill never
        # donates it, so one allocation serves every admission)
        self._slot_cache0 = model.init_cache(1, cache_len, cfg.dtype,
                                             kv_int8=kv_int8)
        # trace counting: the counter bumps inside the to-be-jitted Python
        # body, which only runs when the jit cache misses — so the count
        # IS the number of compiled variants, measured on public jit
        # behavior (and per instance: each wrapper is a fresh closure)
        self._trace_counts = {"prefill": 0, "decode": 0, "insert": 0}

        def counted(name, fn):
            def wrapper(*args):
                self._trace_counts[name] += 1
                return fn(*args)
            return wrapper

        self._prefill = jax.jit(counted("prefill", ST.make_prefill_step(
            model, cfg, policy, mode=mode, prefill_chunk=prefill_chunk)))
        self._decode = jax.jit(counted("decode", ST.make_slot_decode_loop(
            model, cfg, policy, mode=mode, n_steps=block_steps,
            temperature=temperature, top_p=top_p, eos_id=eos_id)),
            donate_argnums=(3,))
        self._insert = jax.jit(counted("insert", _slot_cache_insert),
                               donate_argnums=(0,))

    # -- observability ----------------------------------------------------
    def executable_counts(self) -> dict:
        """Number of times each of the three pieces was TRACED (== number
        of compiled variants) — the no-retrace contract says each stays
        at 1 across every admission pattern."""
        return dict(self._trace_counts)

    # -- one serving session ----------------------------------------------
    def run(self, requests: Iterable[Request],
            max_blocks: Optional[int] = None) -> list[Completion]:
        """Serve ``requests`` to completion through the slot batch.

        Admission is streaming: requests queue up and enter whenever a
        slot frees, so the number of concurrent residents never exceeds
        ``max_slots`` while raggedness (arrival time, prompt length,
        budget) stays data.  Returns completions in finish order.
        ``max_blocks`` bounds the decode blocks (None = drain fully).
        """
        queue = deque(requests)
        B = self.max_slots
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        last_tok = np.zeros((B,), np.int32)
        slot_req: list[Optional[Request]] = [None] * B
        slot_out: list[list] = [[] for _ in range(B)]
        cache = self.model.init_cache(B, self.cache_len, self.cfg.dtype,
                                      kv_int8=self._kv_int8)
        done: list[Completion] = []
        n_blocks = 0

        def retire(slot: int, why: str):
            req = slot_req[slot]
            done.append(Completion(req.rid, len(req.tokens),
                                   slot_out[slot], why))
            slot_req[slot] = None
            slot_out[slot] = []
            active[slot] = False

        while queue or active.any():
            # -- admission: fill every free slot from the queue ------------
            for slot in range(B):
                if slot_req[slot] is not None or not queue:
                    continue
                req = queue.popleft()
                cache, t0 = self._admit(cache, slot, req)
                slot_req[slot] = req
                slot_out[slot] = [int(t0)]
                pos[slot] = len(req.tokens)
                last_tok[slot] = int(t0)
                active[slot] = True
                if self.eos_id >= 0 and int(t0) == self.eos_id:
                    retire(slot, "eos")
                elif req.max_gen <= 1:
                    retire(slot, "budget")
            if not active.any():
                continue

            # -- one decode block over the slot batch ----------------------
            toks, emitted, cache, pos_d, active_d, self._key = self._decode(
                self.serve_params, self.qparams, jnp.asarray(last_tok),
                cache, jnp.asarray(pos), jnp.asarray(active), self._key)
            toks = np.asarray(toks)
            emitted = np.asarray(emitted)
            pos_new = np.asarray(pos_d)
            active_new = np.asarray(active_d)

            # -- collect emissions, retire finished slots ------------------
            for slot in range(B):
                req = slot_req[slot]
                if req is None or not active[slot]:
                    continue
                for i in range(self.block_steps):
                    if not emitted[slot, i]:
                        break
                    if len(slot_out[slot]) >= req.max_gen:
                        break
                    slot_out[slot].append(int(toks[slot, i]))
                pos[slot] = pos_new[slot]
                last_tok[slot] = (slot_out[slot][-1]
                                  if slot_out[slot] else last_tok[slot])
                # finish reason from what was actually COLLECTED: an EOS
                # beyond the budget cut was never part of the output, so
                # that request finished by budget, not eos — and a
                # device-side freeze without a collected EOS and with
                # budget to spare can only be the capacity guard
                hit_eos = (self.eos_id >= 0 and bool(slot_out[slot])
                           and slot_out[slot][-1] == self.eos_id)
                budget_done = len(slot_out[slot]) >= req.max_gen
                if hit_eos:
                    retire(slot, "eos")
                elif budget_done:
                    retire(slot, "budget")
                elif not active_new[slot]:
                    retire(slot, "capacity")
                else:
                    active[slot] = active_new[slot]
            n_blocks += 1
            if max_blocks is not None and n_blocks >= max_blocks:
                break
        return done

    # -- admission ---------------------------------------------------------
    def _admit(self, cache, slot: int, req: Request):
        """Chunked-prefill the prompt into a batch-1 cache, splice it into
        ``slot``'s region, and return (cache, first generated token)."""
        L = int(len(req.tokens))
        if L > self.prompt_cap:
            raise ValueError(
                f"request {req.rid}: prompt length {L} exceeds prompt_cap "
                f"{self.prompt_cap}")
        if L < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_gen < 1:
            # admission always yields the prefill's first token, so a
            # 0-token budget cannot be honored
            raise ValueError(
                f"request {req.rid}: max_gen must be >= 1 (the first "
                "token is sampled at admission)")
        toks = np.zeros((1, self.prompt_cap), np.int32)
        toks[0, :L] = np.asarray(req.tokens, np.int32)
        lengths = jnp.asarray([L], jnp.int32)
        logits, slot_cache = self._prefill(
            self.serve_params, self.qparams, {"tokens": jnp.asarray(toks)},
            self._slot_cache0, lengths)
        self._key, sub = jax.random.split(self._key)
        t0 = ST.sample_tokens(logits[:, -1, :], sub,
                              temperature=self.temperature, top_p=self.top_p)
        cache = self._insert(cache, slot_cache, jnp.asarray(slot, jnp.int32))
        return cache, int(t0[0])
