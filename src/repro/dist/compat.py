"""jax version compatibility shims for mesh / shard_map APIs.

The repo targets current jax, but the pinned container ships an older
release where ``axis_types`` / ``jax.shard_map`` don't exist yet.  These
wrappers accept the modern call shape and degrade gracefully.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axis_names, *, devices=None):
    """jax.make_mesh with auto axis_types when the version supports it."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    try:
        axis_type = jax.sharding.AxisType.Auto
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type,) * len(axis_names), **kw)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axis_names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map (new) or jax.experimental.shard_map (old), with
    replication checking off (collectives here are intentionally uneven)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
