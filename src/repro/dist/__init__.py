"""Distribution layer: sharding rules, activation constraints, collectives.

Pure-jax (no hard mesh dependency): every entry point degrades to an
identity / replicated behavior when no mesh is active, so the same model
code runs on 1 CPU in tests and on the production mesh in the dry-run.
"""
