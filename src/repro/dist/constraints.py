"""Activation sharding constraints.

``constrain_activation`` is called on every residual-stream tensor and
batch input (see models/model.py, models/transformer.py, launch/steps.py).
Under an active mesh with installed ShardingRules it pins the activation
layout so GSPMD doesn't invent resharding chatter inside the layer stack:

  * batch axis  -> the data axis (always)
  * sequence    -> the model axis, but only for the scan *carry*
    (``carry=True``): the residual stream rides sequence-sharded between
    blocks and is gathered at block entry (sequence parallelism)

Outside a mesh (unit tests, CPU smoke runs) every call is an identity —
the constraint is advisory placement, never semantics.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.dist.sharding import P, ShardingRules

_RULES: Optional[ShardingRules] = None


def install(rules: ShardingRules) -> None:
    """Set process-global rules (the dry-run / launcher call this once)."""
    global _RULES
    _RULES = rules


def installed() -> Optional[ShardingRules]:
    return _RULES


def _active_mesh():
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        return None
    return None


def _in_manual_context() -> bool:
    """True inside a ``shard_map`` body (mesh axes are manual there —
    ``with_sharding_constraint`` over those axes is at best a no-op and
    at worst a hard error, so the constraint must stand down)."""
    try:
        from jax._src.core import get_axis_env

        return bool(get_axis_env().axis_sizes)
    except Exception:
        return False


def constrain_activation(x: jax.Array, *, carry: bool = False) -> jax.Array:
    """Pin an activation's sharding; identity when no mesh/rules are active.

    Only rank-2/3 float batch-major tensors are constrained — anything else
    (scalars, threshold vectors, integer token ids of other ranks) passes
    through untouched.  Inside a traced context with no rules installed,
    or inside a ``shard_map`` body (manual axes), this is an explicit
    no-op — model code must be callable under any tracer without the
    process-global install ever having happened.
    """
    rules = _RULES
    if rules is None or not hasattr(x, "ndim"):
        return x
    if _in_manual_context():
        return x
    mesh = _active_mesh()
    if mesh is None:
        return x
    if x.ndim == 2:  # (B, S) token ids
        spec = P(rules.act_batch, None)
    elif x.ndim == 3:  # (B, S, d) activations
        seq = rules.act_seq if carry else None
        spec = P(rules.act_batch, seq, None)
    else:
        return x
    try:
        sharding = jax.sharding.NamedSharding(mesh, spec)
        return jax.lax.with_sharding_constraint(x, sharding)
    except Exception:
        # axis not in this mesh / indivisible dim: placement is best-effort
        return x
