"""Compressed cross-replica collectives.

``compressed_psum`` applies the paper's int8 machinery to every tensor
the engine reduces across chips.  Two regimes share one entry point:

  * **float payloads** (the gradient stream): FAT's trainable state is
    tiny (threshold alphas), but pretraining the substrate still
    all-reduces full weight gradients — quantizing the payload to int8
    with a shared max-abs threshold (paper eq. 2) quarters the DCN/ICI
    bytes of the data-parallel reduction at one-quantization-step error.
  * **integer payloads** (the serving stream): tensor-parallel row
    epilogues reduce the int8 matmul's *int32 accumulators*
    (core/api.py::_int8_matmul).  Integer addition is exact, so the
    fast path psums the payload as-is — bit-identical to the unsharded
    matmul AND integer-on-the-wire, with no threshold pmax at all.

Interconnect dtype contract (machine-checked): the static analyzer's
``drift.collective`` rule (repro.analysis.dtype_drift) fails CI on any
collective moving floating-point payload.  This module is the reason
that rule can be strict — every tensor-sized transfer here is integer —
and its single sanctioned exception: the one-scalar f32 ``pmax`` that
establishes the shared threshold, declared as the ``compressed_psum``
:class:`~repro.analysis.dtype_drift.AllowRule` in
``DEFAULT_ALLOWLIST`` (scope-matched to this function, capped at one
element so the exemption cannot grow into a tensor-sized hole).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str, *,
                    mean: bool = True) -> jax.Array:
    """Reduce ``x`` over ``axis_name`` with an integer wire payload.

    Integer inputs take the exact fast path: the payload rides the wire
    as int32 and the int32 *sum* is returned (dequantization is the
    caller's job — the tensor-parallel epilogue divides by nothing, it
    applies the weight scale once after the reduce).  ``mean=True`` is
    rejected there because an integer mean would truncate.

    Float inputs quantize to int8 first: the threshold is the max|x|
    across the axis (so every participant uses the same scale — a pmax
    of one scalar), the payload is int8, and the accumulation runs in
    int32 (no overflow below 2**24 participants).  Returns the
    dequantized mean (``mean=True``, the gradient contract) or sum;
    error is bounded by step/2 per element.  A zero or non-finite
    max-abs falls back to the 1e-8 threshold floor, and NaN payload
    elements quantize as 0 — a poisoned shard can never corrupt the
    shared scale or the other shards' contributions.
    """
    if jnp.issubdtype(x.dtype, jnp.integer):
        if mean:
            raise ValueError(
                "integer payloads reduce exactly; a mean would truncate — "
                "pass mean=False and rescale after the reduce")
        return jax.lax.psum(x.astype(jnp.int32), axis_name)
    # NaN floor: squash poison BEFORE the shared-threshold pmax so one
    # shard's NaN cannot widen every shard's quantization step to NaN
    xf = jnp.nan_to_num(x.astype(jnp.float32), nan=0.0)
    # the ONE float collective in the engine: a single f32 scalar (see
    # module docstring / analysis allowlist) — keep it scalar; widening
    # it would trip drift.collective in CI, by design
    t = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    s = jnp.maximum(t, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    # integer-accumulator contract: the payload psum stays int32 —
    # dequantization happens once, after the reduce, never on the wire
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = acc.astype(jnp.float32) * s
    if mean:
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        out = out / n.astype(jnp.float32)
    return out.astype(x.dtype)
