"""Compressed cross-replica collectives.

``compressed_psum`` applies the paper's int8 machinery to the *gradient*
stream: FAT's trainable state is tiny (threshold alphas), but pretraining
the substrate still all-reduces full weight gradients — quantizing the
payload to int8 with a shared max-abs threshold (paper eq. 2) quarters the
DCN/ICI bytes of the data-parallel reduction at one-quantization-step
error.

Interconnect dtype contract (machine-checked): the static analyzer's
``drift.collective`` rule (repro.analysis.dtype_drift) fails CI on any
collective moving floating-point payload.  This module is the reason
that rule can be strict — every tensor-sized transfer here is integer —
and its single sanctioned exception: the one-scalar f32 ``pmax`` that
establishes the shared threshold, declared as the ``compressed_psum``
:class:`~repro.analysis.dtype_drift.AllowRule` in
``DEFAULT_ALLOWLIST`` (scope-matched to this function, capped at one
element so the exemption cannot grow into a tensor-sized hole).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce ``x`` over ``axis_name`` with an int8-compressed payload.

    The threshold is the max|x| across the axis (so every participant uses
    the same scale — a pmax of one scalar), the payload is int8, and the
    accumulation runs in int32 (no overflow below 2**24 participants).
    Returns the dequantized mean; error is bounded by step/2 per element.
    """
    xf = x.astype(jnp.float32)
    # the ONE float collective in the engine: a single f32 scalar (see
    # module docstring / analysis allowlist) — keep it scalar; widening
    # it would trip drift.collective in CI, by design
    t = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    s = jnp.maximum(t, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    # integer-accumulator contract: the payload psum stays int32 —
    # dequantization happens once, after the reduce, never on the wire
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return (acc.astype(jnp.float32) * s / n.astype(jnp.float32)).astype(x.dtype)
