"""Sharding rules: logical model axes -> production mesh axes.

The production mesh is (data=16, model=16) per pod (launch/mesh.py).  The
rules object is a plain dataclass so the dry-run can hillclimb individual
knobs (sequence-parallel residual, KV-cache layout) via dataclasses.replace
without touching model code.

Spec builders return pytrees of ``PartitionSpec`` mirroring the abstract
state trees from launch/specs.py; ``to_shardings`` binds them to a mesh.
Everything falls back to replication for leaves it doesn't recognize —
placement is an optimization, never a correctness requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mesh-axis assignment knobs (hillclimb surface for the dry-run)."""

    act_batch: Union[str, tuple] = "data"   # batch dim of activations/batches
    act_seq: Optional[str] = "model"        # residual-carry sequence axis (SP)
    tensor: str = "model"                   # tensor-parallel param axis
    kv_cache_layout: str = "batch"          # 'batch' | 'seq' cache sharding
    model_axis_size: int = 16               # divisibility guard for specs


def multipod(rules: ShardingRules) -> ShardingRules:
    """Two-pod variant: pure DP across DCN — batch over ('pod', 'data')."""
    return dataclasses.replace(rules, act_batch=("pod", "data"))


def _divisible(dim: int, rules: ShardingRules) -> bool:
    return dim % rules.model_axis_size == 0


def param_specs(model, params_a, rules: ShardingRules):
    """PartitionSpec tree for a (possibly int8-converted) param pytree.

    Weight matrices shard their output (last) axis over the tensor axis
    when divisible; per-channel scale vectors follow their weight; biases,
    norms and thresholds replicate.  Leading scan-stack axes stay
    unsharded (lax.scan slices them).
    """

    def spec(leaf):
        shp = leaf.shape
        if len(shp) >= 2 and _divisible(shp[-1], rules):
            return P(*((None,) * (len(shp) - 1) + (rules.tensor,)))
        if len(shp) == 1 and _divisible(shp[-1], rules):
            return P(rules.tensor)
        return P()

    return jax.tree.map(spec, params_a)


def qparam_specs(model, params_a, qparams_a, rules: ShardingRules):
    """Threshold state is a few floats per layer — replicate it all."""
    return jax.tree.map(lambda _: P(), qparams_a)


# --------------------------------------------------------------------------
# Tensor-parallel (Megatron-style) specs for the sharded serving engine
# --------------------------------------------------------------------------
#
# ``param_specs`` above is shape-driven (good enough for the dry-run's
# placement hillclimb) but serving TP needs ROLE-aware placement: a
# row-parallel projection shards its *input* axis and must replicate its
# per-output-channel scale, which shape alone cannot tell apart from a
# column-parallel scale of the same length.  Roles come from the param
# tree's own dict keys, the same path-classified idiom as ``cache_specs``.

# column-parallel: output features split across shards (no epilogue)
TP_COL_KEYS = frozenset({"wq", "wk", "wv", "gate", "up", "fc1"})
# row-parallel: input features split; int32 psum epilogue after the dot
TP_ROW_KEYS = frozenset({"wo", "down", "fc2"})


def _path_keys(path) -> list:
    return [getattr(p, "key", getattr(p, "name", None)) for p in path]


def tp_param_specs(params_a, *, tp: int, axis: str = "model"):
    """PartitionSpec tree for tensor-parallel serving params.

    Column-parallel weights shard the last (output) axis; their
    per-channel scales/biases follow.  Row-parallel weights shard the
    second-to-last (input) axis; their scales/biases replicate (the
    output axis is whole on every shard after the psum epilogue).
    Everything else — embeddings, norms, scalar scales — replicates.
    Leading scan-stack axes are counted from the end so stacked (L, in,
    out) params place identically to unstacked ones.  Indivisible
    sharded axes raise: silent replication would desynchronize the
    local-config model's shapes.
    """
    from jax.tree_util import tree_map_with_path

    def spec(path, leaf):
        keys = _path_keys(path)
        role = next((k for k in reversed(keys[:-1])
                     if k in TP_COL_KEYS or k in TP_ROW_KEYS), None)
        if role is None or tp <= 1:
            return P()
        name = keys[-1]
        shp = leaf.shape
        if role in TP_ROW_KEYS:
            if name in ("w", "w_q"):
                if shp[-2] % tp:
                    raise ValueError(
                        f"{'/'.join(map(str, keys))}: input axis {shp[-2]} "
                        f"not divisible by tp={tp}")
                return P(*((None,) * (len(shp) - 2) + (axis, None)))
            return P()  # w_scale/bias span the whole output axis
        if name in ("w", "w_q"):
            if shp[-1] % tp:
                raise ValueError(
                    f"{'/'.join(map(str, keys))}: output axis {shp[-1]} "
                    f"not divisible by tp={tp}")
            return P(*((None,) * (len(shp) - 1) + (axis,)))
        if len(shp) >= 1 and shp[-1] > 1 and shp[-1] % tp == 0:
            # per-output-channel companions: w_scale, b, b_q, b_scale
            return P(*((None,) * (len(shp) - 1) + (axis,)))
        return P()  # scalar scales

    return tree_map_with_path(spec, params_a)


def tp_qparam_specs(qparams_a, *, tp: int, n_kv: int, axis: str = "model"):
    """Threshold state under TP: activation thresholds are per-tensor
    scalars (replicate — the frozen §2 scale must be IDENTICAL on every
    shard for local-quantize == slice-of-global-quantize), but the
    per-KV-head cache thresholds split with their heads so each shard's
    ``_kv_scales`` sees exactly its local heads."""
    from jax.tree_util import tree_map_with_path

    from repro.core.api import is_kv_path

    def spec(path, leaf):
        keys = _path_keys(path)
        shp = getattr(leaf, "shape", ())
        if (tp > 1 and keys and isinstance(keys[0], str)
                and is_kv_path(keys[0]) and len(shp) >= 1
                and shp[-1] == n_kv and n_kv % tp == 0):
            return P(*((None,) * (len(shp) - 1) + (axis,)))
        return P()

    return tree_map_with_path(spec, qparams_a)


def tp_cache_specs(cache_a, *, tp: int, axis: str = "model"):
    """KV cache under TP: the KV-head axis (axis -2 of every 4-d k/v
    leaf — dense (B, S, KV, D), ring (B, W, KV, D) and paged pools
    (T, ps, KV, D) alike) splits with the heads, as do the per-head
    (KV,) dequant scale vectors.  Block tables and positions replicate.
    """
    from jax.tree_util import tree_map_with_path

    def spec(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else None
        shp = leaf.shape
        if tp <= 1:
            return P()
        if name in ("k", "v") and len(shp) >= 4:
            if shp[-2] % tp:
                raise ValueError(
                    f"cache {name}: KV-head axis {shp[-2]} not divisible "
                    f"by tp={tp}")
            return P(*((None,) * (len(shp) - 2) + (axis, None)))
        if name in ("k_scale", "v_scale") and len(shp) >= 1 \
                and shp[-1] % tp == 0:
            return P(*((None,) * (len(shp) - 1) + (axis,)))
        return P()

    return tree_map_with_path(spec, cache_a)


def sp_cache_specs(cache_a, *, sp: int, axis: str = "model"):
    """KV cache under sequence parallelism: delegate to ``cache_specs``'s
    'seq' layout (the S axis of each k/v leaf splits over the model
    axis); scales and positions replicate.  A thin wrapper so the
    serving engine and the dry-run hillclimb share one classification.
    """
    from jax.tree_util import tree_map_with_path

    def check(path, leaf):
        keys = _path_keys(path)
        if keys and keys[-1] in ("k", "v") and len(leaf.shape) >= 4:
            s_axis = len(leaf.shape) - 3  # KV tail is (B, S, KV, D)
            if leaf.shape[s_axis] % sp:
                raise ValueError(
                    f"cache {keys[-1]}: sequence axis {leaf.shape[s_axis]} "
                    f"not divisible by sp={sp}")
        return leaf

    tree_map_with_path(check, cache_a)
    # act_batch=None: on the 1-axis serving mesh an indivisible leaf has
    # already raised above, so the 'batch' fallback must stay unsharded
    rules = ShardingRules(act_batch=None, tensor=axis,
                          kv_cache_layout="seq", model_axis_size=sp)
    return cache_specs(cache_a, rules, sp)


def batch_specs(batch_a, rules: ShardingRules):
    """Batch inputs shard the leading batch dim over the data axis."""

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(*((rules.act_batch,) + (None,) * (nd - 1)))

    return jax.tree.map(spec, batch_a)


def cache_specs(cache_a, rules: ShardingRules, model_size: int):
    """KV/SSM cache placement.

    'batch' layout shards the cache batch dim over data (decode batches are
    large); 'seq' layout shards the KV sequence dim over the model axis,
    which GSPMD turns into flash-decode partial-softmax combines (see
    models/attention.py docstring).  Scale vectors replicate; SSM states
    shard batch over data.  Stacked (L, ...) caches keep the layer axis
    unsharded.

    Leaves are classified by their tree path ("k"/"v" under attn/cross are
    KV; "ssm"/"conv" are SSM states) — both are 4-d per layer, so rank
    alone cannot tell them apart.
    """
    from jax.tree_util import tree_map_with_path

    def spec(path, leaf):
        shp = leaf.shape
        nd = len(shp)
        keys = [getattr(p, "key", None) for p in path]
        is_kv = keys and keys[-1] in ("k", "v")
        if nd < 3 or not (is_kv or nd >= 4):  # scales, positions
            return P()
        if not is_kv:  # SSM states (B, H, N, P) etc. -> batch over data
            lead = 1 if nd >= 5 else 0  # stacked (L, ...) in scan mode
            axes = [None] * nd
            axes[lead] = rules.act_batch
            return P(*axes)
        lead = nd - 4  # KV tail is (B, S, KV, D)
        axes = [None] * nd
        if rules.kv_cache_layout == "seq" and shp[lead + 1] % model_size == 0:
            axes[lead + 1] = rules.tensor
        else:
            axes[lead] = rules.act_batch
        return P(*axes)

    return tree_map_with_path(spec, cache_a)


def to_shardings(spec_tree, mesh, tree_a=None):
    """Bind a PartitionSpec tree (or one spec) to NamedShardings on mesh."""

    def mk(p):
        return jax.sharding.NamedSharding(mesh, p)

    if isinstance(spec_tree, P):
        if tree_a is None:
            return mk(spec_tree)
        return jax.tree.map(lambda _: mk(spec_tree), tree_a)
    return jax.tree.map(mk, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
