"""Sharding rules: logical model axes -> production mesh axes.

The production mesh is (data=16, model=16) per pod (launch/mesh.py).  The
rules object is a plain dataclass so the dry-run can hillclimb individual
knobs (sequence-parallel residual, KV-cache layout) via dataclasses.replace
without touching model code.

Spec builders return pytrees of ``PartitionSpec`` mirroring the abstract
state trees from launch/specs.py; ``to_shardings`` binds them to a mesh.
Everything falls back to replication for leaves it doesn't recognize —
placement is an optimization, never a correctness requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax

P = jax.sharding.PartitionSpec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mesh-axis assignment knobs (hillclimb surface for the dry-run)."""

    act_batch: Union[str, tuple] = "data"   # batch dim of activations/batches
    act_seq: Optional[str] = "model"        # residual-carry sequence axis (SP)
    tensor: str = "model"                   # tensor-parallel param axis
    kv_cache_layout: str = "batch"          # 'batch' | 'seq' cache sharding
    model_axis_size: int = 16               # divisibility guard for specs


def multipod(rules: ShardingRules) -> ShardingRules:
    """Two-pod variant: pure DP across DCN — batch over ('pod', 'data')."""
    return dataclasses.replace(rules, act_batch=("pod", "data"))


def _divisible(dim: int, rules: ShardingRules) -> bool:
    return dim % rules.model_axis_size == 0


def param_specs(model, params_a, rules: ShardingRules):
    """PartitionSpec tree for a (possibly int8-converted) param pytree.

    Weight matrices shard their output (last) axis over the tensor axis
    when divisible; per-channel scale vectors follow their weight; biases,
    norms and thresholds replicate.  Leading scan-stack axes stay
    unsharded (lax.scan slices them).
    """

    def spec(leaf):
        shp = leaf.shape
        if len(shp) >= 2 and _divisible(shp[-1], rules):
            return P(*((None,) * (len(shp) - 1) + (rules.tensor,)))
        if len(shp) == 1 and _divisible(shp[-1], rules):
            return P(rules.tensor)
        return P()

    return jax.tree.map(spec, params_a)


def qparam_specs(model, params_a, qparams_a, rules: ShardingRules):
    """Threshold state is a few floats per layer — replicate it all."""
    return jax.tree.map(lambda _: P(), qparams_a)


def batch_specs(batch_a, rules: ShardingRules):
    """Batch inputs shard the leading batch dim over the data axis."""

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(*((rules.act_batch,) + (None,) * (nd - 1)))

    return jax.tree.map(spec, batch_a)


def cache_specs(cache_a, rules: ShardingRules, model_size: int):
    """KV/SSM cache placement.

    'batch' layout shards the cache batch dim over data (decode batches are
    large); 'seq' layout shards the KV sequence dim over the model axis,
    which GSPMD turns into flash-decode partial-softmax combines (see
    models/attention.py docstring).  Scale vectors replicate; SSM states
    shard batch over data.  Stacked (L, ...) caches keep the layer axis
    unsharded.

    Leaves are classified by their tree path ("k"/"v" under attn/cross are
    KV; "ssm"/"conv" are SSM states) — both are 4-d per layer, so rank
    alone cannot tell them apart.
    """
    from jax.tree_util import tree_map_with_path

    def spec(path, leaf):
        shp = leaf.shape
        nd = len(shp)
        keys = [getattr(p, "key", None) for p in path]
        is_kv = keys and keys[-1] in ("k", "v")
        if nd < 3 or not (is_kv or nd >= 4):  # scales, positions
            return P()
        if not is_kv:  # SSM states (B, H, N, P) etc. -> batch over data
            lead = 1 if nd >= 5 else 0  # stacked (L, ...) in scan mode
            axes = [None] * nd
            axes[lead] = rules.act_batch
            return P(*axes)
        lead = nd - 4  # KV tail is (B, S, KV, D)
        axes = [None] * nd
        if rules.kv_cache_layout == "seq" and shp[lead + 1] % model_size == 0:
            axes[lead + 1] = rules.tensor
        else:
            axes[lead] = rules.act_batch
        return P(*axes)

    return tree_map_with_path(spec, cache_a)


def to_shardings(spec_tree, mesh, tree_a=None):
    """Bind a PartitionSpec tree (or one spec) to NamedShardings on mesh."""

    def mk(p):
        return jax.sharding.NamedSharding(mesh, p)

    if isinstance(spec_tree, P):
        if tree_a is None:
            return mk(spec_tree)
        return jax.tree.map(lambda _: mk(spec_tree), tree_a)
    return jax.tree.map(mk, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
