"""Adam + cosine annealing with warm restarts — the paper's exact training
recipe (§4.1.2: "Adam optimizer is used for training, and cosine annealing
with the reset of optimizer parameters — for learning rate").

Functional (optax-style) but dependency-free.  The restart resets BOTH the
learning-rate phase and the Adam moments — the "reset of optimizer
parameters" the paper calls out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamState(NamedTuple):
    step: jax.Array     # global step
    mu: Any             # first moment pytree
    nu: Any             # second moment pytree


def adam_init(params) -> AdamState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=z,
                     nu=jax.tree.map(jnp.copy, z))


def cosine_restarts(step, base_lr: float, period: int, t_mult: float = 1.0,
                    min_frac: float = 0.0):
    """Learning rate at ``step`` under SGDR-style cosine annealing.

    Phase resets every ``period`` steps (period optionally growing by
    t_mult).  Implemented in jnp so it jits inside the train step.
    """
    # uniform host/traced handling: python ints, numpy scalars, and
    # tracers all take the same path (float(step) on the fallback branch
    # raised ConcretizationTypeError the first time a caller jitted over
    # a non-array step)
    step = jnp.asarray(step, jnp.float32)
    if t_mult == 1.0:
        phase = jnp.mod(step, period) / period
    else:
        # closed form for geometric periods
        k = jnp.floor(
            jnp.log1p((t_mult - 1.0) * step / period) / np.log(t_mult)
        )
        start = period * (t_mult**k - 1.0) / (t_mult - 1.0)
        cur = period * t_mult**k
        phase = (step - start) / cur
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * phase))
    return base_lr * (min_frac + (1.0 - min_frac) * cos)


def restart_boundary(step: int, period: int, t_mult: float = 1.0) -> bool:
    """True when ``step`` begins a new annealing cycle (host-side helper)."""
    if t_mult == 1.0:
        return step > 0 and step % period == 0
    acc = 0
    cur = period
    while acc < step:
        acc += cur
        cur = int(round(cur * t_mult))
    return acc == step and step > 0


def reset_moments(state: AdamState) -> AdamState:
    """The paper's 'reset of optimizer parameters' at each LR restart."""
    return AdamState(
        step=state.step,
        mu=jax.tree.map(jnp.zeros_like, state.mu),
        nu=jax.tree.map(jnp.zeros_like, state.nu),
    )


def adam_update(
    grads,
    state: AdamState,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask=None,
):
    """One Adam step.  ``mask`` (pytree of bool) freezes leaves where False —
    how FAT trains *only* the threshold scale factors while every network
    weight stays fixed (§3.1.3 "All network parameters except quantization
    thresholds are fixed")."""
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p, trainable=True):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1**t)
        vhat = v2 / (1 - b2**t)
        delta = lr * (mhat / (jnp.sqrt(vhat) + eps)
                      + weight_decay * p.astype(jnp.float32))
        if isinstance(trainable, bool):
            keep = trainable
        else:
            keep = trainable  # array mask
        new_p = (p.astype(jnp.float32) - delta).astype(p.dtype)
        if keep is True:
            return new_p, m2, v2
        return (
            jnp.where(keep, new_p, p).astype(p.dtype),
            jnp.where(keep, m2, m),
            jnp.where(keep, v2, v),
        )

    if mask is None:
        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    else:
        out = jax.tree.map(
            lambda g, m, v, p, k: upd(g, m, v, p, k),
            grads, state.mu, state.nu, params, mask,
        )
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, mu=new_mu, nu=new_nu)
