"""Deterministic, resumable synthetic data pipeline.

Serves three roles the paper's setup needs:
  * an *unlabeled* training stream for FAT distillation (§3.2 — labels are
    deliberately discarded; ~10% of the full corpus suffices),
  * a small *calibration* set (§2 — the paper uses 100 images),
  * a labeled stream for the pretrain substrate mode.

Tokens follow a Zipf marginal with a 2-gram mixing process so quantized /
full-precision outputs diverge in realistic, non-uniform ways (uniform
random tokens would under-exercise activation outliers — the paper's whole
motivation, Fig. 1).

Determinism/resumability: a batch is a pure function of (seed, step) via
``jax.random.fold_in``; pipeline state is just the integer step, which the
checkpoint carries — restart resumes the exact stream (fault-tolerance
requirement).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    modality: str = "text"   # text | vlm | audio
    mm_patches: int = 0
    mm_dim: int = 0
    frame_dim: int = 0
    dec_ratio: int = 8
    dtype: jnp.dtype = jnp.bfloat16


def _zipf_tokens(key, shape, vocab: int, a: float):
    """Inverse-CDF Zipf sampling (vectorized, jit-safe)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # ranks ~ u^(-1/(a-1)) truncated to vocab (approximate Zipf tail)
    r = jnp.floor(u ** (-1.0 / (a - 1.0))) % vocab
    return r.astype(jnp.int32)


def make_batch(spec: PipelineSpec, step) -> dict:
    """Pure function of (seed, step) -> batch dict (jit-able)."""
    key = jax.random.fold_in(jax.random.PRNGKey(spec.seed), step)
    k_tok, k_mix, k_mm = jax.random.split(key, 3)
    b = spec.global_batch
    s = spec.seq_len
    if spec.modality == "vlm":
        s_text = s - spec.mm_patches
    elif spec.modality == "audio":
        s_text = max(s // spec.dec_ratio, 4)
    else:
        s_text = s
    toks = _zipf_tokens(k_tok, (b, s_text), spec.vocab, spec.zipf_a)
    # 2-gram structure: with p=0.3 repeat the previous token + 1 (mod V)
    rep = jax.random.bernoulli(k_mix, 0.3, (b, s_text))
    shifted = jnp.roll(toks, 1, axis=1)
    toks = jnp.where(rep, (shifted + 1) % spec.vocab, toks)
    batch = {"tokens": toks}
    if spec.modality == "vlm":
        batch["patches"] = jax.random.normal(
            k_mm, (b, spec.mm_patches, spec.mm_dim)
        ).astype(spec.dtype)
    elif spec.modality == "audio":
        batch["frames"] = jax.random.normal(
            k_mm, (b, s, spec.frame_dim)
        ).astype(spec.dtype)
    # labels for the pretrain substrate mode; FAT distillation ignores them
    batch["labels"] = jnp.roll(toks, -1, axis=1)
    return batch


def spec_for(cfg, shape, seed: int = 0) -> PipelineSpec:
    """PipelineSpec from a ModelConfig + ShapeSpec."""
    return PipelineSpec(
        vocab=cfg.vocab,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        modality=cfg.modality if cfg.family != "encdec" else "audio",
        mm_patches=cfg.mm_patches,
        mm_dim=cfg.mm_dim,
        frame_dim=cfg.frame_dim,
        dec_ratio=cfg.dec_ratio,
        dtype=cfg.dtype,
    )


def calibration_batches(spec: PipelineSpec, n: int = 4, offset: int = 1 << 20):
    """The paper's calibration set (§4.1.2 uses 100 images ≈ a few batches);
    drawn from a disjoint region of the stream (offset) so calibration data
    is 'the most typical data' rather than training batches."""
    return [make_batch(spec, offset + i) for i in range(n)]
