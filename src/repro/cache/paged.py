"""Paged KV cache: a page pool + per-slot block tables (vLLM-style),
plus the host-side prefix store that makes prompt reuse free.

Layout
------
::

    k, v      : (pages, page_size, KV, D)   the page pool (int8 or float)
    table     : (B, n_blocks) int32         per-slot block table: logical
                                            block j of slot b lives in
                                            pool page ``table[b, j]``
    k_scale,
    v_scale   : (KV,) f32                   frozen per-head dequant scales

The pool holds ``B * n_blocks`` slot-private pages (page ``b*n_blocks+j``
is slot b's default page for block j — the identity table) plus an
optional ``extra_pages`` shared region owned by the :class:`PrefixStore`.

Why the paper makes this free: FAT's thresholds are calibrated once and
frozen (§2), so the int8 dequant scales are request-independent — a page
quantized while serving one request is bit-valid for every other request.
Prefix sharing is therefore pure bookkeeping: point a new slot's table at
already-written pages.  No requantization, no recalibration, no copy for
full pages.

Mutability rule: a shared page is **immutable**.  Only *full* pages of a
registered prompt are shared by reference; the partial tail page (the one
decode will keep appending into) is snapshotted into the shared region at
registration and **copied** into the new slot's private page on a hit —
so a sharer's decode writes can never corrupt another resident.

The stacked-layer helpers at the bottom (``splice_dense_into_pages``,
``set_table_row``, ``copy_pages``) tolerate an optional leading ``(L,)``
layer axis (scanned stacks): all axis math is relative to the trailing
(page, page_size, KV, D) / (B, n_blocks) dims.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import ClassVar, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.base import KernelView, KVCache, _zeros_kv


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class PagedCache(KVCache):
    """Page pool + per-slot block table behind the ``KVCache`` protocol.

    Logical position p of slot b lives at page ``table[b, p // ps]``,
    offset ``p % ps``.  With the identity table this is exactly a dense
    cache whose sequence axis is tiled into pages — which is what keeps
    dense↔paged bit-parity and lets the fused kernels serve both layouts
    from one compiled executable (the table is data, never shape).
    """

    layout: ClassVar[str] = "paged"

    k: jax.Array          # (T, ps, KV, D) page pool (D/2 at bits == 4)
    v: jax.Array
    k_scale: jax.Array    # (KV,) f32
    v_scale: jax.Array
    table: jax.Array      # (B, NB) int32
    _quantized: bool = dataclasses.field(default=False)
    page_size: int = dataclasses.field(default=64)
    bits: int = dataclasses.field(default=8)

    # pytree: the table is a child (keyed "table"); page_size and the KV
    # bit width join quantized in the static aux (KVCache pytree plumbing)
    _static = ("_quantized", "page_size", "bits")

    # -- construction ------------------------------------------------------
    @classmethod
    def init(cls, batch, max_len, n_kv, head_dim, *, dtype=jnp.bfloat16,
             quantized=False, page_size=64, extra_pages=0, bits=8):
        """Identity-table pool: slot b owns pages [b*NB, (b+1)*NB) where
        NB = ceil(max_len / page_size); ``extra_pages`` reserves the
        shared prefix region at the pool tail."""
        if page_size < 8 or page_size % 8:
            # the fused kernels use the page as their KV tile — keep it
            # sublane-aligned for the TPU lowering
            raise ValueError(
                f"page_size must be a positive multiple of 8, got "
                f"{page_size}")
        nb = -(-max_len // page_size)
        k, v, ks, vs = _zeros_kv(batch * nb + extra_pages, page_size, n_kv,
                                 head_dim, dtype, quantized, bits)
        table = jnp.arange(batch * nb, dtype=jnp.int32).reshape(batch, nb)
        return cls(k, v, ks, vs, table, _quantized=quantized,
                   page_size=page_size, bits=bits)

    @property
    def capacity(self) -> int:
        return self.n_blocks * self.page_size

    @property
    def n_blocks(self) -> int:
        return self.table.shape[-1]

    @property
    def n_pages(self) -> int:
        return self.k.shape[-4]

    # -- writes ------------------------------------------------------------
    def _page_of(self, positions):
        """(B, n) positions -> (pages (B, n), offsets (B, n)) via the
        table; positions clamp to the last valid slot (the same clamp XLA
        dynamic-update-slice gives the dense layout)."""
        pos = jnp.clip(jnp.asarray(positions, jnp.int32), 0,
                       self.capacity - 1)
        blocks = pos // self.page_size
        pages = jnp.take_along_axis(self.table, blocks, axis=-1)
        return pages, pos % self.page_size

    def append(self, kq, vq, start):
        """Token-granular scatter through the table: tokens [start,
        start+s) of every batch row land in their mapped pages.  Requires
        per-row-distinct target pages (any table the engine builds —
        identity or shared-prefix — satisfies this: appends only ever
        target private pages)."""
        b, s = kq.shape[0], kq.shape[1]
        pos = jnp.asarray(start, jnp.int32) + jnp.arange(s)
        pages, offs = self._page_of(jnp.broadcast_to(pos, (b, s)))
        return dataclasses.replace(
            self,
            k=self.k.at[pages, offs].set(kq, mode="drop"),
            v=self.v.at[pages, offs].set(vq, mode="drop"))

    def append_slots(self, kq, vq, starts, active=None):
        """Per-slot token scatter (kq/vq: (B, s, KV, D) — s == 1 for the
        decode step, s > 1 for the speculative verify window; a row's s
        tokens may span a page boundary, the table maps each); inactive
        slots read back their mapped tiles and write them unchanged —
        bit-exact cache-neutral, matching DenseCache."""
        s = kq.shape[1]
        starts = jnp.asarray(starts, jnp.int32).reshape(-1, 1)     # (B, 1)
        pos = starts + jnp.arange(s, dtype=jnp.int32)[None]        # (B, s)
        pages, offs = self._page_of(pos)
        if active is not None:
            sel = active[:, None, None, None]
            kq = jnp.where(sel, kq, self.k[pages, offs])
            vq = jnp.where(sel, vq, self.v[pages, offs])
        return dataclasses.replace(
            self,
            k=self.k.at[pages, offs].set(kq, mode="drop"),
            v=self.v.at[pages, offs].set(vq, mode="drop"))

    # -- reads -------------------------------------------------------------
    def _blocks_for(self, limit: Optional[int]):
        if limit is None:
            return self.n_blocks
        return min(self.n_blocks, -(-int(limit) // self.page_size))

    def dense_view(self, limit=None):
        """Gather table-mapped pages back into contiguous (B, S', KV, D)
        tiles (the jnp fallback path; the fused kernels read the pool
        directly through the block table instead)."""
        nb = self._blocks_for(limit)
        tb = self.table[:, :nb]
        b = tb.shape[0]
        shp = (b, nb * self.page_size, self.n_kv, self.head_dim)
        k = self.k[tb].reshape(shp)
        v = self.v[tb].reshape(shp)
        if limit is not None and limit < shp[1]:
            k, v = k[:, :limit], v[:, :limit]
        return k, v

    def kernel_view(self, limit=None):
        nb = self._blocks_for(limit)
        return KernelView(self.k, self.v, self.table[:, :nb],
                          self.page_size, self.bits)

    def splice_slot(self, slot_cache, slot):
        raise NotImplementedError(
            "paged splices go through splice_dense_into_pages (the "
            "scheduler prefills admissions into a dense batch-1 cache and "
            "scatters it into the slot's private pages)")

    def rollback(self, pos, private_row=None):
        """Rewind slot b's table cursor to ``pos[b]`` valid entries.

        Without ``private_row`` this is the dense no-op (entries at
        positions >= pos are dead through the table exactly as they are
        through a contiguous layout — masks never read them, the next
        append overwrites them) and appends stay wherever the table
        points, which the engine guarantees is private storage.

        With ``private_row`` (B, NB) — the slot's own page ids — the
        rewound region is RE-POINTED at private pages so a rewind into a
        SHARED prefix page can never let a later append mutate refcounted
        storage: blocks strictly after the boundary hold only dead data
        and just swap their table entry; the boundary block (the one
        containing ``pos``, partially live) is copy-on-rewind — its
        current page's contents are copied into the private page before
        the table swap, so the live prefix survives and the shared page
        is never written.  Shared pages' refcounts are host-side
        (PrefixStore) state; this device op never frees or mutates them.
        """
        if private_row is None:
            return self
        ps, nb = self.page_size, self.n_blocks
        pos = jnp.asarray(pos, jnp.int32).reshape(-1)              # (B,)
        prow = jnp.asarray(private_row, jnp.int32)                 # (B, NB)
        blk = jnp.clip(pos // ps, 0, nb - 1)                       # boundary
        rewind = jnp.arange(nb, dtype=jnp.int32)[None] >= blk[:, None]
        new_table = jnp.where(rewind, prow, self.table)  # broadcasts (L,...)
        # copy-on-rewind the boundary block (a self-copy when it is
        # already private — identity, since the gather reads pre-scatter
        # contents).  Scanned stacks keep one table per layer with
        # identical entries (the scheduler writes rows uniformly), so one
        # (B,)-vector page copy serves every layer's pool slice.
        tb2 = self.table.reshape((-1,) + self.table.shape[-2:])[0]  # (B, NB)
        src = jnp.take_along_axis(tb2, blk[:, None], axis=-1)[:, 0]
        dst = jnp.take_along_axis(prow, blk[:, None], axis=-1)[:, 0]
        return dataclasses.replace(
            self,
            k=_put_pages(self.k, dst, _take_pages(self.k, src)),
            v=_put_pages(self.v, dst, _take_pages(self.v, src)),
            table=new_table)


# -- scheduler-side page ops (stacked-layer aware) --------------------------

def _page_axis(pool) -> int:
    return pool.ndim - 4


def _put_pages(pool, idx, vals):
    """Scatter ``vals`` into pool pages ``idx`` along the page axis,
    tolerating leading layer axes.  ``vals``'s page axis sits where the
    pool's does."""
    ax = _page_axis(pool)
    p = jnp.moveaxis(pool, ax, 0)
    v = jnp.moveaxis(vals, ax, 0)
    return jnp.moveaxis(p.at[idx].set(v.astype(p.dtype), mode="drop"), 0, ax)


def _take_pages(pool, idx):
    return jnp.take(pool, idx, axis=_page_axis(pool))


def splice_dense_into_pages(paged: PagedCache, dense_slot, row):
    """Admission splice: scatter a batch-1 DENSE cache (the prefill
    executable's output — one compiled prefill serves every layout) into
    the pages listed in ``row`` (NB,) and point ``slot``-row of the table
    at them.  ``row`` is data: private ids on a miss; the caller swaps in
    shared ids afterwards on a hit (set_table_row), so this one jitted
    splice serves every admission pattern."""
    ps, nb = paged.page_size, paged.n_blocks
    row = jnp.asarray(row, jnp.int32)

    def tiles(x):  # (..., 1, S, KV, D) -> (..., NB, ps, KV, D)
        shp = x.shape[:-4] + (nb, ps) + x.shape[-2:]
        return x.reshape(shp)

    return dataclasses.replace(
        paged,
        k=_put_pages(paged.k, row, tiles(dense_slot.k)),
        v=_put_pages(paged.v, row, tiles(dense_slot.v)),
        k_scale=dense_slot.k_scale, v_scale=dense_slot.v_scale)


def set_table_row(paged: PagedCache, slot, row):
    """Point slot ``slot``'s block table at pages ``row`` (NB,)."""
    t = jnp.moveaxis(paged.table, -2, 0)
    t = t.at[jnp.asarray(slot, jnp.int32)].set(
        jnp.asarray(row, jnp.int32), mode="drop")
    return dataclasses.replace(paged, table=jnp.moveaxis(t, 0, -2))


def copy_pages(paged: PagedCache, src, dst):
    """Copy pool pages ``src`` -> ``dst`` (fixed-length index vectors; pad
    unused entries with self-copies, e.g. src == dst == pool_size - 1).
    Used to snapshot a registered prefix's tail page and to give a hit
    its private copy — device bytes move, zero model FLOPs."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    return dataclasses.replace(
        paged,
        k=_put_pages(paged.k, dst, _take_pages(paged.k, src)),
        v=_put_pages(paged.v, dst, _take_pages(paged.v, src)))


# -- host-side prefix registry ----------------------------------------------

class PrefixEntry(NamedTuple):
    pages: tuple          # shared page ids of the FULL prompt pages
    tail_page: Optional[int]   # snapshot page of the partial tail (or None)
    length: int           # prompt length in tokens
    logits: np.ndarray    # last-position logits (1, 1, V) — admission
    #                       samples t0 from these, so a hit runs no model


class PrefixStore:
    """Host-side registry: full-prompt key -> shared pages + stored
    logits, with an LRU page allocator over the pool's shared region.

    Keys are the full prompt token tuple (the prompt IS the prefix of the
    whole sequence); registration is opportunistic — when the shared
    region has no free pages and every entry is in use, new prompts
    simply aren't registered.  ``users`` tracks which live slots hold
    references so an entry's pages are never reclaimed under a resident.
    """

    def __init__(self, first_page: int, n_pages: int, page_size: int):
        self.page_size = page_size
        self._free = list(range(first_page, first_page + n_pages))
        self._entries: "OrderedDict[tuple, dict]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.shared_tokens = 0   # prompt tokens served from shared pages
        self.evictions = 0       # LRU entries reclaimed for new prompts
        self.exhausted = 0       # reserves denied: no free/evictable pages

    # -- stats -------------------------------------------------------------
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "shared_tokens": self.shared_tokens,
                "entries": len(self._entries),
                "free_pages": len(self._free),
                "evictions": self.evictions,
                "exhausted": self.exhausted}

    # -- lookup / reference counting --------------------------------------
    def lookup(self, key: tuple, slot: int):
        """Full-prompt hit: returns the entry and marks ``slot`` as a
        user (release with ``release(slot)`` at retirement)."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        e["users"].add(slot)
        self.hits += 1
        self.shared_tokens += e["entry"].length
        return e["entry"]

    def release(self, slot: int):
        for e in self._entries.values():
            e["users"].discard(slot)

    # -- registration ------------------------------------------------------
    def _reclaim(self, need: int):
        """Evict least-recently-used entries with no live users until
        ``need`` pages are free (or nothing evictable remains)."""
        for key in list(self._entries):
            if len(self._free) >= need:
                break
            e = self._entries[key]
            if e["users"]:
                continue
            ent = e["entry"]
            self._free.extend(ent.pages)
            if ent.tail_page is not None:
                self._free.append(ent.tail_page)
            del self._entries[key]
            self.evictions += 1

    def reserve(self, key: tuple, length: int):
        """Allocate shared pages for a prompt of ``length`` tokens:
        returns (full_page_ids, tail_page_id | None) or None when the
        shared region can't fit it (counted in ``exhausted``)."""
        if key in self._entries:
            return None
        n_full, rem = divmod(length, self.page_size)
        need = n_full + (1 if rem else 0)
        if need == 0 or len(self._free) < need:
            self._reclaim(need)
        if need == 0:
            return None
        if len(self._free) < need:
            self.exhausted += 1
            return None
        pages = [self._free.pop() for _ in range(n_full)]
        tail = self._free.pop() if rem else None
        return tuple(pages), tail

    def register(self, key: tuple, entry: PrefixEntry):
        self._entries[key] = {"entry": entry, "users": set()}

    # -- durable serving (snapshot/restore) --------------------------------
    def state_dict(self) -> dict:
        """Serializable snapshot: free list, counters, and every entry —
        including slot refcounts (``users``) and the stored last-position
        logits (admission samples t0 from them, so they must survive a
        restore bit-exactly).  Entry order is preserved (it IS the LRU
        order).  Everything is JSON-compatible except the logits arrays,
        which stay numpy (the scheduler routes them through the
        checkpoint's array tree)."""
        return {
            "page_size": self.page_size,
            "free": [int(p) for p in self._free],
            "counters": {"hits": self.hits, "misses": self.misses,
                         "shared_tokens": self.shared_tokens,
                         "evictions": self.evictions,
                         "exhausted": self.exhausted},
            "entries": [
                {"key": [int(t) for t in key],
                 "pages": [int(p) for p in d["entry"].pages],
                 "tail_page": (None if d["entry"].tail_page is None
                               else int(d["entry"].tail_page)),
                 "length": int(d["entry"].length),
                 "users": sorted(int(s) for s in d["users"]),
                 "logits": np.asarray(d["entry"].logits)}
                for key, d in self._entries.items()
            ],
        }

    def load_state_dict(self, sd: dict):
        """Restore a ``state_dict`` in place (LRU order preserved).  The
        pool pages the entries point at are restored separately — by the
        scheduler's cache restore — so pointers and contents stay
        consistent."""
        if int(sd["page_size"]) != self.page_size:
            raise ValueError(
                f"prefix store page_size mismatch: snapshot has "
                f"{sd['page_size']}, store has {self.page_size}")
        self._free = [int(p) for p in sd["free"]]
        c = sd["counters"]
        self.hits = int(c["hits"])
        self.misses = int(c["misses"])
        self.shared_tokens = int(c["shared_tokens"])
        self.evictions = int(c["evictions"])
        self.exhausted = int(c["exhausted"])
        self._entries = OrderedDict()
        for e in sd["entries"]:
            entry = PrefixEntry(
                pages=tuple(int(p) for p in e["pages"]),
                tail_page=(None if e["tail_page"] is None
                           else int(e["tail_page"])),
                length=int(e["length"]), logits=np.asarray(e["logits"]))
            self._entries[tuple(int(t) for t in e["key"])] = {
                "entry": entry, "users": set(int(s) for s in e["users"])}
