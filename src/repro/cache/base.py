"""The ``KVCache`` protocol: one cache API, many layouts.

Serving used to hardcode a dense-contiguous KV layout as an implicit
convention spread across ``models/attention.py`` (quantize/write helpers),
``launch/steps.py`` (capacity probes), the scheduler (slot splices) and
both Pallas kernels (contiguous BlockSpecs).  This package makes the
layout a first-class, swappable artifact: a cache is a registered JAX
pytree object that knows how to

  * ``ready``         turn raw K/V into cache-ready tiles (quantize ONCE
                      against the frozen per-head thresholds — the paper's
                      §2 scales, static at serve time, which is exactly
                      what makes tiles reusable across requests);
  * ``append``        write a contiguous run of positions (prefill chunks,
                      single-stream decode);
  * ``append_slots``  per-slot one-token writes (continuous batching);
  * ``splice_slot``   receive a batch-1 cache into one slot of a batch
                      cache (scheduler admission);
  * ``dense_view``    materialize (B, S, KV, D) storage-dtype tiles for
                      the jnp reference paths;
  * ``kernel_view``   hand the Pallas kernels (tiles, block-table,
                      tile-size) — dense and ring degenerate to an
                      identity table, so the fused kernels keep ONE
                      compiled executable per piece across layouts.

Three implementations: ``DenseCache`` (position p at slot p),
``RingCache`` (SWA ring: position p at slot ``p % window``), and
``PagedCache`` (page pool + per-slot block table; see
``repro.cache.paged`` — the layout that makes prefix sharing free).

Caches are pytrees, so they flow through jit / lax.scan / donation
untouched; layout metadata (``quantized``, page size) is static aux data,
so switching layouts retraces while switching *contents* (positions,
tables, tiles) never does.  Dict-style access (``cache["k"]``,
``"k_scale" in cache``) is kept as a compatibility shim for tests and
tooling that poke at cache internals.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import ClassVar, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_int4, unpack_int4

# layout name -> concrete KVCache subclass, filled by __init_subclass__;
# ``KVCache.from_state_dict`` dispatches restores through it (import
# repro.cache — not this module — to guarantee every layout is registered)
LAYOUT_REGISTRY: dict = {}

# int8 KV cache uses the symmetric signed-8-bit grid (paper eq. 4); the
# per-head dequant scale T/127 is frozen at finalize_calibration
KV_LEVELS = 127.0


def kv_levels(bits: int) -> float:
    """Symmetric signed level count for a KV bit width (127 / 7)."""
    if bits not in (4, 8):
        raise ValueError(f"kv cache bits must be 4 or 8, got {bits}")
    return float(2 ** (bits - 1) - 1)

# a dead channel (all-zero calibration activations) would hand the cache
# a zero — or, through a NaN-poisoned observer, non-finite — threshold;
# dividing by it in quantize_kv turns the whole int8 cache into inf/NaN.
# Floor at the same 1e-8 threshold floor the matmul path uses, expressed
# as a dequant scale (T / 127).
_SCALE_FLOOR = 1e-8 / KV_LEVELS


def _safe_scale(scale):
    """Clamp per-head dequant scales to a positive finite floor.  The
    ``where`` (not ``maximum``) makes it NaN-robust: maximum(NaN, f) is
    NaN, where(NaN > f, ...) picks the floor.  Healthy calibrated scales
    (>= 1e-8/127 by the observer floor) pass through bit-identically."""
    s = jnp.asarray(scale, jnp.float32)
    return jnp.where(s > _SCALE_FLOOR, s, _SCALE_FLOOR)


def quantize_kv(x, scale, bits: int = 8):
    """(B, S, KV, D) float -> quantized storage tiles with per-head
    dequant ``scale`` (KV,).  ``bits == 8`` emits plain int8; ``bits ==
    4`` clips to the int4 grid (±7) and packs two values per byte along
    the head dim (D -> D/2 storage bytes)."""
    lv = kv_levels(bits)
    s = scale.reshape(1, 1, -1, 1)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -lv, lv).astype(
        jnp.int8)
    if bits == 4:
        q = pack_int4(q, axis=-1)
    return q


def dequantize_kv(x_q, scale, bits: int = 8):
    """Quantized cache tiles -> f32 with per-head dequant ``scale``
    (KV,); int4 tiles unpack their nibbles first."""
    if bits == 4:
        x_q = unpack_int4(x_q, axis=-1)
    return x_q.astype(jnp.float32) * scale.reshape(1, 1, -1, 1)


class KernelView(NamedTuple):
    """What the fused Pallas kernels consume, layout-independently.

    ``k``/``v``: KV tiles — dense/ring pass (B, S, KV, D) contiguous
    storage with ``block_table is None`` (the kernel wrapper builds the
    identity table); paged passes the (pages, page_size, KV, D) pool with
    a (B, n_blocks) table and ``tile == page_size``.  ``bits`` carries
    the storage width: at 4 the tiles hold packed nibbles (last dim is
    D/2 bytes) and the kernels fold one unpack into their dequant
    epilogue.
    """
    k: jax.Array
    v: jax.Array
    block_table: Optional[jax.Array]
    tile: Optional[int]
    bits: int = 8


class KVCache(abc.ABC):
    """Layout-agnostic KV cache protocol (see module docstring).

    Subclasses are frozen dataclasses registered as pytrees: array fields
    are children, everything else (``quantized``, page size) is static
    aux data.  All shape math uses trailing axes so a scanned-layer stack
    (leading ``(L,)`` axis on every leaf) flows through the scheduler's
    splice/table ops unchanged.
    """

    layout: ClassVar[str] = "abstract"

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        layout = cls.__dict__.get("layout")
        if layout is not None and layout != "abstract":
            LAYOUT_REGISTRY[layout] = cls

    # -- static structure --------------------------------------------------
    @property
    def quantized(self) -> bool:
        return self._quantized

    @property
    def capacity(self) -> int:
        """Logical sequence capacity of one slot."""
        return self.k.shape[-3]

    @property
    def n_kv(self) -> int:
        return self.k.shape[-2]

    @property
    def head_dim(self) -> int:
        """STORAGE width of the last axis (packed bytes at bits == 4 —
        half the logical head dim; ``dequantize`` restores D)."""
        return self.k.shape[-1]

    # -- scales ------------------------------------------------------------
    def scales(self):
        """Per-head dequant scales (ones for a float cache): the kernels
        accept float tiles through the same code path with unit scales."""
        return self.k_scale, self.v_scale

    def with_scales(self, k_scale, v_scale) -> "KVCache":
        """Install calibrated per-head dequant scales — the single entry
        point where thresholds reach a cache, so the dead-channel floor
        (``_safe_scale``) is applied here once for every layout."""
        return dataclasses.replace(
            self, k_scale=_safe_scale(k_scale), v_scale=_safe_scale(v_scale))

    def ready(self, k, v):
        """Cache-ready K/V tiles: quantize against the frozen per-head
        scales when the cache stores int8, else cast to the storage dtype.
        The single quantize-on-append point for every layout and both
        phases — K/V quantize ONCE and the same tiles feed attention and
        the cache write."""
        if self.quantized:
            return (quantize_kv(k, self.k_scale, self.bits),
                    quantize_kv(v, self.v_scale, self.bits))
        return k.astype(self.k.dtype), v.astype(self.v.dtype)

    def dequantize(self, k_tiles, v_tiles):
        """Storage tiles -> f32 for the jnp reference attention paths
        (int4 tiles unpack back to the logical head dim here)."""
        if not self.quantized:
            return k_tiles, v_tiles
        return (dequantize_kv(k_tiles, self.k_scale, self.bits),
                dequantize_kv(v_tiles, self.v_scale, self.bits))

    # -- writes ------------------------------------------------------------
    @abc.abstractmethod
    def append(self, kq, vq, start) -> "KVCache":
        """Write cache-ready tiles at positions [start, start + len)."""

    @abc.abstractmethod
    def append_slots(self, kq, vq, starts, active=None) -> "KVCache":
        """Per-slot append: batch row b writes its (B, s, KV, D) tiles at
        positions ``starts[b] + [0, s)`` (s == 1 is the decode step; s > 1
        is the speculative verify window); ``active`` masks rows
        bit-neutrally."""

    def rollback(self, pos, private_row=None) -> "KVCache":
        """Logically rewind slot b to ``pos[b]`` valid entries (the
        speculative-decode reject path).  Dense/ring: a no-op — entries at
        positions >= pos are DEAD data that the masks never read and the
        next append overwrites, so the rewind is pure position
        bookkeeping in the caller's carry.  ``PagedCache`` overrides:
        with ``private_row`` it re-points rewound table blocks at the
        slot's private pages, copy-on-rewind for the boundary block, so a
        rewind into a SHARED prefix page never lets a later append mutate
        refcounted storage."""
        del pos, private_row
        return self

    # -- reads -------------------------------------------------------------
    @abc.abstractmethod
    def dense_view(self, limit: Optional[int] = None):
        """(k, v) as contiguous (B, S', KV, D) storage-dtype tiles, where
        S' = ``limit`` (static) or the full capacity."""

    @abc.abstractmethod
    def kernel_view(self, limit: Optional[int] = None) -> KernelView:
        """Tiles + block-table for the fused kernels (see KernelView)."""

    # -- scheduler ---------------------------------------------------------
    def splice_slot(self, slot_cache: "KVCache", slot) -> "KVCache":
        """Receive a batch-1 cache into batch row ``slot``.  Handles an
        optional leading layer axis (batch axis is always ndim-4 on KV
        leaves).  Scale leaves are request-independent (frozen
        calibration) and identical for every admission: take the slot
        cache's copy wholesale, which also fixes up the ones-initialized
        scales of a never-admitted batch cache."""
        slot = jnp.asarray(slot, jnp.int32)

        def write(big, small):
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, big.ndim - 4)

        return dataclasses.replace(
            self,
            k=write(self.k, slot_cache.k), v=write(self.v, slot_cache.v),
            k_scale=slot_cache.k_scale, v_scale=slot_cache.v_scale)

    # -- dict-style compat shim -------------------------------------------
    _KEYS = ("k", "v", "k_scale", "v_scale")

    def __getitem__(self, key):
        if key in ("k_scale", "v_scale") and not self.quantized:
            raise KeyError(key)  # float caches had no scale entries
        if key in self._KEYS:
            return getattr(self, key)
        raise KeyError(key)

    def __contains__(self, key):
        try:
            self[key]
        except KeyError:
            return False
        return True

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    # -- pytree plumbing ---------------------------------------------------
    # static (aux) dataclass fields; everything else is an array child.
    # Children flatten WITH DictKeys named like the old cache dicts
    # ("k", "v", "k_scale", ...) so path-based tooling — dist/sharding's
    # cache_specs classifies KV leaves by their ``"k"``/``"v"`` path key —
    # keeps working across the dict -> protocol migration.
    _static = ("_quantized", "bits")

    @classmethod
    def _child_names(cls):
        return tuple(f.name for f in dataclasses.fields(cls)
                     if f.name not in cls._static)

    def tree_flatten(self):
        return (tuple(getattr(self, n) for n in self._child_names()),
                tuple(getattr(self, s) for s in self._static))

    def tree_flatten_with_keys(self):
        children = tuple((jax.tree_util.DictKey(n), getattr(self, n))
                         for n in self._child_names())
        return children, tuple(getattr(self, s) for s in self._static)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kw = dict(zip(cls._child_names(), children))
        kw.update(zip(cls._static, aux))
        return cls(**kw)

    # -- durable serving (snapshot/restore) --------------------------------
    def state_dict(self) -> dict:
        """Host-side serializable snapshot of this cache: the layout name,
        the static aux fields, and every array child as a numpy array.
        Round-trips through ``from_state_dict`` bit-exactly — the
        snapshot-recovery half of the durability story (the journal-replay
        half never saves device state at all: FAT's frozen thresholds make
        the int8 cache recomputable from the token sequence)."""
        return {
            "layout": type(self).layout,
            "static": {s: getattr(self, s) for s in self._static},
            "arrays": {n: np.asarray(jax.device_get(getattr(self, n)))
                       for n in self._child_names()},
        }

    @staticmethod
    def from_state_dict(sd: dict) -> "KVCache":
        """Rebuild a cache from a ``state_dict`` (possibly round-tripped
        through ``checkpoint.manager.CheckpointManager``, which boxes
        scalars as 0-d arrays — coerced back here)."""
        layout = _unbox(sd["layout"])
        cls = LAYOUT_REGISTRY.get(layout)
        if cls is None:
            raise ValueError(
                f"unknown cache layout {layout!r} in state dict "
                f"(registered: {sorted(LAYOUT_REGISTRY)})")
        static = {k: _unbox(v) for k, v in sd["static"].items()}
        # pre-int4 snapshots carry no bit width: they are int8 by
        # construction, so default rather than reject
        static.setdefault("bits", 8)
        missing = set(cls._static) - set(static)
        if missing:
            raise ValueError(
                f"{layout} cache state dict missing static field(s) "
                f"{sorted(missing)}")
        arrays = {k: jnp.asarray(v) for k, v in sd["arrays"].items()}
        want = set(cls._child_names())
        if set(arrays) != want:
            raise ValueError(
                f"{layout} cache state dict arrays mismatch: got "
                f"{sorted(arrays)}, want {sorted(want)}")
        return cls(**arrays, **static)


def _unbox(v):
    """Undo the 0-d numpy boxing a npz round-trip applies to python
    scalars/strings (CheckpointManager flattens every leaf to an array)."""
    if isinstance(v, np.ndarray) and v.ndim == 0:
        v = v.item()
    if isinstance(v, np.generic):
        v = v.item()
    if isinstance(v, (bytes, np.bytes_)):
        v = v.decode()
    return v


def _zeros_kv(batch, seq, n_kv, head_dim, dtype, quantized, bits=8):
    # four DISTINCT buffers: donation (serve.py donates the cache into
    # the decode loop) rejects the same buffer appearing as two leaves
    if quantized and bits == 4:
        if head_dim % 2:
            raise ValueError(
                f"int4 KV packing needs an even head dim, got {head_dim}")
        head_dim //= 2  # two nibbles per stored byte
    kd = (batch, seq, n_kv, head_dim)
    store = jnp.int8 if quantized else dtype
    return (jnp.zeros(kd, store), jnp.zeros(kd, store),
            jnp.ones((n_kv,), jnp.float32), jnp.ones((n_kv,), jnp.float32))


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class DenseCache(KVCache):
    """Contiguous layout: position p lives at slot p.  Required by chunked
    prefill and the slot scheduler (absolute slots)."""

    layout: ClassVar[str] = "dense"

    k: jax.Array          # (B, S, KV, D) int8 or float (D/2 at bits == 4)
    v: jax.Array
    k_scale: jax.Array    # (KV,) f32 (ones when not quantized)
    v_scale: jax.Array
    _quantized: bool = dataclasses.field(default=False)
    bits: int = dataclasses.field(default=8)

    @classmethod
    def init(cls, batch, max_len, n_kv, head_dim, *, dtype=jnp.bfloat16,
             quantized=False, bits=8):
        return cls(*_zeros_kv(batch, max_len, n_kv, head_dim, dtype,
                              quantized, bits), _quantized=quantized,
                   bits=bits)

    def append(self, kq, vq, start):
        ax = self.k.ndim - 3
        return dataclasses.replace(
            self,
            k=jax.lax.dynamic_update_slice_in_dim(self.k, kq, start, ax),
            v=jax.lax.dynamic_update_slice_in_dim(self.v, vq, start, ax))

    def append_slots(self, kq, vq, starts, active=None):
        """kq/vq: (B, s, KV, D); starts: (B,) int32 (s == 1 is the decode
        step, s > 1 the speculative verify window).  An inactive slot
        reads back the tiles at its (clamped) write index and writes them
        unchanged — a masked step is bit-exact cache-neutral.  Out-of-
        range starts clamp (XLA dynamic-slice semantics); the decode
        loops deactivate capacity-full slots before they could clamp
        while active."""
        starts = jnp.asarray(starts, jnp.int32)
        s = kq.shape[1]

        def write_one(c, u, st):          # c: (S, KV, D), u: (s, KV, D)
            return jax.lax.dynamic_update_slice_in_dim(c, u, st, 0)

        if active is not None:
            def read_one(c, st):
                return jax.lax.dynamic_slice_in_dim(c, st, s, 0)

            sel = active[:, None, None, None]
            kq = jnp.where(sel, kq, jax.vmap(read_one)(self.k, starts))
            vq = jnp.where(sel, vq, jax.vmap(read_one)(self.v, starts))
        return dataclasses.replace(
            self,
            k=jax.vmap(write_one)(self.k, kq, starts),
            v=jax.vmap(write_one)(self.v, vq, starts))

    def dense_view(self, limit=None):
        if limit is None or limit >= self.capacity:
            return self.k, self.v
        return self.k[..., :limit, :, :], self.v[..., :limit, :, :]

    def kernel_view(self, limit=None):
        k, v = self.dense_view(limit)
        return KernelView(k, v, None, None, self.bits)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class RingCache(KVCache):
    """SWA ring buffer: capacity == window, position p lives at slot
    ``p % window`` (decode relies on this invariant).  Rings keep the
    scalar-position decode contract: per-slot decode and chunked prefill
    drop absolute slots, so both reject rings upstream."""

    layout: ClassVar[str] = "ring"

    k: jax.Array          # (B, window, KV, D) (D/2 at bits == 4)
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    _quantized: bool = dataclasses.field(default=False)
    bits: int = dataclasses.field(default=8)

    @classmethod
    def init(cls, batch, window, n_kv, head_dim, *, dtype=jnp.bfloat16,
             quantized=False, bits=8):
        return cls(*_zeros_kv(batch, window, n_kv, head_dim, dtype,
                              quantized, bits), _quantized=quantized,
                   bits=bits)

    @property
    def window(self) -> int:
        return self.capacity

    def append(self, kq, vq, start):
        """Single-token writes land at ``start % window``; a whole-prompt
        write (``start == 0``, s tokens) keeps the last ``window`` entries
        rolled so position p sits at slot ``p % window``."""
        ax = self.k.ndim - 3
        s = kq.shape[ax]
        cap = self.capacity
        if s == 1:
            idx = start % cap
            return dataclasses.replace(
                self,
                k=jax.lax.dynamic_update_slice_in_dim(self.k, kq, idx, ax),
                v=jax.lax.dynamic_update_slice_in_dim(self.v, vq, idx, ax))
        # one-shot prompt write: keep the last `window` positions and roll
        # them into ring order (static shapes — s and cap are trace-time)
        keep = min(s, cap)
        kk = jax.lax.slice_in_dim(kq, s - keep, s, axis=ax)
        vv = jax.lax.slice_in_dim(vq, s - keep, s, axis=ax)
        if keep == cap:
            shift = (s - keep) % cap
            kk = jnp.roll(kk, shift, axis=ax)
            vv = jnp.roll(vv, shift, axis=ax)
        return dataclasses.replace(
            self,
            k=jax.lax.dynamic_update_slice_in_dim(self.k, kk, 0, ax),
            v=jax.lax.dynamic_update_slice_in_dim(self.v, vv, 0, ax))

    def append_slots(self, kq, vq, starts, active=None):
        raise NotImplementedError(
            "per-slot decode needs absolute slots; the SWA ring buffer "
            "keeps the scalar-position contract (use a dense or paged "
            "cache sized >= max_len)")

    def abs_positions(self, cur_pos):
        """(window,) absolute position held by each ring slot, given the
        newest token's absolute position ``cur_pos``."""
        cap = self.capacity
        idx = cur_pos % cap
        slot = jnp.arange(cap)
        return jnp.where(slot <= idx, cur_pos - (idx - slot),
                         cur_pos - (idx + cap - slot))

    def dense_view(self, limit=None):
        return self.k, self.v   # ring storage IS its attended extent

    def kernel_view(self, limit=None):
        return KernelView(self.k, self.v, None, None, self.bits)
