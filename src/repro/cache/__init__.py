"""One KV-cache API: dense / SWA-ring / paged layouts behind the
``KVCache`` protocol (see repro.cache.base for the contract).

``make_cache`` is the single construction point the model layers use;
``layout`` semantics:

  * ``"ring"``  (default) — today's behavior: sliding-window layers get a
    window-sized ring buffer, everything else a dense cache.
  * ``"dense"`` — force dense everywhere (the slot scheduler's
    requirement: absolute slots).
  * ``"paged"`` — page-pool + block-table for non-windowed layers
    (windowed layers keep their ring: a window-bounded buffer is already
    the right layout for SWA).
"""
from repro.cache.base import (DenseCache, KernelView, KVCache, KV_LEVELS,
                              LAYOUT_REGISTRY, RingCache, dequantize_kv,
                              quantize_kv)
from repro.cache.paged import (PagedCache, PrefixEntry, PrefixStore,
                               copy_pages, set_table_row,
                               splice_dense_into_pages)

LAYOUTS = ("dense", "ring", "paged")


def make_cache(batch, max_len, n_kv, head_dim, *, dtype, quantized=False,
               layout="ring", window=None, page_size=64, extra_pages=0):
    """Build the right ``KVCache`` for one attention layer (see module
    docstring for the layout semantics)."""
    if layout not in LAYOUTS:
        raise ValueError(f"unknown cache layout {layout!r} (use one of "
                         f"{LAYOUTS})")
    if window is not None and layout != "dense" and window < max_len:
        return RingCache.init(batch, window, n_kv, head_dim, dtype=dtype,
                              quantized=quantized)
    if layout == "paged":
        return PagedCache.init(batch, max_len, n_kv, head_dim, dtype=dtype,
                               quantized=quantized, page_size=page_size,
                               extra_pages=extra_pages)
    return DenseCache.init(batch, max_len, n_kv, head_dim, dtype=dtype,
                           quantized=quantized)


__all__ = [
    "KVCache", "KernelView", "DenseCache", "RingCache", "PagedCache",
    "PrefixStore", "PrefixEntry", "make_cache", "quantize_kv",
    "dequantize_kv", "copy_pages", "set_table_row",
    "splice_dense_into_pages", "KV_LEVELS", "LAYOUTS", "LAYOUT_REGISTRY",
]
