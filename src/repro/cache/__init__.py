"""One KV-cache API: dense / SWA-ring / paged layouts behind the
``KVCache`` protocol (see repro.cache.base for the contract).

``make_cache`` is the single construction point the model layers use;
``layout`` semantics:

  * ``"ring"``  (default) — today's behavior: sliding-window layers get a
    window-sized ring buffer, everything else a dense cache.
  * ``"dense"`` — force dense everywhere (the slot scheduler's
    requirement: absolute slots).
  * ``"paged"`` — page-pool + block-table for non-windowed layers
    (windowed layers keep their ring: a window-bounded buffer is already
    the right layout for SWA).

``bits`` picks the quantized storage width (8 = int8, 4 = packed int4
nibbles along the head dim — half the cache bytes); it is static aux
data on every layout, rides ``scales``/``state_dict``, and the fused
kernels fold the nibble unpack into their dequant epilogue.
"""
from repro.cache.base import (DenseCache, KernelView, KVCache, KV_LEVELS,
                              LAYOUT_REGISTRY, RingCache, dequantize_kv,
                              kv_levels, quantize_kv)
from repro.cache.paged import (PagedCache, PrefixEntry, PrefixStore,
                               copy_pages, set_table_row,
                               splice_dense_into_pages)
from repro.core.packing import pack_int4, unpack_int4

LAYOUTS = ("dense", "ring", "paged")
KV_BITS = (8, 4)


def make_cache(batch, max_len, n_kv, head_dim, *, dtype, quantized=False,
               layout="ring", window=None, page_size=64, extra_pages=0,
               bits=8):
    """Build the right ``KVCache`` for one attention layer (see module
    docstring for the layout and bit-width semantics)."""
    if layout not in LAYOUTS:
        raise ValueError(f"unknown cache layout {layout!r} (use one of "
                         f"{LAYOUTS})")
    if bits not in KV_BITS:
        raise ValueError(f"unknown kv cache bits {bits!r} (use one of "
                         f"{KV_BITS})")
    if window is not None and layout != "dense" and window < max_len:
        return RingCache.init(batch, window, n_kv, head_dim, dtype=dtype,
                              quantized=quantized, bits=bits)
    if layout == "paged":
        return PagedCache.init(batch, max_len, n_kv, head_dim, dtype=dtype,
                               quantized=quantized, page_size=page_size,
                               extra_pages=extra_pages, bits=bits)
    return DenseCache.init(batch, max_len, n_kv, head_dim, dtype=dtype,
                           quantized=quantized, bits=bits)


__all__ = [
    "KVCache", "KernelView", "DenseCache", "RingCache", "PagedCache",
    "PrefixStore", "PrefixEntry", "make_cache", "quantize_kv",
    "dequantize_kv", "copy_pages", "set_table_row",
    "splice_dense_into_pages", "KV_LEVELS", "kv_levels", "pack_int4",
    "unpack_int4", "LAYOUTS", "KV_BITS", "LAYOUT_REGISTRY",
]
