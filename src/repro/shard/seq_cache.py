"""Owner-shard KV-cache writes for sequence parallelism.

Under sp > 1 each shard holds rows [i*S_local, (i+1)*S_local) of the
dense cache's S axis.  Appends arrive with GLOBAL positions (prefill
chunk offsets, per-slot decode positions), so each shard must keep the
rows it owns and drop the rest — and it must drop them EXACTLY:
``dynamic_update_slice`` clamps out-of-range starts, which would smear a
neighbor's rows over this shard's boundary.  Every write here therefore
goes through ``.at[...].set(mode="drop")`` with an explicit
out-of-bounds sentinel index (S_local): a row the shard does not own is
routed to the sentinel and dropped, bit-exact, whether it is a prefill
chunk straddling a shard boundary, a decode token, or an inactive
scheduler slot.

Reads that need the whole sequence (chunked prefill, speculative
verify) gather the int8 tiles with ``all_gather`` — integer payload on
the wire, and a gather (not an all-reduce), so both dtype contracts
(jaxpr drift.collective, HLO integer-all-reduce) hold without new
exemptions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def shard_slice_info(cache, axis_name: str):
    """(shard_index, s_local) for this shard's cache slice (trace-time
    shapes are already local inside shard_map)."""
    return jax.lax.axis_index(axis_name), cache.k.shape[-3]


def owner_append(cache, kq, vq, start, axis_name: str):
    """Append ``s`` cache-ready rows at global position ``start``
    (scalar; prefill chunks).  Each shard keeps its own rows."""
    idx, s_local = shard_slice_info(cache, axis_name)
    s = kq.shape[1]
    local = start + jnp.arange(s, dtype=jnp.int32) - idx * s_local
    local = jnp.where((local >= 0) & (local < s_local), local, s_local)
    k = cache.k.at[:, local].set(kq, mode="drop")
    v = cache.v.at[:, local].set(vq, mode="drop")
    return dataclasses.replace(cache, k=k, v=v)


def owner_append_slots(cache, kq, vq, pos_vec, axis_name: str, *,
                       active=None):
    """Per-slot append: slot b writes its ``s`` rows at global positions
    ``pos_vec[b] + [0, s)`` (decode s=1, speculative-verify windows).
    Inactive slots write nothing (their indices route to the drop
    sentinel), matching ``KVCache.append_slots``'s cache-neutral
    contract."""
    idx, s_local = shard_slice_info(cache, axis_name)
    b, s = kq.shape[0], kq.shape[1]
    pos = jnp.asarray(pos_vec, jnp.int32).reshape(-1)
    local = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None] \
        - idx * s_local                                           # (B, s)
    oob = (local < 0) | (local >= s_local)
    if active is not None:
        oob = oob | ~active[:, None]
    local = jnp.where(oob, s_local, local)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    k = cache.k.at[rows, local].set(kq, mode="drop")
    v = cache.v.at[rows, local].set(vq, mode="drop")
    return dataclasses.replace(cache, k=k, v=v)


def gathered_dense(cache, axis_name: str, limit: int | None = None):
    """The GLOBAL dequantized (k, v) view of a sequence-sharded cache.

    All-gathers the stored int8 tiles along the S axis (tiled=True
    concatenates in shard order — exactly the unsharded layout) and
    dequantizes with the replicated per-head scales, so the result is
    bit-identical to the unsharded cache's ``dense_view``.  ``limit``
    truncates AFTER the gather (a static bound; chunked prefill passes
    the padded prompt length)."""
    kg = jax.lax.all_gather(cache.k, axis_name, axis=1, tiled=True)
    vg = jax.lax.all_gather(cache.v, axis_name, axis=1, tiled=True)
    if limit is not None:
        kg, vg = kg[:, :limit], vg[:, :limit]
    return cache.dequantize(kg, vg)
