"""Cross-shard partial-softmax combine for sequence-parallel flash-decode.

With the KV cache's S axis split across ``sp`` shards, each shard can
only score its local keys.  Flash-decode's online-softmax state makes
the split exact: a shard emits (m, l, acc) — running max, normalizer,
and UNNORMALIZED value accumulator over its local visible keys — and
the merge

    M     = max_i m_i
    l_tot = sum_i l_i * exp(m_i - M)
    out   = sum_i acc_i * exp(m_i - M) / l_tot

reproduces the unsharded softmax up to f32 summation order (ulp-bounded;
the parity tests in tests/test_sharded.py pin it).  A shard with zero
visible keys contributes l = 0 / acc = 0 (its masked scores sit at the
finite NEG_INF sentinel, and the mask multiplies its probabilities to
exact zero), so inactive slots keep the engine's exact-zero-rows
convention through the merge.

Wire discipline: the partials are gathered with ``all_gather`` — a
float payload, but NOT an all-reduce, so the HLO-level "every all-reduce
carries integer bytes" assertion (launch/hlo_analysis.py) stays strict.
At the jaxpr level the gather is sanctioned by the ``drift.collective``
AllowRule scoped to ``sp_partial_combine`` (the per-token partial state
is KV*G*D floats per slot — orders of magnitude below the S-sized K/V
stream the sharding exists to avoid moving).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, _gqa_scores


def local_decode_partials(q, k_local, v_local, valid_local):
    """Per-shard flash-decode partials.

    q: (B, 1, KV, G, D) — the decode query (replicated across shards);
    k_local/v_local: (B, S_local, KV, D) — this shard's cache slice
    (already dequantized); valid_local: (B,) — number of visible keys
    IN THIS SHARD (clip(valid_global - shard*S_local, 0, S_local)).

    Returns (m, l, acc): (B, KV, G, 1), (B, KV, G, 1), (B, KV, G, 1, D)
    f32.  Scores masked beyond valid_local sit at NEG_INF and their
    probabilities are multiplied to exact zero, so a shard with nothing
    visible returns (NEG_INF, 0, 0) — the merge identity element.
    """
    b = q.shape[0]
    d = q.shape[-1]
    s_local = k_local.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = _gqa_scores(q.astype(jnp.float32) * scale,
                    k_local.astype(jnp.float32))       # (B, KV, G, 1, S_l)
    valid = jnp.broadcast_to(
        jnp.asarray(valid_local, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(s_local)[None, :] < valid[:, None]          # (B, S_l)
    maskb = mask[:, None, None, None, :]
    s = jnp.where(maskb, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                       # (B,KV,G,1)
    p = jnp.exp(s - m[..., None]) * maskb                         # exact 0s
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqs,bskd->bkgqd", p, v_local.astype(jnp.float32))
    return m, l, acc


def sp_partial_combine(m, l, acc, axis_name: str):
    """Merge per-shard (m, l, acc) partials into the exact softmax output.

    All inputs are this shard's locals; the function all-gathers them
    over ``axis_name`` (shard count known statically from the mesh) and
    reduces in deterministic shard-index order, so every shard computes
    the identical merged value — the combined output is replicated.

    Returns (B, 1, KV, G, D) in f32 (callers cast to the residual
    dtype).  All-empty rows (l_tot == 0, e.g. an inactive scheduler
    slot) return exact zeros.
    """
    # float payloads — gathers, not reduces; see module docstring
    mg = jax.lax.all_gather(m, axis_name)        # (sp, B, KV, G, 1)
    lg = jax.lax.all_gather(l, axis_name)
    ag = jax.lax.all_gather(acc, axis_name)      # (sp, B, KV, G, 1, D)
    m_tot = jnp.max(mg, axis=0)
    # NEG_INF is a finite sentinel: an all-empty row has m_i == M, the
    # weights come out exp(0) == 1, and the l_i == 0 terms still produce
    # l_tot == 0 — the zero-guard below owns that case, never a NaN
    w = jnp.exp(mg - m_tot[None])                # (sp, B, KV, G, 1)
    l_tot = jnp.sum(lg * w, axis=0)
    o = jnp.sum(ag * w[..., None], axis=0)
    o = o / jnp.maximum(l_tot[..., None], 1e-30)
    o = o * (l_tot[..., None] > 0)
    # (B, KV, G, 1, D) -> (B, 1, KV, G, D): the attention output layout
    return jnp.moveaxis(o, 3, 1)
