"""Trace-time shard context for the sharded serving engine.

``ShardedModel`` traces the (local-config) model INSIDE a ``shard_map``
body; the layers deep in that trace — ``core/api.py::dense_forward``'s
row-parallel epilogue, ``models/attention.py``'s sequence-parallel cache
writes — need to know the parallelism layout without threading a new
argument through every Module signature.  This module is that side
channel: a process-global, trace-time-only context installed around the
``shard_map`` body by ``ShardedModel`` and consulted lazily by the
hooks.  It holds ONLY static trace facts (axis name, shard counts) —
never tracers — so installing it is free and forgetting to clear it
cannot leak device state.

Outside any ``shard_scope`` every query returns None and all hooks are
inert: the unsharded engine's traces are bit-identical to before the
subsystem existed.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """Static parallelism facts for the trace under construction.

    ``axis`` is the mesh axis name both tp and sp shard over (they are
    mutually exclusive on the 1-axis serving mesh); ``tp``/``sp`` are
    the shard counts (1 = off).
    """

    axis: str = "model"
    tp: int = 1
    sp: int = 1

    def __post_init__(self):
        if self.tp > 1 and self.sp > 1:
            raise ValueError(
                "tp and sp share the one 'model' mesh axis — run one of "
                "them per engine (tp*sp composition needs a 2-axis mesh)")


_CURRENT: Optional[ShardContext] = None


def current_shard() -> Optional[ShardContext]:
    """The installed context, or None (the unsharded default)."""
    return _CURRENT


@contextlib.contextmanager
def shard_scope(ctx: ShardContext):
    """Install ``ctx`` for the duration of a trace (re-entrant; restores
    the previous context on exit even when tracing raises)."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield ctx
    finally:
        _CURRENT = prev


def tp_shard_info() -> Optional[ShardContext]:
    """The context iff tensor parallelism is active (tp > 1)."""
    c = _CURRENT
    return c if c is not None and c.tp > 1 else None


def sp_shard_info() -> Optional[ShardContext]:
    """The context iff sequence parallelism is active (sp > 1)."""
    c = _CURRENT
    return c if c is not None and c.sp > 1 else None
