"""``ShardedEngine`` — the sharded-serving facade.

An :class:`~repro.launch.engine.Engine` whose model is a
:class:`~repro.shard.model.ShardedModel` over a one-axis device mesh
(launch/mesh.py::make_serving_mesh).  Everything above the model surface
— continuous batching, speculative decode, chunked prefill, journaling,
snapshot/restore — is inherited UNCHANGED: the scheduler and strategies
call the same four serving entry points, which now run under shard_map.

    eng = ShardedEngine.from_checkpoint(arch="smollm-135m", smoke=True,
                                        tp=2, cache_layout="dense")
    out = eng.generate_batch(batch, gen=16)     # token-identical to tp=1

Construction knobs on top of Engine's:

``tp`` / ``sp``
    Tensor-parallel / sequence-parallel shard counts (mutually
    exclusive — they share the one mesh axis).  ``tp > 1`` requires
    ``mode == 'int8'`` (the row epilogues reduce int32 accumulators);
    ``sp > 1`` requires a dense-compatible cache layout (the S axis of
    a paged pool has no contiguous shard slices).
``mesh``
    An explicit mesh (tests reuse one); default builds
    ``make_serving_mesh(max(tp, sp))`` over host-local devices — on
    CPU, ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``dry_run_report`` compiles the sharded prefill + decode executables
and proves the interconnect dtype contract on their post-optimization
HLO: every serving-path all-reduce carries integer payload bytes
(launch/hlo_analysis.py::check_integer_all_reduces, one sanctioned
scalar f32 pmax), alongside the roofline's collective-byte counts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.engine import Engine
from repro.shard.model import ShardedModel


class ShardedEngine(Engine):
    """Engine over a tensor- or sequence-parallel mesh; see module
    docstring.  With ``tp == sp == 1`` this is exactly an Engine (no
    mesh, no shard_map) — the dry-run/degenerate mode."""

    def __init__(self, model, cfg, policy, serve_params, qparams, *,
                 tp: int = 1, sp: int = 1, mesh=None,
                 mesh_axis: str = "model", **engine_kw):
        if tp < 1 or sp < 1:
            raise ValueError(f"tp/sp must be >= 1, got tp={tp} sp={sp}")
        mode = engine_kw.get("mode", "int8")
        if tp > 1 and mode != "int8":
            raise ValueError(
                f"tensor-parallel serving requires mode='int8' (got "
                f"{mode!r}): the row epilogues reduce int32 accumulators "
                "— float weights have nothing exact to psum")
        if sp > 1 and engine_kw.get("cache_layout", "ring") == "paged":
            raise ValueError(
                "sequence-parallel serving shards the dense cache's S "
                "axis — the paged pool has no contiguous shard slices "
                "(use cache_layout='dense' or 'ring')")
        n = max(tp, sp)
        if mesh is None and n > 1:
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(n, axis=mesh_axis)
        self.tp, self.sp = tp, sp
        self.mesh, self.mesh_axis = mesh, mesh_axis
        if n > 1:
            # ShardedModel validates exclusivity, divisibility, mesh size
            model = ShardedModel(model, cfg, mesh, tp=tp, sp=sp,
                                 axis=mesh_axis)
        super().__init__(model, cfg, policy, serve_params, qparams,
                         **engine_kw)

    # -- interconnect dtype contract ---------------------------------------
    def dry_run_report(self, *, batch: int = 2, prompt_len: int = 32,
                       cache_len: Optional[int] = None) -> dict:
        """Compile this engine's prefill + decode executables and audit
        their post-optimization HLO.

        Returns, per executable: the roofline's ``collective_bytes`` /
        ``collective_by_kind``, the all-reduce payload list, and the
        integer-all-reduce verdict.  Top-level ``int8_all_reduces_ok``
        is the AND over executables — the assertion the ``sharded`` CI
        lane enforces.  Compiles on the engine's mesh (host-local
        devices), so this doubles as the pre-deploy smoke that the
        sharded executables build at all.
        """
        from repro.launch import hlo_analysis as H
        from repro.launch import steps as ST

        if cache_len is None:
            cache_len = self._cache_len(prompt_len, 32)
        cache = self.init_cache(batch, cache_len)
        toks = jnp.zeros((batch, prompt_len), jnp.int32)
        prefill = jax.jit(ST.make_prefill_step(
            self.model, self.cfg, self.policy, self.mode))
        decode = jax.jit(ST.make_serve_step(
            self.model, self.cfg, self.policy, self.mode))
        entries = {
            "prefill": (prefill, (self.serve_params, self.qparams,
                                  {"tokens": toks}, cache)),
            "decode": (decode, (self.serve_params, self.qparams,
                                jnp.zeros((batch, 1), jnp.int32), cache,
                                jnp.int32(prompt_len))),
        }
        report = {"tp": self.tp, "sp": self.sp, "executables": {}}
        all_ok = True
        for name, (fn, args) in entries.items():
            hlo = fn.lower(*args).compile().as_text()
            costs = H.analyze(hlo)
            ok, findings = H.check_integer_all_reduces(hlo)
            all_ok &= ok
            report["executables"][name] = {
                "collective_bytes": costs.collective_bytes,
                "collective_by_kind": {
                    k: v for k, v in costs.collective_by_kind.items()
                    if v > 0},
                "all_reduce_payloads": H.all_reduce_payloads(hlo),
                "int8_all_reduces_ok": ok,
                "findings": findings,
            }
        report["int8_all_reduces_ok"] = all_ok
        return report
