"""Sharded serving: tensor + sequence-parallel engine over int8 collectives.

The subsystem wires the Engine to a device mesh (docs/serving.md
"Sharded serving"):

  * ``ShardedModel`` — wraps the CausalLM method surface in
    ``dist/compat.py::shard_map`` so every step maker, decode strategy
    and the slot scheduler run UNCHANGED on the sharded path.
  * tensor parallelism (tp) — Megatron-style head/ffn split; row
    epilogues psum int32 accumulators through ``compressed_psum``
    (bit-exact AND integer-on-the-wire).
  * sequence parallelism (sp) — the KV cache's S axis splits across
    shards; decode merges per-shard flash partials (m, l, acc) into the
    exact unsharded softmax (``partial_softmax.sp_partial_combine``).
  * ``ShardedEngine`` — the Engine facade with --tp/--sp/--mesh knobs.
"""
from repro.shard.context import (ShardContext, current_shard, shard_scope,
                                 sp_shard_info, tp_shard_info)
from repro.shard.engine import ShardedEngine
from repro.shard.model import ShardedModel

__all__ = [
    "ShardContext", "ShardedEngine", "ShardedModel", "current_shard",
    "shard_scope", "sp_shard_info", "tp_shard_info",
]
