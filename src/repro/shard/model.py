"""``ShardedModel`` — the serving model surface mapped over a device mesh.

Wraps the global (unsharded) model and re-exposes exactly the four
serving entry points the steps/scheduler/strategies layer calls —
``prefill`` / ``prefill_chunk`` / ``decode_step`` / ``verify_step`` —
each run through ``shard_map`` over the one-axis serving mesh
(launch/mesh.py).  Everything else (``init_cache``, ``readout_fn``,
``fold_plan``, ...) delegates to the wrapped model untouched, so the
engine, the scheduler, continuous batching, speculative decode and
recovery drive a ShardedModel exactly like the model it wraps.

Two parallelism modes, mutually exclusive (they share the one mesh axis;
``ShardContext`` enforces it):

Tensor parallel (``tp > 1``)
    The *inner* model is rebuilt with a LOCAL config —
    ``n_heads/tp``, ``n_kv_heads/tp``, ``d_ff/tp`` — so inside
    ``shard_map`` every trace sees ordinary local shapes and zero model
    code changes.  Params shard by role (dist/sharding.py:
    column-parallel projections split their output axis, row-parallel
    ones their input axis), the KV cache splits its head axis, and the
    per-KV-head threshold leaves follow their heads.  The only
    collectives are the row-parallel int32 psum epilogues that
    ``core.api._tp_reduce_axis`` routes through
    ``dist.collectives.compressed_psum`` — integer payloads on the
    interconnect, bit-identical to the unsharded product (integer
    addition is exact, and the frozen per-tensor §2 activation scales
    make each shard's local quantize a slice of the global one).
    Requires ``mode == 'int8'`` (the float paths have no integer
    accumulator to reduce exactly; ``dense_forward`` enforces this).

Sequence parallel (``sp > 1``)
    The inner model IS the global model; only the dense cache's S axis
    splits (dist/sharding.py::sp_cache_specs).  The attention SP
    branches (models/attention.py) write owner-shard rows, emit local
    flash partials, and merge them exactly
    (repro.shard.partial_softmax); chunked prefill / verify all-gather
    the int8 tiles.  No all-reduce exists on this path at all, so the
    HLO integer-all-reduce assertion stays trivially strict.

``qparams`` travel as an EXPLICIT shard_map operand (a ctx built
outside would smuggle outer-trace tracers into the inner trace); the
inner ctx is rebuilt from the local leaves via ``core.api.make_ctx``.
The ``ShardContext`` is installed with ``shard_scope`` only while the
inner function traces — outside these four methods the process is
bit-identical to the unsharded build.
"""
from __future__ import annotations

import jax

from repro.dist import compat
from repro.dist.sharding import (P, sp_cache_specs, tp_cache_specs,
                                 tp_param_specs, tp_qparam_specs)
from repro.shard.context import ShardContext, shard_scope


def _replicate(tree):
    return jax.tree.map(lambda _: P(), tree)


class ShardedModel:
    """Serving-surface wrapper; see module docstring.

    ``model``/``cfg`` are the GLOBAL model and config (caches, readout
    and introspection keep global shapes); the mesh must expose
    ``axis`` with size ``tp * sp``.
    """

    def __init__(self, model, cfg, mesh, *, tp: int = 1, sp: int = 1,
                 axis: str = "model"):
        # validates tp/sp exclusivity and positivity
        self._shard_ctx = ShardContext(axis=axis, tp=tp, sp=sp)
        n = max(tp, sp)
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis (axes: "
                             f"{tuple(mesh.shape)})")
        if mesh.shape[axis] != n:
            raise ValueError(
                f"mesh axis {axis!r} has size {mesh.shape[axis]}, "
                f"expected {n} (tp={tp}, sp={sp})")
        if tp > 1:
            for field, dim in (("n_heads", cfg.n_heads),
                               ("n_kv_heads", cfg.n_kv_heads),
                               ("d_ff", cfg.d_ff)):
                if dim % tp:
                    raise ValueError(
                        f"cfg.{field}={dim} not divisible by tp={tp}")
        self._model = model
        self.cfg = cfg
        self.mesh = mesh
        self.tp, self.sp, self.axis = tp, sp, axis
        if tp > 1:
            from repro.models import build_model

            # the local-config trick: inside shard_map every param/cache
            # leaf arrives pre-sliced, so a model built at the LOCAL
            # head/ff widths traces with ordinary dense shapes — same
            # module paths (cfg.name is unchanged), same qparams keys
            self._inner = build_model(cfg.replace(
                n_heads=cfg.n_heads // tp,
                n_kv_heads=cfg.n_kv_heads // tp,
                d_ff=cfg.d_ff // tp,
                head_dim=cfg.head_dim))
        else:
            self._inner = model

    # -- spec builders (shape-driven, so they run at trace time) -----------
    def _param_specs(self, params):
        if self.tp > 1:
            return tp_param_specs(params, tp=self.tp, axis=self.axis)
        return _replicate(params)

    def _qparam_specs(self, qparams):
        if self.tp > 1:
            return tp_qparam_specs(qparams, tp=self.tp,
                                   n_kv=self.cfg.n_kv_heads,
                                   axis=self.axis)
        return _replicate(qparams)

    def _cache_specs(self, cache):
        if self.tp > 1:
            return tp_cache_specs(cache, tp=self.tp, axis=self.axis)
        if self.sp > 1:
            return sp_cache_specs(cache, sp=self.sp, axis=self.axis)
        return _replicate(cache)

    def _inner_ctx(self, ctx, qparams):
        if ctx is None:
            return None
        from repro.core import api as A

        return A.make_ctx(ctx.mode, ctx.policy, qparams)

    def _mapped(self, method: str, ctx, params, cache, pos_args,
                kw_arrays=(), **static_kw):
        """Build + invoke the shard_map'd form of one serving method.

        Every array operand travels EXPLICITLY through shard_map (a
        closure over outer-trace tracers would leak across the manual
        boundary): ``pos_args`` are the replicated positional operands
        (batch/tokens, positions), ``kw_arrays`` is a sequence of
        (name, array-or-None) keyword operands — None entries fall back
        to the method's default and never become operands.
        ``static_kw`` holds trace-time constants (``kv_limit``).
        Outputs are (replicated activations/logits, sharded cache).
        shard_map traces eagerly at call time, so constructing the
        wrapper per call costs one trace the surrounding jit caches.
        """
        qparams = {} if ctx is None else ctx.qparams
        shard_ctx = self._shard_ctx
        inner_model = self._inner
        mode_policy = None if ctx is None else (ctx.mode, ctx.policy)
        kw_names = [k for k, v in kw_arrays if v is not None]
        kw_vals = [v for _, v in kw_arrays if v is not None]
        n_pos = len(pos_args)

        def inner(params, qparams, cache, *arrs):
            ictx = None
            if mode_policy is not None:
                from repro.core import api as A

                ictx = A.make_ctx(mode_policy[0], mode_policy[1], qparams)
            kw = dict(zip(kw_names, arrs[n_pos:]), **static_kw)
            with shard_scope(shard_ctx):
                return getattr(inner_model, method)(
                    params, arrs[0], cache, *arrs[1:n_pos], ictx, **kw)

        cache_specs = self._cache_specs(cache)
        operands = tuple(pos_args) + tuple(kw_vals)
        fn = compat.shard_map(
            inner, mesh=self.mesh,
            in_specs=(self._param_specs(params),
                      self._qparam_specs(qparams),
                      cache_specs,
                      *(_replicate(a) for a in operands)),
            out_specs=(P(), cache_specs))
        return fn(params, qparams, cache, *operands)

    # -- the four serving entry points -------------------------------------
    def prefill(self, params, batch, cache, ctx=None):
        return self._mapped("prefill", ctx, params, cache, (batch,))

    def prefill_chunk(self, params, tokens, cache, q_offset, ctx=None, *,
                      lengths=None, kv_limit=None):
        return self._mapped("prefill_chunk", ctx, params, cache,
                            (tokens, q_offset),
                            kw_arrays=(("lengths", lengths),),
                            kv_limit=kv_limit)

    def decode_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        return self._mapped("decode_step", ctx, params, cache,
                            (tokens, cur_pos),
                            kw_arrays=(("slot_mask", slot_mask),))

    def verify_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        return self._mapped("verify_step", ctx, params, cache,
                            (tokens, cur_pos),
                            kw_arrays=(("slot_mask", slot_mask),))

    # -- cache construction -------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *args, **kw):
        """Global-shape cache, with the S axis rounded up to a shard
        multiple under sequence parallelism (sp_cache_specs rejects
        indivisible lengths; extra rows sit beyond every valid count).
        Rounding HERE keeps the scheduler's batch cache and its batch-1
        slot templates consistent — both size through this method."""
        if self.sp > 1:
            max_len = -(-max_len // self.sp) * self.sp
        return self._model.init_cache(batch, max_len, *args, **kw)

    # -- everything else is the global model -------------------------------
    def __getattr__(self, name):
        # only reached for attributes not set on self: init_cache,
        # readout_fn, fold_plan, hidden, embed, stack, ... — all global-
        # shape operations that run OUTSIDE the mesh
        return getattr(self._model, name)
