"""Mamba2 (state-space duality) block — chunked SSD train/prefill path and
O(1)-state recurrent decode path.

Faithful to the SSD formulation (arXiv:2405.21060): within a chunk the
output is an attention-like quadratic form with a decay mask; across chunks
a (B, H, N, P) state is carried by a linear scan.  ``long_500k`` decode is
feasible precisely because the decode state is O(1) in sequence length.

Projections are separate quantizable Dense layers (z/x/B/C/dt) rather than
one fused in_proj: each piece then has a clean logical sharding (heads over
the model axis) and its own FAT thresholds — mixing them in one matmul
would force a resharding slice *and* a shared quantization threshold over
statistically different distributions (exactly what the paper's per-filter
thresholds are designed to avoid).

Equalization note (DESIGN.md §Arch-applicability): every path into the SSD
recursion crosses a nonlinearity (silu on the conv stream and the z gate,
softplus on dt) — per-channel rescaling does NOT commute (paper §3.3's own
restriction), so this block declares NO equalization pairs; FAT thresholds
still quantize all six projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import RMSNorm, silu
from repro.models.module import Dense, Module


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int = 128):
    """Chunked SSD scan.

    x:  (B, L, H, P) inputs per head
    dt: (B, L, H)    post-softplus timesteps
    a_log: (H,)      A = -exp(a_log)
    b:  (B, L, G, N) input projections (G groups broadcast over heads)
    c:  (B, L, G, N) output projections
    Returns y: (B, L, H, P)
    """
    bsz, l0, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hpg = h // g  # heads per group
    chunk = min(chunk, l0)
    # pad ragged lengths: dt=0 at padded steps makes them exact no-ops in
    # the recursion (dA=0, B.x=0); padded outputs are sliced off
    l = -(-l0 // chunk) * chunk
    if l != l0:
        pad = l - l0
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        b = jnp.pad(b, [(0, 0), (0, pad), (0, 0), (0, 0)])
        c = jnp.pad(c, [(0, 0), (0, pad), (0, 0), (0, 0)])
    nc = l // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative
    dta = dt.astype(jnp.float32) * a  # (B, L, H) decay log-increments

    # chunked views
    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    dac = dta.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=2)  # (B, nc, Q, H) inclusive
    total = cum[:, :, -1, :]  # (B, nc, H) chunk decay total

    # ---- intra-chunk (quadratic attention-like form) ---------------------
    # decay[i, j] = exp(cum_i - cum_j) for i >= j.  The exponent is clamped
    # to 0 at masked (i < j) entries BEFORE exp: exp(diff) overflows there
    # (diff > 0 grows with chunk length) and inf * 0 in the VJP poisons
    # every gradient upstream (classic where/exp NaN trap).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, diff, 0.0)) * causal
    # scores[i, j] per group: C_i . B_j
    scores = jnp.einsum("bzign,bzjgn->bzijg", cc, bc)  # (B,nc,Q,Q,G)
    scores = jnp.repeat(scores, hpg, axis=-1)  # -> (B,nc,Q,Q,H)
    m = scores * decay * dtc[:, :, None, :, :]  # weight by dt_j
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", m, xc)

    # ---- chunk-local states ---------------------------------------------
    # S_local = sum_j exp(total - cum_j) * dt_j * B_j (x) x_j
    rdecay = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,Q,H)
    bh = jnp.repeat(bc, hpg, axis=3)  # (B,nc,Q,H,N)
    s_local = jnp.einsum(
        "bzqhn,bzqh,bzqhp->bzhnp", bh, rdecay * dtc, xc
    )  # (B,nc,H,N,P)

    # ---- inter-chunk linear scan -----------------------------------------
    def scan_fn(carry, inp):
        s_loc, tot = inp  # (B,H,N,P), (B,H)
        s_in = carry
        s_out = jnp.exp(tot)[:, :, None, None] * s_in + s_loc
        return s_out, s_in  # emit state *entering* the chunk

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, s_in = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (B, nc, H, N, P)

    # ---- inter-chunk contribution ----------------------------------------
    ch = jnp.repeat(cc, hpg, axis=3)  # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bzqhn,bzhnp->bzqhp", ch * jnp.exp(cum)[..., None], s_in)

    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y[:, :l0].astype(x.dtype)


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t):
    """One recurrent step.

    state: (B, H, N, P); x_t: (B, H, P); dt_t: (B, H);
    b_t/c_t: (B, G, N) broadcast over heads.
    Returns (new_state, y_t (B, H, P)).
    """
    h = x_t.shape[1]
    g = b_t.shape[1]
    hpg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt_t.astype(jnp.float32) * a)  # (B, H)
    bh = jnp.repeat(b_t.astype(jnp.float32), hpg, axis=1)  # (B,H,N)
    ch = jnp.repeat(c_t.astype(jnp.float32), hpg, axis=1)
    outer = jnp.einsum("bhn,bhp->bhnp", bh, x_t.astype(jnp.float32))
    new_state = da[:, :, None, None] * state + dt_t[:, :, None, None] * outer
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    return new_state, y.astype(x_t.dtype)


def causal_conv1d(x, w, b=None):
    """Depthwise causal conv. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    if b is not None:
        y = y + b
    return y


def conv1d_decode(conv_state, x_t, w, b=None):
    """conv_state: (B, K-1, C) previous inputs; x_t: (B, 1, C)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    if b is not None:
        y = y + b
    return window[:, 1:, :], y


class Mamba2Block(Module):
    def __init__(
        self,
        d_model: int,
        *,
        path: str,
        d_state: int = 128,
        n_heads: int | None = None,
        head_dim: int = 64,
        expand: int = 2,
        n_groups: int = 1,
        conv_width: int = 4,
        chunk: int = 128,
        dtype=jnp.bfloat16,
    ):
        self.d_model = d_model
        self.d_inner = expand * d_model
        self.head_dim = head_dim
        self.n_heads = n_heads or self.d_inner // head_dim
        assert self.n_heads * head_dim == self.d_inner
        self.d_state = d_state
        self.n_groups = n_groups
        self.conv_width = conv_width
        self.chunk = chunk
        self.path = path
        self.dtype = dtype
        dd = dict(dtype=dtype)
        self.z_proj = Dense(d_model, self.d_inner, path=f"{path}/z_proj",
                            logical_axes=("embed", "heads"), **dd)
        self.x_proj = Dense(d_model, self.d_inner, path=f"{path}/x_proj",
                            logical_axes=("embed", "heads"), **dd)
        self.b_proj = Dense(d_model, n_groups * d_state, path=f"{path}/b_proj",
                            logical_axes=("embed", "state"), **dd)
        self.c_proj = Dense(d_model, n_groups * d_state, path=f"{path}/c_proj",
                            logical_axes=("embed", "state"), **dd)
        self.dt_proj = Dense(d_model, self.n_heads, path=f"{path}/dt_proj",
                             logical_axes=("embed", "heads"), **dd)
        self.out_proj = Dense(self.d_inner, d_model, path=f"{path}/out_proj",
                              logical_axes=("heads", "embed"), **dd)
        self.norm = RMSNorm(self.d_inner, path=f"{path}/norm", dtype=dtype)

    def init(self, key):
        ks = jax.random.split(key, 9)
        h = self.n_heads
        conv_ch = self.d_inner + 2 * self.n_groups * self.d_state
        return {
            "z_proj": self.z_proj.init(ks[0]),
            "x_proj": self.x_proj.init(ks[1]),
            "b_proj": self.b_proj.init(ks[2]),
            "c_proj": self.c_proj.init(ks[3]),
            "dt_proj": self.dt_proj.init(ks[4]),
            "out_proj": self.out_proj.init(ks[5]),
            "norm": self.norm.init(ks[6]),
            "a_log": jnp.log(
                jnp.linspace(1.0, 16.0, h).astype(jnp.float32)
            ),
            "d_skip": jnp.ones((h,), jnp.float32),
            "dt_bias": jnp.zeros((h,), jnp.float32),
            "conv_w": (jax.random.normal(ks[7], (self.conv_width, conv_ch))
                       * 0.1).astype(jnp.float32),
            "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        }

    def _project(self, params, u, ctx):
        z = self.z_proj(params["z_proj"], u, ctx)
        xi = self.x_proj(params["x_proj"], u, ctx)
        bi = self.b_proj(params["b_proj"], u, ctx)
        ci = self.c_proj(params["c_proj"], u, ctx)
        dt = self.dt_proj(params["dt_proj"], u, ctx)
        return z, xi, bi, ci, dt

    def __call__(self, params, u, ctx=None):
        """u: (B, L, d_model) -> (B, L, d_model). Train / prefill path."""
        bsz, l, _ = u.shape
        z, xi, bi, ci, dt = self._project(params, u, ctx)
        # causal depthwise conv over the concatenated (x, B, C) stream
        xbc = jnp.concatenate(
            [xi.astype(jnp.float32), bi.astype(jnp.float32),
             ci.astype(jnp.float32)], axis=-1
        )
        xbc = silu(causal_conv1d(xbc, params["conv_w"], params["conv_b"]))
        di = self.d_inner
        gn = self.n_groups * self.d_state
        xi = xbc[..., :di]
        bi = xbc[..., di : di + gn]
        ci = xbc[..., di + gn :]

        x_h = xi.reshape(bsz, l, self.n_heads, self.head_dim)
        b_h = bi.reshape(bsz, l, self.n_groups, self.d_state)
        c_h = ci.reshape(bsz, l, self.n_groups, self.d_state)
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

        y = ssd_chunked(x_h, dt_s, params["a_log"], b_h, c_h, chunk=self.chunk)
        y = y + params["d_skip"][None, None, :, None] * x_h.astype(jnp.float32)
        y = y.reshape(bsz, l, di)
        # gated RMSNorm (mamba2): norm(y * silu(z))
        y = self.norm(params["norm"], y.astype(u.dtype) * silu(z))
        return self.out_proj(params["out_proj"], y, ctx)

    # -- decode -----------------------------------------------------------
    def init_cache(self, batch: int, dtype=jnp.float32) -> dict:
        conv_ch = self.d_inner + 2 * self.n_groups * self.d_state
        return {
            "ssm": jnp.zeros(
                (batch, self.n_heads, self.d_state, self.head_dim), jnp.float32
            ),
            "conv": jnp.zeros((batch, self.conv_width - 1, conv_ch), jnp.float32),
        }

    def decode(self, params, u, cache, ctx=None):
        """u: (B, 1, d_model). Returns (y, new_cache). O(1) in seq len."""
        bsz = u.shape[0]
        z, xi, bi, ci, dt = self._project(params, u, ctx)
        xbc = jnp.concatenate(
            [xi.astype(jnp.float32), bi.astype(jnp.float32),
             ci.astype(jnp.float32)], axis=-1
        )
        conv_state, xbc = conv1d_decode(
            cache["conv"], xbc, params["conv_w"], params["conv_b"]
        )
        xbc = silu(xbc)
        di = self.d_inner
        gn = self.n_groups * self.d_state
        x_t = xbc[:, 0, :di].reshape(bsz, self.n_heads, self.head_dim)
        b_t = xbc[:, 0, di : di + gn].reshape(bsz, self.n_groups, self.d_state)
        c_t = xbc[:, 0, di + gn :].reshape(bsz, self.n_groups, self.d_state)
        dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])

        new_state, y_t = ssd_decode_step(
            cache["ssm"], x_t, dt_t, params["a_log"], b_t, c_t
        )
        y_t = y_t + params["d_skip"][None, :, None] * x_t.astype(jnp.float32)
        y = y_t.reshape(bsz, 1, di).astype(u.dtype)
        y = self.norm(params["norm"], y * silu(z))
        y = self.out_proj(params["out_proj"], y, ctx)
        return y, {"ssm": new_state, "conv": conv_state}

    def equalization_pairs(self):
        """None: every producer->consumer pair in this block crosses a
        nonlinearity (silu on z and on the conv stream) or the SSD
        recursion itself — the paper's §3.3 restriction ("any non-linear
        operations on the scaled data ... are not allowed") locks the whole
        block.  FAT per-channel thresholds still apply to every projection;
        only the *rescaling* trick is inapplicable.  See DESIGN.md
        §Arch-applicability."""
        return []
