"""Mixture-of-Experts with sort-based dispatch (MaxText-style).

Dispatch happens independently per *group* (one group == one sequence in
training/prefill, the whole batch in decode), so the token->slot cumsum
stays local to a data shard — no global prefix scan crosses the mesh.

Expert FFN weights are ``ExpertDense`` (E, d, f) tensors sharded over the
model axis on the hidden dim (TP-experts): every data shard holds all
experts (model-sharded), so dispatch needs **zero all-to-all**.  A true
expert-parallel layout (experts sharded over 'model', all-to-all dispatch)
is available as ``layout='ep'`` for the perf hillclimb.

Quantization: expert weights quantize in vector mode with per-(expert,
out-channel) thresholds — the paper's per-filter thresholds, one level up.
The router stays unquantized (tiny, accuracy-critical — same spirit as the
paper keeping accumulators in int32).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ACTIVATIONS
from repro.models.module import Dense, ExpertDense, Module


def _dispatch(x, logits, top_k: int, capacity: int, num_experts: int):
    """Batched sort-based dispatch: one independent dispatch per group.

    x: (G, T, d); logits: (G, T, E).
    Returns (x_dispatched (G, E, C, d), combine info for the return path).

    Implementation note: the wide (.., d) tensors move ONLY through
    gathers — GSPMD partitions gather batch dims cleanly, while a scatter
    on a (G, slots, d) buffer falls back to full replication (+ giant u32
    index broadcasts).  Scatters touch only small (G, T*K) int32 maps.
    """
    g, t, d = x.shape
    e = num_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)  # (G, T, K)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    e_flat = top_idx.reshape(g, t * top_k)                      # (G, T*K)
    t_flat = jnp.tile(jnp.repeat(jnp.arange(t), top_k), (g, 1))  # (G, T*K)
    w_flat = top_vals.reshape(g, t * top_k)

    order = jnp.argsort(e_flat, axis=-1)             # stable: ties by index
    se = jnp.take_along_axis(e_flat, order, axis=-1)
    st = jnp.take_along_axis(t_flat, order, axis=-1)

    counts = jnp.sum(jax.nn.one_hot(e_flat, e, dtype=jnp.int32), axis=1)  # (G, E)
    starts = jnp.cumsum(counts, axis=-1) - counts    # exclusive cumsum
    rank = jnp.arange(t * top_k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    valid = rank < capacity
    slot = jnp.where(valid, se * capacity + rank, e * capacity)  # trash slot

    gi = jnp.arange(g)[:, None]
    # slot -> source token (small int32 scatter); unfilled slots read the
    # zero sentinel row of x_pad
    src_tok = jnp.full((g, e * capacity + 1), t, jnp.int32)
    src_tok = src_tok.at[gi, slot].set(st, mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    x_disp = jnp.take_along_axis(x_pad, src_tok[:, :-1, None], axis=1)
    x_disp = x_disp.reshape(g, e, capacity, d)
    # (token, k) -> slot in original order (small int32 scatter)
    slot_unsorted = jnp.full((g, t * top_k), e * capacity, jnp.int32)
    slot_unsorted = slot_unsorted.at[gi, order].set(slot, mode="drop")
    return x_disp, (slot_unsorted, w_flat)


def _combine(y_exp, info, t: int):
    """Return path: gather expert outputs back to token order and
    weight-sum over the K assignments.  y_exp: (G, E, C, d) -> (G, T, d).
    Dropped assignments point at the zero sentinel row — no masking pass
    over the wide tensor needed."""
    slot_unsorted, w_flat = info  # (G, T*K)
    g, e, c, d = y_exp.shape
    k = (slot_unsorted.shape[1]) // t
    flat = y_exp.reshape(g, e * c, d)
    flat_pad = jnp.concatenate([flat, jnp.zeros((g, 1, d), flat.dtype)], axis=1)
    contrib = jnp.take_along_axis(flat_pad, slot_unsorted[..., None], axis=1)
    contrib = contrib * w_flat[..., None].astype(flat.dtype)
    return contrib.reshape(g, t, k, d).sum(axis=2)


class MoE(Module):
    def __init__(
        self,
        d_model: int,
        d_ff: int,
        num_experts: int,
        top_k: int,
        *,
        path: str,
        capacity_factor: float = 1.25,
        activation: str = "silu",
        dtype=jnp.bfloat16,
        layout: str = "tp",  # 'tp' (no all-to-all) | 'ep' (hillclimb)
    ):
        self.d_model = d_model
        self.d_ff = d_ff
        self.num_experts = num_experts
        self.top_k = top_k
        self.capacity_factor = capacity_factor
        self.act = ACTIVATIONS[activation]
        self.path = path
        self.layout = layout
        self.router = Dense(d_model, num_experts, path=f"{path}/router",
                            quantize=False, dtype=jnp.float32,
                            logical_axes=("embed", "expert"))
        ea = ("expert", "embed", "mlp")
        self.gate = ExpertDense(num_experts, d_model, d_ff,
                                path=f"{path}/gate", dtype=dtype, logical_axes=ea)
        self.up = ExpertDense(num_experts, d_model, d_ff,
                              path=f"{path}/up", dtype=dtype, logical_axes=ea)
        self.down = ExpertDense(num_experts, d_ff, d_model,
                                path=f"{path}/down", dtype=dtype,
                                logical_axes=("expert", "mlp", "embed"))

    def init(self, key):
        ks = jax.random.split(key, 4)
        return {
            "router": self.router.init(ks[0]),
            "gate": self.gate.init(ks[1]),
            "up": self.up.init(ks[2]),
            "down": self.down.init(ks[3]),
        }

    def capacity(self, tokens_per_group: int) -> int:
        c = int(np.ceil(tokens_per_group * self.top_k / self.num_experts
                        * self.capacity_factor))
        return max(8, int(np.ceil(c / 8)) * 8)  # pad to sublane multiple

    def __call__(self, params, x, ctx=None):
        """x: (B, S, d) -> (y (B, S, d), aux load-balance loss)."""
        from repro.dist.constraints import constrain_activation

        b, s, d = x.shape
        logits = self.router(params["router"], x.astype(jnp.float32), ctx)
        cap = self.capacity(s)

        xd, info = _dispatch(x, logits, self.top_k, cap, self.num_experts)
        # scatter output defeats GSPMD propagation: anchor the dispatch
        # buffer's group dim on the batch axes or it replicates (G,E,C,d)
        # on every device
        xd = constrain_activation(xd)
        g = self.act(self.gate(params["gate"], xd, ctx))
        u = self.up(params["up"], xd, ctx)
        yd = self.down(params["down"], g * u, ctx)
        yd = constrain_activation(yd)
        y = constrain_activation(_combine(yd, info, s))

        # standard load-balance auxiliary (Switch-style): E * sum(f_e * p_e)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top1 = jnp.argmax(probs, axis=-1)
        f = jnp.mean(jax.nn.one_hot(top1, self.num_experts), axis=(0, 1))
        p = jnp.mean(probs, axis=(0, 1))
        aux = self.num_experts * jnp.sum(f * p)
        return y, aux

    def equalization_pairs(self):
        """Per-expert up->down rescale (§3.3 through the gate product)."""
        return [(self.up.path, self.down.path)]
