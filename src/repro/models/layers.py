"""Common layers: norms, embeddings, rotary position encoding.

Norm layers carry their scale explicitly so the FAT folding pass
(repro.core.folding) can fold gamma/beta into downstream projections the
way the paper folds batch-norm into conv weights (§3.1.2, eqs. 10-11).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import Module, normal_init


class RMSNorm(Module):
    def __init__(self, dim: int, *, path: str, eps: float = 1e-6, dtype=jnp.bfloat16):
        self.dim = dim
        self.path = path
        self.eps = eps
        self.dtype = dtype

    def init(self, key):
        return {"scale": jnp.ones((self.dim,), jnp.float32)}

    def __call__(self, params, x, ctx=None):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps) * params["scale"]
        return y.astype(x.dtype)


class LayerNorm(Module):
    def __init__(self, dim: int, *, path: str, eps: float = 1e-5, dtype=jnp.bfloat16):
        self.dim = dim
        self.path = path
        self.eps = eps
        self.dtype = dtype

    def init(self, key):
        return {
            "scale": jnp.ones((self.dim,), jnp.float32),
            "bias": jnp.zeros((self.dim,), jnp.float32),
        }

    def __call__(self, params, x, ctx=None):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"] + params["bias"]
        return y.astype(x.dtype)


class Embedding(Module):
    """Token embedding; vocab-sharded under TP.

    The table is padded to ``vocab_padded`` (multiple of 128) so the vocab
    axis shards evenly on any power-of-two mesh; padded rows are masked to
    -inf at readout.  Quantizable to int8 (per-row thresholds) on the
    serving path — an embedding gather is pure memory traffic, so int8
    halves its HBM bytes; this is the paper's per-filter vector
    quantization applied to rows.
    """

    def __init__(self, vocab: int, dim: int, *, path: str, dtype=jnp.bfloat16,
                 vocab_padded: int | None = None):
        self.vocab = vocab
        self.vocab_padded = vocab_padded or (-(-vocab // 128) * 128)
        self.dim = dim
        self.path = path
        self.dtype = dtype
        self.logical_axes = ("vocab", "embed")

    def init(self, key):
        return {
            "table": normal_init(key, (self.vocab_padded, self.dim), self.dtype)
        }

    def __call__(self, params, tokens, ctx=None):
        return jnp.take(params["table"], tokens, axis=0)

    def attend(self, params, x, ctx=None):
        """Tied-weight readout: (..., d) @ (d, Vp) -> logits (padded rows
        masked to a large negative so argmax/softmax never select them)."""
        logits = x @ params["table"].T
        if self.vocab_padded != self.vocab:
            pad_mask = jnp.arange(self.vocab_padded) >= self.vocab
            logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype), logits)
        return logits


def rotary_angles(positions: jax.Array, head_dim: int, base: float = 10000.0):
    """(..., S) int positions -> (cos, sin) of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (base ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D). cos/sin: (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # add head axis; rotate-half convention (llama family)
    c = cos[..., None, :]
    s = sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x)


def relu6(x):
    """Bounded activation central to the paper's §3.3 analysis."""
    return jnp.clip(x, 0.0, 6.0)


ACTIVATIONS = {"silu": silu, "gelu": gelu, "relu": jax.nn.relu, "relu6": relu6}
