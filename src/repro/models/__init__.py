from repro.models.module import Module, Dense, ExpertDense
from repro.models.layers import RMSNorm, LayerNorm, Embedding
from repro.models.model import CausalLM, EncDecLM, build_model
