"""Attention: GQA/MQA with full, chunked-flash and sliding-window paths,
plus KV-cache decode (the int8 serving hot path).

Memory discipline matters at the assigned shapes (32k prefill, 512k
decode):

  * ``flash_attention`` — double-scan (query chunks x kv chunks) online
    softmax; peak score tensor is (B, Hq, q_chunk, kv_chunk).
  * ``sliding_window_attention`` — per-query-chunk dynamic slice of the
    last ``window`` keys: O(S * window) compute, which is what makes the
    gemma3/mixtral local layers sub-quadratic.
  * ``decode_attention`` — one-token query against the cache; with a
    sequence-sharded cache GSPMD turns the softmax reductions into the
    flash-decode partial-max/partial-sum combine automatically.

The KV cache is consumed ONLY through the ``repro.cache.KVCache``
protocol: ``init_cache`` picks a layout (dense / SWA ring / paged),
``cache.ready`` is the single quantize-on-append point (K/V quantize ONCE
against the frozen calibrated per-head thresholds — paper §2 — and the
same tiles feed attention and the cache write), ``append`` /
``append_slots`` do the layout-appropriate writes, and ``kernel_view`` /
``dense_view`` feed the fused Pallas kernels and the jnp reference paths
respectively.  This file contains no layout math: ring rolls live in
``RingCache``, page-table scatters in ``PagedCache``.

Serving runs a TWO-KERNEL fused engine when policy.use_pallas: prefill
attends through kernels/prefill_attention.py, decode through
kernels/decode_attention.py — both read KV tiles through the cache's
kernel view (an identity block table for dense/ring, the page table for
paged), so one compiled kernel serves every layout.  ``decode`` takes a
(B,) position vector + active mask for continuous batching
(launch/scheduler.py, docs/serving.md).

All paths share GQA head grouping: Hq = KV * G, computed as einsum over a
(B, S, KV, G, D) view so no materialized head replication occurs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.cache import (DenseCache, KVCache, KV_LEVELS, RingCache,
                         dequantize_kv, kv_levels, make_cache, quantize_kv)
from repro.models.layers import apply_rotary, rotary_angles
from repro.models.module import Dense, Module

NEG_INF = -1e30


def _sp_info():
    """Trace-time sequence-parallel context (repro.shard.context), or
    None on the unsharded path.  Imported lazily: repro.shard layers on
    top of the model stack, so a module-level import would be a cycle —
    and the unsharded engine should not pay for it at all."""
    try:
        from repro.shard.context import sp_shard_info
    except ImportError:
        return None
    return sp_shard_info()


def _gqa_scores(q, k):
    """q: (B,Sq,KV,G,D)  k: (B,Sk,KV,D) -> (B,KV,G,Sq,Sk)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k)


def _gqa_out(p, v):
    """p: (B,KV,G,Sq,Sk)  v: (B,Sk,KV,D) -> (B,Sq,KV,G,D)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def full_attention(q, k, v, *, causal: bool, q_pos=None, k_pos=None,
                   window: int | None = None):
    """Reference full-materialization attention (small shapes / oracle)."""
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = _gqa_scores(q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v.astype(jnp.float32)).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 512, q_offset: int = 0,
                    window: int | None = None):
    """Online-softmax attention, scanned over query and kv chunks.

    q: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D).  ``q_offset`` shifts query
    positions (decode / continued prefill).  Pure JAX (lax.scan) so it
    lowers on any backend and GSPMD shards it; the Pallas TPU kernel in
    kernels/ is the hardware hot path for the same contraction.
    """
    b, sq0, kvh, g, d = q.shape
    sk0 = k.shape[1]
    q_chunk = min(q_chunk, sq0)
    kv_chunk = min(kv_chunk, sk0)
    # pad ragged sequence lengths up to a chunk multiple; padded keys are
    # masked by position (k_pos < sk0), padded query rows are sliced off.
    sq = -(-sq0 // q_chunk) * q_chunk
    sk = -(-sk0 // kv_chunk) * kv_chunk
    if sq != sq0:
        q = jnp.pad(q, [(0, 0), (0, sq - sq0), (0, 0), (0, 0), (0, 0)])
    if sk != sk0:
        k = jnp.pad(k, [(0, 0), (0, sk - sk0), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, sk - sk0), (0, 0), (0, 0)])
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def q_body(_, qi):
        # slice in the storage dtype, upcast per chunk — a full-tensor f32
        # copy of q/k/v would cost 2x HBM for the whole sequence
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        qc = qc.astype(jnp.float32) * scale
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        # checkpoint: the (cq, ck) score/prob tiles must be recomputed in
        # the backward, not saved — saving them stacks (nq*nk) f32 tiles
        # and destroys flash attention's O(S) memory property
        @jax.checkpoint
        def kv_body(carry, ki):
            o, m, l = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            kc = kc.astype(jnp.float32)
            vc = vc.astype(jnp.float32)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = _gqa_scores(qc, kc)  # (B,KV,G,cq,ck)
            mask = (k_pos < sk0)[None, :]  # mask padded keys
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vc)
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_body, (o0, m0, l0), jnp.arange(nk))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # emit in storage dtype: the stacked output is (Sq, ...)-sized
        return None, jnp.moveaxis(o, 3, 1).astype(v.dtype)  # (B,cq,KV,G,D)

    _, chunks = jax.lax.scan(q_body, None, jnp.arange(nq))
    # chunks: (nq, B, cq, KV, G, D) -> (B, Sq, KV, G, D)
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, kvh, g, d)
    return out[:, :sq0].astype(v.dtype)


def sliding_window_attention(q, k, v, *, window: int, q_chunk: int = 512,
                             q_offset: int = 0):
    """Banded causal attention in O(S * (window + q_chunk)) compute.

    Pads KV on the left by ``window`` then, per query chunk, slices the
    (window + q_chunk) keys that can possibly be visible.  All shapes are
    static so this lowers/shards cleanly.
    """
    b, sq0, kvh, g, d = q.shape
    sk0 = k.shape[1]
    q_chunk = min(q_chunk, sq0)
    sq = -(-sq0 // q_chunk) * q_chunk
    if sq != sq0:
        q = jnp.pad(q, [(0, 0), (0, sq - sq0), (0, 0), (0, 0), (0, 0)])
        k = jnp.pad(k, [(0, 0), (0, sq - sk0), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, sq - sk0), (0, 0), (0, 0)])
    nq = sq // q_chunk
    span = window + q_chunk  # kv visible to one query chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    # left-pad keys/values by `window` so slices never underflow; stay in
    # storage dtype and upcast per chunk (full-tensor f32 doubles HBM)
    pad = [(0, 0), (window, 0), (0, 0), (0, 0)]
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)

    @jax.checkpoint
    def q_body(_, qi):
        q_start = qi * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, q_chunk, axis=1)
        qc = qc.astype(jnp.float32) * scale
        kc = jax.lax.dynamic_slice_in_dim(kp, q_start, span, axis=1).astype(
            jnp.float32)
        vc = jax.lax.dynamic_slice_in_dim(vp, q_start, span, axis=1).astype(
            jnp.float32)
        # absolute positions: queries q_start..q_start+cq-1 (+offset);
        # keys (q_start - window)..(q_start + cq - 1); padding keys have
        # negative positions and are masked out.
        q_pos = q_offset + q_start + jnp.arange(q_chunk)
        k_pos = q_offset + q_start - window + jnp.arange(span)
        s = _gqa_scores(qc, kc)
        mask = (
            (q_pos[:, None] >= k_pos[None, :])
            & ((q_pos[:, None] - k_pos[None, :]) < window)
            & (k_pos[None, :] >= 0)
            & (k_pos[None, :] < q_offset + sk0)
        )
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return None, _gqa_out(p, vc).astype(v.dtype)

    _, chunks = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, kvh, g, d)
    return out[:, :sq0]


def decode_attention(q, k_cache, v_cache, cur_pos, *, window: int | None = None):
    """One-step decode: q (B,1,KV,G,D) against cache (B,Smax,KV,D).

    ``cur_pos`` is the number of valid cache entries — a scalar (uniform
    batch) or a (B,) per-slot vector (continuous batching: each slot of
    the batch decodes at its own position; a 0 entry means no visible key
    and returns exact zeros, matching the fused kernel's inactive-slot
    convention).  Positions beyond it (and outside the sliding window, if
    any) are masked.  With a sequence-sharded cache, GSPMD lowers the
    masked softmax into partial reductions + a tiny cross-shard combine
    (flash-decode).
    """
    b, _, kvh, g, d = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = _gqa_scores(q.astype(jnp.float32) * scale, k_cache.astype(jnp.float32))
    pos = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
    k_pos = jnp.arange(smax)
    mask = k_pos[None, :] < pos[:, None]                       # (B, Smax)
    if window is not None:
        mask &= k_pos[None, :] >= (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible key (pos == 0) softmax uniformly over NEG_INF
    # scores — zero them so inactive slots are well-defined
    p = p * (pos > 0)[:, None, None, None, None]
    return _gqa_out(p, v_cache.astype(jnp.float32)).astype(q.dtype)


def verify_attention(q, k_cache, v_cache, pos_vec, *, window: int | None = None):
    """Speculative-verify attention: s draft-window queries per slot, each
    slot at its own position.  q: (B, s, KV, G, D) — query j of slot b
    sits at absolute position ``pos_vec[b] + j`` and sees cache keys
    ``<= pos_vec[b] + j`` (the just-appended draft window included, causal
    within it).  The s == 1 case is exactly ``decode_attention`` with
    ``cur_pos = pos_vec + 1`` — same contraction order, same mask
    convention — which is what makes speculative decoding bit-identical
    to greedy under deterministic acceptance.  A slot with
    ``pos_vec < 0`` (the inactive-slot encoding) sees no key and returns
    exact zeros."""
    b, s, kvh, g, d = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    sc = _gqa_scores(q.astype(jnp.float32) * scale,
                     k_cache.astype(jnp.float32))      # (B, KV, G, s, Smax)
    pos = jnp.broadcast_to(jnp.asarray(pos_vec, jnp.int32).reshape(-1), (b,))
    q_pos = pos[:, None] + jnp.arange(s)               # (B, s)
    k_pos = jnp.arange(smax)
    mask = k_pos[None, None, :] <= q_pos[..., None]    # (B, s, Smax)
    if window is not None:
        mask &= (q_pos[..., None] - k_pos[None, None, :]) < window
    mask &= (pos >= 0)[:, None, None]
    sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = p * (pos >= 0)[:, None, None, None, None]
    return _gqa_out(p, v_cache.astype(jnp.float32)).astype(q.dtype)


class Attention(Module):
    """GQA attention block with rotary embedding and optional SWA."""

    def __init__(
        self,
        d_model: int,
        n_heads: int,
        n_kv_heads: int,
        head_dim: int,
        *,
        path: str,
        window: int | None = None,
        rope_base: float = 10000.0,
        causal: bool = True,
        cross: bool = False,
        dtype=jnp.bfloat16,
        q_chunk: int = 512,
        kv_chunk: int = 512,
    ):
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_kv = n_kv_heads
        self.head_dim = head_dim
        self.groups = n_heads // n_kv_heads
        self.window = window
        self.rope_base = rope_base
        self.causal = causal
        self.cross = cross
        self.path = path
        self.q_chunk = q_chunk
        self.kv_chunk = kv_chunk
        dd = dict(dtype=dtype)
        self.wq = Dense(d_model, n_heads * head_dim, path=f"{path}/wq",
                        logical_axes=("embed", "heads"), **dd)
        self.wk = Dense(d_model, n_kv_heads * head_dim, path=f"{path}/wk",
                        logical_axes=("embed", "kv_heads"), **dd)
        self.wv = Dense(d_model, n_kv_heads * head_dim, path=f"{path}/wv",
                        logical_axes=("embed", "kv_heads"), **dd)
        self.wo = Dense(n_heads * head_dim, d_model, path=f"{path}/wo",
                        logical_axes=("heads", "embed"), **dd)

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "wq": self.wq.init(k1),
            "wk": self.wk.init(k2),
            "wv": self.wv.init(k3),
            "wo": self.wo.init(k4),
        }

    # -- cache ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_int8: bool = False, layout: str = "ring",
                   page_size: int = 64, extra_pages: int = 0,
                   kv_bits: int = 8) -> KVCache:
        """Build this layer's ``KVCache`` (repro.cache.make_cache picks
        dense / SWA-ring / paged from ``layout`` and the layer's window).
        ``kv_int8`` stores entries as int8 + per-head f32 dequant scales
        (half the bf16 HBM stream — the decode bandwidth win); scales
        start at 1 and are written from the calibrated thresholds during
        prefill.  ``kv_bits=4`` narrows the quantized store to packed
        int4 nibbles (quarter of bf16).  Cross-attention memory stays
        float and dense (computed once per request, not the decode
        bottleneck)."""
        if self.cross:
            return DenseCache.init(batch, max_len, self.n_kv, self.head_dim,
                                   dtype=dtype, quantized=False)
        return make_cache(batch, max_len, self.n_kv, self.head_dim,
                          dtype=dtype, quantized=kv_int8, layout=layout,
                          window=self.window, page_size=page_size,
                          extra_pages=extra_pages,
                          bits=kv_bits if kv_int8 else 8)

    def _observe_kv(self, ctx, k, v):
        """Feed post-rope K / raw V into the KV calibration observers
        (same §2 calibration pass that feeds activation observers)."""
        if ctx is None or ctx.mode != "calibrate":
            return
        from repro.core import api as A
        from repro.core import calibration as calib

        key = A.kv_path(self.path)
        if key not in ctx.qparams:
            return
        ent = ctx.qparams[key]
        spec = ctx.policy.kv_spec()
        kw = dict(kind=ctx.policy.observer, percentile=ctx.policy.percentile)
        ctx.updates[key] = {
            "k": calib.update_observer(ent["k"], k, spec, **kw),
            "v": calib.update_observer(ent["v"], v, spec, **kw),
        }

    def _fake_quant_kv(self, ctx, k, v):
        """Trained-threshold fake-quant of the KV stream (paper §3 applied
        to the cache): fires in fake mode ONLY when finalize_calibration
        emitted trainable ``log2_t`` leaves, so the static-threshold
        training path is bit-identical to before.  This is the
        differentiable stand-in for ``cache.ready`` — the distill loss
        sees exactly the quantization error serving will pay, and the TQT
        backward moves the per-head thresholds."""
        if ctx is None or ctx.mode != "fake":
            return k, v
        from repro.core import api as A
        from repro.core import quant as Q

        ent = ctx.qparams.get(A.kv_path(self.path))
        if not ent or "log2_t" not in ent.get("k", {}):
            return k, v
        spec = ctx.policy.kv_spec()
        return (Q.fake_quant_log_t(k, ent["k"]["log2_t"], spec),
                Q.fake_quant_log_t(v, ent["v"]["log2_t"], spec))

    def _kv_scales(self, ctx) -> tuple[jax.Array, jax.Array]:
        """Frozen per-head dequant scales T/levels from calibrated
        qparams (levels = 127 at kv_bits=8, 7 at kv_bits=4)."""
        from repro.core import api as A

        ent = None if ctx is None else ctx.qparams.get(A.kv_path(self.path))
        # raw observer states also carry 't_max' (as running stats, zeros
        # before any batch) — only a finalized entry (observer fields
        # stripped) is a usable threshold
        finalized = (ent is not None and "t_max" in ent.get("k", {})
                     and "count" not in ent["k"])
        if not finalized:
            raise ValueError(
                f"{self.path}: int8 KV cache requires calibrated+finalized "
                "kv thresholds in qparams (QuantPolicy(kv_int8=True) at "
                "init_qparams, the calibration pass, then "
                "finalize_calibration)"
            )
        levels = kv_levels(ctx.policy.kv_bits)
        k_s = (jnp.maximum(ent["k"]["t_max"], 1e-8) / levels)
        v_s = (jnp.maximum(ent["v"]["t_max"], 1e-8) / levels)
        return k_s.astype(jnp.float32), v_s.astype(jnp.float32)

    def _qkv(self, params, x, ctx, kv_src=None):
        b, s, _ = x.shape
        q = self.wq(params["wq"], x, ctx).reshape(
            b, s, self.n_kv, self.groups, self.head_dim
        )
        src = x if kv_src is None else kv_src
        sk = src.shape[1]
        k = self.wk(params["wk"], src, ctx).reshape(b, sk, self.n_kv, self.head_dim)
        v = self.wv(params["wv"], src, ctx).reshape(b, sk, self.n_kv, self.head_dim)
        return q, k, v

    def _rope(self, q, k, q_positions, k_positions):
        cos_q, sin_q = rotary_angles(q_positions, self.head_dim, self.rope_base)
        b, s, kvh, g, d = q.shape
        qf = q.reshape(b, s, kvh * g, d)
        qf = apply_rotary(qf, cos_q, sin_q)
        cos_k, sin_k = rotary_angles(k_positions, self.head_dim, self.rope_base)
        k = apply_rotary(k, cos_k, sin_k)
        return qf.reshape(b, s, kvh, g, d), k

    def __call__(self, params, x, ctx=None, *, memory=None, q_offset: int = 0,
                 force_full=None):
        """Full-sequence forward (training / prefill without cache return).

        memory: encoder states for cross-attention.
        force_full: per-layer global-attention selector for scanned stacks
          (gemma3 5:1, hymba's 3 global layers).  None/False -> this
          layer's static behavior; True -> full attention; a traced bool ->
          lax.cond between the two (both branches share the same params).
        """
        b, s, _ = x.shape
        q, k, v = self._qkv(params, x, ctx, kv_src=memory)
        if not self.cross:
            q_pos = q_offset + jnp.arange(s)
            k_pos = q_offset + jnp.arange(k.shape[1])
            q, k = self._rope(q, k, q_pos, k_pos)
            self._observe_kv(ctx, k, v)
            k, v = self._fake_quant_kv(ctx, k, v)

            def windowed(q, k, v):
                if self.window is not None and s > self.window:
                    return sliding_window_attention(
                        q, k, v, window=self.window, q_chunk=self.q_chunk,
                        q_offset=q_offset,
                    )
                return flash_attention(
                    q, k, v, causal=self.causal, q_chunk=self.q_chunk,
                    kv_chunk=self.kv_chunk, q_offset=q_offset,
                    window=self.window,
                )

            def full(q, k, v):
                return flash_attention(
                    q, k, v, causal=self.causal, q_chunk=self.q_chunk,
                    kv_chunk=self.kv_chunk, q_offset=q_offset,
                )

            if force_full is None or force_full is False or self.window is None:
                o = windowed(q, k, v)
            elif force_full is True:
                o = full(q, k, v)
            else:
                o = jax.lax.cond(force_full, full, windowed, q, k, v)
        else:
            o = flash_attention(
                q, k, v, causal=False, q_chunk=self.q_chunk,
                kv_chunk=self.kv_chunk,
            )
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx)

    def prefill(self, params, x, cache: KVCache, ctx=None, *, memory=None,
                q_offset=0, lengths=None, kv_limit=None):
        """Forward + populate the KV cache (returns (y, cache)).

        K/V quantize ONCE via ``cache.ready`` (frozen calibrated per-head
        thresholds for an int8 cache) and the same cache-ready tiles feed
        both ``cache.append`` and — on the fused path (policy.use_pallas)
        — the Pallas flash-prefill kernel, which attends directly over
        the int8 stream through the cache's kernel view (identity block
        table for dense/ring, the page table for paged).  The jnp
        fallback reads ``dense_view`` and keeps the exact-K/V attention
        of the reference path.

        ``q_offset``/``lengths`` enable chunked ragged prefill: positions
        shift by ``q_offset``, the chunk's K/V append at position
        ``q_offset``, and attention runs against the updated cache masked
        to each request's valid length.  ``kv_limit`` (static int) bounds
        the cache extent attention reads — the step passes the padded
        prompt length so per-chunk work scales with the prompt, not the
        cache capacity.  ``lengths is None`` is the one-shot whole-prompt
        case."""
        b, s, _ = x.shape
        chunked = lengths is not None
        q, k, v = self._qkv(params, x, ctx, kv_src=memory)
        if not self.cross:
            pos = q_offset + jnp.arange(s)
            q, k = self._rope(q, k, pos, pos)
            self._observe_kv(ctx, k, v)
        if self.cross:
            # cross memory: written once per request, truncated to the
            # cache capacity; replaces the cache contents wholesale
            cap = min(cache.capacity, k.shape[1])
            kq, vq = cache.ready(k[:, :cap], v[:, :cap])
            new_cache = dataclasses.replace(cache, k=kq, v=vq)
            o = flash_attention(q, k, v, causal=False, q_chunk=self.q_chunk,
                                kv_chunk=self.kv_chunk)
            o = o.reshape(b, s, self.n_heads * self.head_dim)
            return self.wo(params["wo"], o, ctx), new_cache

        if cache.quantized:
            cache = cache.with_scales(*self._kv_scales(ctx))
        # quantize once: the same tiles are appended AND (kernel path)
        # attended — no bf16 K/V re-materialization between the two
        kq, vq = cache.ready(k, v)
        sp = _sp_info()
        if sp is not None:
            return self._sp_prefill(params, x, cache, ctx, q, k, v, kq, vq,
                                    chunked, q_offset, kv_limit, sp)
        use_kernel = (ctx is not None and ctx.policy.use_pallas
                      and self.causal)

        if chunked:
            if cache.layout == "ring":
                raise ValueError(
                    f"{self.path}: chunked prefill needs absolute slots (a "
                    "dense cache or paged layout); the SWA ring buffer "
                    "drops them (size the cache >= max_len or prefill "
                    "one-shot)")
            new_cache = cache.append(kq, vq, q_offset)
            kv_len = jnp.clip(jnp.asarray(lengths, jnp.int32), 0,
                              q_offset + s)
            # attend only the cache prefix that can hold prompt K/V —
            # without this every chunk pays for max_len (prompt + full
            # generation budget) worth of dequant + scores
            limit = (cache.capacity if kv_limit is None
                     else min(kv_limit, cache.capacity))
            if use_kernel:
                from repro.kernels import ops as kops

                o = kops.prefill_attention_view(
                    q, new_cache.kernel_view(limit), *new_cache.scales(),
                    q_offset, kv_len, causal=True, window=self.window,
                ).astype(x.dtype)
            else:
                k_eff, v_eff = new_cache.dequantize(
                    *new_cache.dense_view(limit))
                # cast back to the residual dtype: the dequantized f32
                # stream must not promote the carry (the fused path's
                # astype(x.dtype) contract; layer-scanned stacks require
                # a dtype-stable residual)
                o = flash_attention(q, k_eff, v_eff, causal=True,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk,
                                    q_offset=q_offset,
                                    window=self.window).astype(x.dtype)
        else:
            # one-shot prompt write: the layout places the tiles (dense
            # at absolute slots, ring keeps the last `window` rolled,
            # paged scatters through the block table)
            new_cache = cache.append(kq, vq, 0)
            if use_kernel:
                from repro.kernels import ops as kops

                # attend the prompt's own cache-ready stream (identical
                # tiles to what append just wrote — packed nibbles at
                # bits=4, hence kv_bits from the cache)
                o = kops.prefill_attention(
                    q, kq, vq, *cache.scales(), jnp.int32(0),
                    jnp.full((b,), s, jnp.int32), causal=True,
                    window=self.window, kv_bits=cache.bits,
                ).astype(x.dtype)
            elif self.window is not None and s > self.window:
                o = sliding_window_attention(q, k, v, window=self.window,
                                             q_chunk=self.q_chunk)
            else:
                o = flash_attention(q, k, v, causal=self.causal,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk,
                                    window=self.window)
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx), new_cache

    def _sp_prefill(self, params, x, cache, ctx, q, k, v, kq, vq, chunked,
                    q_offset, kv_limit, sp):
        """Sequence-parallel prefill: each shard keeps the cache rows it
        owns (repro.shard.seq_cache owner writes — drop-mode scatters,
        never clamping slices).  One-shot chunks attend their own K/V
        stream directly (no gather needed — the prompt IS the visible
        sequence); chunked prefill all-gathers the int8 tiles and
        attends the bit-identical global view."""
        from repro.shard import seq_cache

        b, s, _ = x.shape
        if cache.layout != "dense":
            raise ValueError(
                f"{self.path}: sequence-parallel serving shards the dense "
                f"cache's S axis — layout {cache.layout!r} unsupported")
        if chunked:
            new_cache = seq_cache.owner_append(cache, kq, vq, q_offset,
                                               sp.axis)
            # inside shard_map ``capacity`` is the LOCAL slice length —
            # the gathered view below spans sp * capacity rows
            cap = cache.capacity * sp.sp
            limit = cap if kv_limit is None else min(kv_limit, cap)
            k_eff, v_eff = seq_cache.gathered_dense(new_cache, sp.axis,
                                                    limit)
            o = flash_attention(q, k_eff, v_eff, causal=True,
                                q_chunk=self.q_chunk,
                                kv_chunk=self.kv_chunk,
                                q_offset=q_offset,
                                window=self.window).astype(x.dtype)
        else:
            new_cache = seq_cache.owner_append(cache, kq, vq, 0, sp.axis)
            if self.window is not None and s > self.window:
                o = sliding_window_attention(q, k, v, window=self.window,
                                             q_chunk=self.q_chunk)
            else:
                o = flash_attention(q, k, v, causal=self.causal,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk,
                                    window=self.window)
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx), new_cache

    def decode(self, params, x, cache: KVCache, cur_pos, ctx=None, *,
               memory=None, slot_mask=None):
        """Single-token decode. x: (B,1,d); cur_pos: tokens already cached
        — a scalar (uniform batch, the single-stream path) or a (B,)
        per-slot vector (continuous batching: each batch slot decodes at
        its own position, writes its K/V at its own cache index, and masks
        its own valid prefix).  ``slot_mask`` (B,) bool marks active slots
        when a scheduler drives the batch: inactive slots write nothing
        (bit-exact cache-neutral) and attend over zero keys (output rows
        zero).  The per-slot path needs absolute slots (dense or paged
        layout) — SWA ring buffers keep the scalar contract.

        With an int8 cache the new K/V quantize on append using the scales
        stored in the cache (written at prefill), so decode needs no
        threshold state.  The non-windowed int8 path can run the fused
        Pallas flash-decode kernel (policy.use_pallas), which streams the
        cache's kernel-view tiles (block-table-mapped for paged) and
        dequantizes in VMEM; otherwise the cache dequantizes into the jnp
        reference attention.
        """
        b, s, _ = x.shape
        q, k, v = self._qkv(params, x, ctx, kv_src=None if not self.cross else memory)
        if self.cross:
            o = decode_attention(q, cache.k, cache.v, cache.capacity)
            o = o.reshape(b, s, self.n_heads * self.head_dim)
            return self.wo(params["wo"], o, ctx), cache
        per_slot = jnp.ndim(cur_pos) > 0 or slot_mask is not None
        ring = cache.layout == "ring"
        if per_slot and ring:
            raise ValueError(
                f"{self.path}: per-slot decode (vector cur_pos / slot_mask) "
                "needs absolute slots (a dense cache or paged layout); the "
                "SWA ring buffer drops them — size the cache >= max_len or "
                "decode with a scalar position")
        sp = _sp_info()
        if sp is not None:
            return self._sp_decode(params, x, cache, cur_pos, ctx, q, k, v,
                                   per_slot, slot_mask, sp)
        if per_slot:
            pos_vec = jnp.broadcast_to(
                jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
            # per-slot rotary: positions (B, 1) batch the angle tables
            q, k = self._rope(q, k, pos_vec[:, None], pos_vec[:, None])
            kq, vq = cache.ready(k, v)
            upd = cache.append_slots(kq, vq, pos_vec, active=slot_mask)
        else:
            pos = jnp.full((s,), 0) + cur_pos
            q, k = self._rope(q, k, pos, pos)
            # same quantize-on-append point as prefill: the new token's
            # K/V become cache-ready tiles once, then a single write (the
            # ring layout wraps the index internally)
            kq, vq = cache.ready(k, v)
            upd = cache.append(kq, vq, cur_pos)

        if ring:
            # ring buffer: absolute decode against the layout's slot ->
            # position mapping
            k_eff, v_eff = upd.dequantize(upd.k, upd.v)
            abs_pos = upd.abs_positions(cur_pos)
            sc = _gqa_scores(
                q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(self.head_dim, jnp.float32)),
                k_eff.astype(jnp.float32),
            )
            mask = (abs_pos >= 0) & (abs_pos >= cur_pos - self.window + 1)
            sc = jnp.where(mask[None, None, None, None, :], sc, NEG_INF)
            p = jax.nn.softmax(sc, axis=-1)
            o = _gqa_out(p, v_eff.astype(jnp.float32)).astype(x.dtype)
        else:
            if per_slot:
                # valid-prefix length per slot; an inactive slot attends
                # over zero keys -> exact-zero output rows
                valid = pos_vec + 1
                if slot_mask is not None:
                    valid = jnp.where(slot_mask, valid, 0)
            else:
                valid = cur_pos + 1
            use_kernel = (
                cache.quantized
                and self.window is None
                and ctx is not None
                and ctx.policy.use_pallas
            )
            if use_kernel:
                from repro.kernels import ops as kops

                o = kops.decode_attention_view(
                    q[:, 0], upd.kernel_view(), *upd.scales(), valid,
                )[:, None].astype(x.dtype)
            else:
                k_eff, v_eff = upd.dequantize(*upd.dense_view())
                # same dtype-stable-residual contract as the fused path
                o = decode_attention(q, k_eff, v_eff, valid,
                                     window=self.window).astype(x.dtype)
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx), upd

    def _sp_decode(self, params, x, cache, cur_pos, ctx, q, k, v, per_slot,
                   slot_mask, sp):
        """Sequence-parallel flash-decode: the owning shard writes the new
        token's K/V (drop-mode scatter), every shard scores its LOCAL
        visible keys into (m, l, acc) flash partials, and
        ``sp_partial_combine`` merges them into the exact unsharded
        softmax (repro.shard.partial_softmax) — the wire carries the
        tiny partial state, never the S-sized K/V stream."""
        from repro.shard import partial_softmax as PS
        from repro.shard import seq_cache

        b, s, _ = x.shape
        if cache.layout != "dense":
            raise ValueError(
                f"{self.path}: sequence-parallel serving shards the dense "
                f"cache's S axis — layout {cache.layout!r} unsupported")
        if self.window is not None:
            raise ValueError(
                f"{self.path}: sliding-window decode is local by "
                "construction — run SWA layers unsharded (sp=1)")
        if per_slot:
            pos_vec = jnp.broadcast_to(
                jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
            q, k = self._rope(q, k, pos_vec[:, None], pos_vec[:, None])
            kq, vq = cache.ready(k, v)
            upd = seq_cache.owner_append_slots(cache, kq, vq, pos_vec,
                                               sp.axis, active=slot_mask)
            valid = pos_vec + 1
            if slot_mask is not None:
                valid = jnp.where(slot_mask, valid, 0)
        else:
            pos = jnp.full((s,), 0) + cur_pos
            q, k = self._rope(q, k, pos, pos)
            kq, vq = cache.ready(k, v)
            upd = seq_cache.owner_append(cache, kq, vq, cur_pos, sp.axis)
            valid = jnp.broadcast_to(
                jnp.asarray(cur_pos, jnp.int32) + 1, (b,))
        shard_idx, s_local = seq_cache.shard_slice_info(upd, sp.axis)
        valid_local = jnp.clip(valid - shard_idx * s_local, 0, s_local)
        use_kernel = (cache.quantized and ctx is not None
                      and ctx.policy.use_pallas)
        if use_kernel:
            from repro.kernels import ops as kops

            acc, m, l = kops.decode_attention_partials_view(
                q[:, 0], upd.kernel_view(), *upd.scales(), valid_local)
            m, l, acc = m[..., None], l[..., None], acc[..., None, :]
        else:
            k_eff, v_eff = upd.dequantize(upd.k, upd.v)
            m, l, acc = PS.local_decode_partials(q, k_eff, v_eff,
                                                 valid_local)
        o = PS.sp_partial_combine(m, l, acc, sp.axis).astype(x.dtype)
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx), upd

    def verify(self, params, x, cache: KVCache, cur_pos, ctx=None, *,
               slot_mask=None):
        """Speculative-verify pass: s draft-window tokens per slot, each
        slot at its own position.  x: (B, s, d); ``cur_pos`` (B,) is the
        number of valid cache entries per slot — the window occupies
        absolute positions ``cur_pos[b] + [0, s)``, its K/V quantize once
        via ``cache.ready`` and append at those slots
        (``cache.append_slots``, the multi-token form), and attention is
        per-slot causal over the updated cache: query j sees keys
        ``<= cur_pos[b] + j``.  This is exactly a short per-slot chunked
        prefill: the fused path reuses the Pallas flash-prefill kernel
        through its per-request ``q_start`` vector, so one compiled
        verify executable serves every draft content and acceptance
        pattern (drafts are data, never shape).  ``slot_mask`` inactive
        slots write nothing (bit-exact cache-neutral) and return zero
        rows, matching ``decode``.  Rejected drafts need no physical
        erase — entries beyond the accepted position are dead until the
        next window overwrites them (``KVCache.rollback`` documents the
        layout contract).  Per-slot writes need absolute slots, so SWA
        ring buffers are rejected (same contract as ``decode``)."""
        b, s, _ = x.shape
        if self.cross:
            raise ValueError(
                f"{self.path}: speculative verify covers causal "
                "self-attention only")
        if cache.layout == "ring":
            raise ValueError(
                f"{self.path}: speculative verify needs absolute slots (a "
                "dense cache or paged layout); the SWA ring buffer drops "
                "them — size the cache >= max_len")
        q, k, v = self._qkv(params, x, ctx)
        pos_vec = jnp.broadcast_to(
            jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
        positions = pos_vec[:, None] + jnp.arange(s)            # (B, s)
        q, k = self._rope(q, k, positions, positions)
        kq, vq = cache.ready(k, v)
        sp = _sp_info()
        if sp is not None:
            from repro.shard import seq_cache

            if cache.layout != "dense":
                raise ValueError(
                    f"{self.path}: sequence-parallel serving shards the "
                    f"dense cache's S axis — layout {cache.layout!r} "
                    "unsupported")
            upd = seq_cache.owner_append_slots(cache, kq, vq, pos_vec,
                                               sp.axis, active=slot_mask)
            # a verify window is a short per-slot chunked prefill: gather
            # the int8 tiles (integer on the wire) and attend globally
            k_eff, v_eff = seq_cache.gathered_dense(upd, sp.axis)
            pos_eff = (pos_vec if slot_mask is None
                       else jnp.where(slot_mask, pos_vec, -1))
            o = verify_attention(q, k_eff, v_eff, pos_eff,
                                 window=self.window).astype(x.dtype)
            o = o.reshape(b, s, self.n_heads * self.head_dim)
            return self.wo(params["wo"], o, ctx), upd
        upd = cache.append_slots(kq, vq, pos_vec, active=slot_mask)

        use_kernel = (
            cache.quantized
            and self.window is None
            and ctx is not None
            and ctx.policy.use_pallas
        )
        if use_kernel:
            from repro.kernels import ops as kops

            # keys visible to the window: the prefix + the window itself,
            # causally restricted per row by the kernel's q_start vector
            kv_len = pos_vec + s
            if slot_mask is not None:
                kv_len = jnp.where(slot_mask, kv_len, 0)
            o = kops.prefill_attention_view(
                q, upd.kernel_view(), *upd.scales(), pos_vec, kv_len,
                causal=True, window=None,
            ).astype(x.dtype)
        else:
            k_eff, v_eff = upd.dequantize(*upd.dense_view())
            pos_eff = (pos_vec if slot_mask is None
                       else jnp.where(slot_mask, pos_vec, -1))
            o = verify_attention(q, k_eff, v_eff, pos_eff,
                                 window=self.window).astype(x.dtype)
        o = o.reshape(b, s, self.n_heads * self.head_dim)
        return self.wo(params["wo"], o, ctx), upd


