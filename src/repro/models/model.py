"""Top-level models: CausalLM (+ VLM/audio embedding frontends) and EncDecLM.

Modality frontends are STUBS per the assignment: ``[audio]``/``[vlm]``
configs receive *precomputed* frame/patch embeddings through input_specs();
the backbone (the part the paper's quantization applies to) is real.

The readout (lm_head) is exposed as a closure for the sequence-chunked
loss functions (repro.core.distill) so full (B, S, V) logits never
materialize during training.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Embedding
from repro.models.module import Dense, Module
from repro.models.transformer import Stack


class CausalLM(Module):
    """Decoder-only LM; covers dense/MoE/SSM/hybrid + VLM/audio frontends."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.path = cfg.name
        self.embed = Embedding(cfg.vocab, cfg.d_model, path=f"{self.path}/embed",
                               dtype=cfg.dtype, vocab_padded=cfg.vocab_padded)
        self.stack = Stack(cfg, path=f"{self.path}/stack")
        if not cfg.tie_embeddings:
            self.lm_head = Dense(cfg.d_model, cfg.vocab_padded,
                                 path=f"{self.path}/lm_head",
                                 logical_axes=("embed", "vocab"),
                                 dtype=cfg.dtype)
        if cfg.modality == "vlm":
            # stub projector for precomputed patch embeddings
            self.mm_proj = Dense(cfg.mm_dim, cfg.d_model,
                                 path=f"{self.path}/mm_proj",
                                 logical_axes=("mm", "embed"), dtype=cfg.dtype)

    def init(self, key):
        ks = jax.random.split(key, 4)
        p = {"embed": self.embed.init(ks[0]), "stack": self.stack.init(ks[1])}
        if not self.cfg.tie_embeddings:
            p["lm_head"] = self.lm_head.init(ks[2])
        if self.cfg.modality == "vlm":
            p["mm_proj"] = self.mm_proj.init(ks[3])
        return p

    # -- embedding frontends ------------------------------------------------
    def embed_inputs(self, params, batch, ctx=None):
        """batch: {'tokens': (B, S)} or (+ 'patches': (B, P, mm_dim)).

        VLM: patch embeddings are projected and prepended (anyres tiling is
        upstream of the stub); total backbone length = P + S_text.
        """
        x = self.embed(params["embed"], batch["tokens"])
        if self.cfg.modality == "vlm" and "patches" in batch:
            pe = self.mm_proj(params["mm_proj"], batch["patches"], ctx)
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        from repro.dist.constraints import constrain_activation

        return constrain_activation(x)

    def readout_fn(self, params, ctx=None):
        """(B, c, d) -> (B, c, Vp) logits closure (for chunked losses);
        padded vocab entries are masked to a large negative."""
        if self.cfg.tie_embeddings:
            return lambda h: self.embed.attend(params["embed"], h, ctx)

        def head(h):
            logits = self.lm_head(params["lm_head"], h, ctx)
            if self.cfg.vocab_padded != self.cfg.vocab:
                pad = jnp.arange(self.cfg.vocab_padded) >= self.cfg.vocab
                logits = jnp.where(pad, jnp.asarray(-1e9, logits.dtype), logits)
            return logits

        return head

    def hidden(self, params, batch, ctx=None, *, remat: bool = False):
        """Backbone only: final hidden states (B, S, d) + MoE aux."""
        x = self.embed_inputs(params, batch, ctx)
        return self.stack(params["stack"], x, ctx, remat=remat)

    def __call__(self, params, batch, ctx=None, *, remat: bool = False):
        """Full logits — small models / eval only."""
        h, aux = self.hidden(params, batch, ctx, remat=remat)
        return self.readout_fn(params, ctx)(h), aux

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_int8: bool = False, layout: str = "ring",
                   page_size: int = 64, extra_pages: int = 0,
                   kv_bits: int = 8):
        """``kv_int8`` allocates the KV cache as int8 + per-head f32 scales
        (see Attention.init_cache) — pair with QuantPolicy(kv_int8=True).
        ``layout`` picks the KV layout per repro.cache.make_cache: "ring"
        (default: SWA layers ring, others dense), "dense", or "paged"
        (+ ``page_size`` / ``extra_pages`` for the shared-prefix pool)."""
        return self.stack.init_cache(batch, max_len, dtype, kv_int8=kv_int8,
                                     layout=layout, page_size=page_size,
                                     extra_pages=extra_pages,
                                     kv_bits=kv_bits)

    def prefill(self, params, batch, cache, ctx=None):
        x = self.embed_inputs(params, batch, ctx)
        h, cache = self.stack.prefill(params["stack"], x, cache, ctx)
        # only the last position's logits are needed to start decoding
        logits = self.readout_fn(params, ctx)(h[:, -1:, :])
        return logits, cache

    def prefill_chunk(self, params, tokens, cache, q_offset, ctx=None, *,
                      lengths=None, kv_limit=None):
        """One fixed-size chunk of a chunked prefill: tokens (B, chunk) at
        absolute positions ``q_offset + arange(chunk)``; K/V append into
        the dense cache at the same slots.  ``kv_limit`` (static) bounds
        the cache extent attention reads — the padded prompt length, so
        per-chunk work scales with the prompt, not max_len.  Returns the
        chunk's final hidden states (B, chunk, d) — the caller gathers
        each request's last valid position and applies the readout once
        (see launch/steps.py::make_prefill_step)."""
        x = self.embed_inputs(params, {"tokens": tokens}, ctx)
        return self.stack.prefill(params["stack"], x, cache, ctx,
                                  q_offset=q_offset, lengths=lengths,
                                  kv_limit=kv_limit)

    def decode_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        """tokens: (B, 1) -> (logits (B, 1, V), new cache).

        ``cur_pos`` may be a per-slot (B,) position vector and
        ``slot_mask`` a (B,) active mask — the continuous-batching decode
        contract (see launch/scheduler.py); scalar cur_pos keeps the
        single-stream behavior."""
        x = self.embed(params["embed"], tokens)
        h, cache = self.stack.decode(params["stack"], x, cache, cur_pos, ctx,
                                     slot_mask=slot_mask)
        return self.readout_fn(params, ctx)(h), cache

    def verify_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        """Speculative-verify pass: tokens (B, s) — the pending token plus
        s - 1 drafted tokens — run as one batched window at per-slot
        offsets ``cur_pos`` (B,).  Returns (logits (B, s, V), new cache):
        position j's logits are the model's next-token distribution after
        token j, which is what the accept rule compares drafts against.
        The window's K/V append into the cache at ``cur_pos + [0, s)``;
        rejected tail entries are logically dead (``KVCache.rollback``).
        With s == 1 this is exactly ``decode_step`` at vector positions —
        the bit-parity anchor for speculative == greedy."""
        x = self.embed(params["embed"], tokens)
        h, cache = self.stack.verify(params["stack"], x, cache, cur_pos, ctx,
                                     slot_mask=slot_mask)
        return self.readout_fn(params, ctx)(h), cache

    # -- quantization plans ---------------------------------------------------
    def fold_plan(self):
        """Pre-norm gamma folds into the projections that consume it
        (paper §3.1.2 analog)."""
        plan = []
        for i, blk in enumerate(self.stack.blocks):
            bp = blk.path
            targets = []
            if hasattr(blk, "attn"):
                targets += [f"{bp}/attn/wq", f"{bp}/attn/wk", f"{bp}/attn/wv"]
            if hasattr(blk, "mamba"):
                mp = blk.mamba.path
                targets += [f"{mp}/z_proj", f"{mp}/x_proj", f"{mp}/b_proj",
                            f"{mp}/c_proj", f"{mp}/dt_proj"]
            if targets:
                plan.append((f"{bp}/pre_norm", targets))
            if blk.ffn_kind in ("swiglu",):
                plan.append((f"{bp}/ffn_norm",
                             [blk.ffn.gate.path, blk.ffn.up.path]))
            elif blk.ffn_kind == "gelu":
                plan.append((f"{bp}/ffn_norm",
                             [blk.ffn.fc1.path]))
        return plan

    def equalization_plan(self):
        """§3.3 analog pairs: v->o per attention, up->down per (Swi)GLU."""
        plan = []
        for blk in self.stack.blocks:
            if hasattr(blk, "attn"):
                plan.append((blk.attn.wv.path, blk.attn.wo.path))
            if blk.ffn_kind in ("swiglu", "moe"):
                plan.extend(blk.ffn.equalization_pairs())
            if hasattr(blk, "mamba"):
                plan.extend(blk.mamba.equalization_pairs())
        return plan


class EncDecLM(Module):
    """Encoder-decoder (seamless-m4t backbone): bidirectional encoder over
    precomputed audio frame embeddings + causal text decoder w/ cross-attn."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.path = cfg.name
        self.embed = Embedding(cfg.vocab, cfg.d_model, path=f"{self.path}/embed",
                               dtype=cfg.dtype, vocab_padded=cfg.vocab_padded)
        # audio frontend stub: frames arrive as (B, S_enc, frame_dim)
        self.frame_proj = Dense(cfg.frame_dim, cfg.d_model,
                                path=f"{self.path}/frame_proj",
                                logical_axes=("mm", "embed"), dtype=cfg.dtype)
        enc_cfg = cfg.replace(causal=False)
        self.encoder = Stack(enc_cfg, path=f"{self.path}/encoder",
                             n_layers=cfg.n_layers)
        self.decoder = Stack(cfg, path=f"{self.path}/decoder",
                             n_layers=cfg.n_layers, cross=True)

    def init(self, key):
        ks = jax.random.split(key, 4)
        return {
            "embed": self.embed.init(ks[0]),
            "frame_proj": self.frame_proj.init(ks[1]),
            "encoder": self.encoder.init(ks[2]),
            "decoder": self.decoder.init(ks[3]),
        }

    def encode(self, params, frames, ctx=None, *, remat: bool = False):
        x = self.frame_proj(params["frame_proj"], frames, ctx)
        h, _ = self.encoder(params["encoder"], x, ctx, remat=remat)
        return h

    def readout_fn(self, params, ctx=None):
        return lambda h: self.embed.attend(params["embed"], h, ctx)

    def hidden(self, params, batch, ctx=None, *, remat: bool = False):
        memory = self.encode(params, batch["frames"], ctx, remat=remat)
        x = self.embed(params["embed"], batch["tokens"])
        return self.decoder(params["decoder"], x, ctx, memory=memory,
                            remat=remat)

    def __call__(self, params, batch, ctx=None, *, remat: bool = False):
        h, aux = self.hidden(params, batch, ctx, remat=remat)
        return self.readout_fn(params, ctx)(h), aux

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_int8: bool = False, layout: str = "ring",
                   page_size: int = 64, extra_pages: int = 0,
                   kv_bits: int = 8):
        return self.decoder.init_cache(batch, max_len, dtype,
                                       kv_int8=kv_int8, layout=layout,
                                       page_size=page_size,
                                       extra_pages=extra_pages,
                                       kv_bits=kv_bits)

    def prefill(self, params, batch, cache, ctx=None):
        memory = self.encode(params, batch["frames"], ctx)
        x = self.embed(params["embed"], batch["tokens"])
        h, cache = self.decoder.prefill(params["decoder"], x, cache, ctx,
                                        memory=memory)
        return self.readout_fn(params, ctx)(h[:, -1:, :]), cache

    def decode_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        x = self.embed(params["embed"], tokens)
        h, cache = self.decoder.decode(params["decoder"], x, cache, cur_pos,
                                       ctx, slot_mask=slot_mask)
        return self.readout_fn(params, ctx)(h), cache

    def fold_plan(self):
        plan = []
        for stack in (self.encoder, self.decoder):
            for blk in stack.blocks:
                bp = blk.path
                if hasattr(blk, "attn"):
                    plan.append((f"{bp}/pre_norm",
                                 [f"{bp}/attn/wq", f"{bp}/attn/wk",
                                  f"{bp}/attn/wv"]))
                if blk.ffn_kind == "gelu":
                    plan.append((f"{bp}/ffn_norm", [blk.ffn.fc1.path]))
                elif blk.ffn_kind == "swiglu":
                    plan.append((f"{bp}/ffn_norm",
                                 [blk.ffn.gate.path, blk.ffn.up.path]))
        return plan

    def equalization_plan(self):
        plan = []
        for stack in (self.encoder, self.decoder):
            for blk in stack.blocks:
                if hasattr(blk, "attn"):
                    plan.append((blk.attn.wv.path, blk.attn.wo.path))
                if blk.ffn_kind in ("swiglu", "moe"):
                    plan.extend(blk.ffn.equalization_pairs())
        return plan


def build_model(cfg):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return CausalLM(cfg)
