"""Decoder/encoder stacks composing attention / MoE / SSM / hybrid blocks.

A ``Block`` is one residual layer; its kind is selected per-layer by the
config's ``layer_kinds`` pattern, which is how gemma3's 5:1 local:global
attention, hymba's parallel attn+SSM, and pure-SSM mamba2 are expressed in
one stack implementation.

Remat: each block is wrapped in ``jax.checkpoint`` (policy: save nothing /
dots) under training so the 4k x 256 batch fits; serving paths never remat.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import Attention
from repro.models.layers import LayerNorm, RMSNorm
from repro.models.mlp import GeluMLP, SwiGLU
from repro.models.moe import MoE
from repro.models.module import Module
from repro.models.ssm import Mamba2Block


class Block(Module):
    """One pre-norm residual layer: norm -> mixer -> (+) -> norm -> ffn -> (+).

    kind: 'attn' | 'attn_local' | 'mamba' | 'hybrid'
    ffn:  'swiglu' | 'gelu' | 'moe' | 'none' (mamba2 has no separate FFN)
    """

    def __init__(self, cfg, layer_idx: int, *, path: str, cross: bool = False):
        self.cfg = cfg
        self.idx = layer_idx
        self.path = path
        self.cross = cross
        d = cfg.d_model
        dt = cfg.dtype
        norm_cls = LayerNorm if cfg.norm == "layernorm" else RMSNorm

        self.kind = cfg.layer_kind(layer_idx)
        self.ffn_kind = cfg.ffn_kind(layer_idx)

        self.pre_norm = norm_cls(d, path=f"{path}/pre_norm", dtype=dt)
        if self.kind in ("attn", "attn_local", "hybrid"):
            window = cfg.attn_window(layer_idx)
            self.attn = Attention(
                d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                path=f"{path}/attn", window=window, rope_base=cfg.rope_base,
                causal=cfg.causal, dtype=dt,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            )
        if self.kind in ("mamba", "hybrid"):
            self.mamba = Mamba2Block(
                d, path=f"{path}/mamba", d_state=cfg.ssm_state,
                n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, n_groups=cfg.ssm_groups,
                chunk=cfg.ssm_chunk, dtype=dt,
            )
        if self.kind == "hybrid":
            # hymba: per-branch output norms before mean fusion
            self.attn_out_norm = norm_cls(d, path=f"{path}/attn_out_norm", dtype=dt)
            self.mamba_out_norm = norm_cls(d, path=f"{path}/mamba_out_norm", dtype=dt)
        if self.cross:
            self.cross_norm = norm_cls(d, path=f"{path}/cross_norm", dtype=dt)
            self.cross_attn = Attention(
                d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                path=f"{path}/cross_attn", causal=False, cross=True,
                rope_base=cfg.rope_base, dtype=dt,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            )
        if self.ffn_kind != "none":
            self.ffn_norm = norm_cls(d, path=f"{path}/ffn_norm", dtype=dt)
            if self.ffn_kind == "moe":
                self.ffn = MoE(d, cfg.d_ff, cfg.n_experts, cfg.top_k,
                               path=f"{path}/moe", dtype=dt,
                               capacity_factor=cfg.capacity_factor)
            elif self.ffn_kind == "gelu":
                self.ffn = GeluMLP(d, cfg.d_ff, path=f"{path}/mlp", dtype=dt,
                                   activation=cfg.mlp_activation)
            else:
                self.ffn = SwiGLU(d, cfg.d_ff, path=f"{path}/mlp", dtype=dt,
                                  activation=cfg.mlp_activation)

    def init(self, key):
        p = {}
        ks = iter(jax.random.split(key, 8))
        p["pre_norm"] = self.pre_norm.init(next(ks))
        if hasattr(self, "attn"):
            p["attn"] = self.attn.init(next(ks))
        if hasattr(self, "mamba"):
            p["mamba"] = self.mamba.init(next(ks))
        if self.kind == "hybrid":
            p["attn_out_norm"] = self.attn_out_norm.init(next(ks))
            p["mamba_out_norm"] = self.mamba_out_norm.init(next(ks))
        if self.cross:
            p["cross_norm"] = self.cross_norm.init(next(ks))
            p["cross_attn"] = self.cross_attn.init(next(ks))
        if self.ffn_kind != "none":
            p["ffn_norm"] = self.ffn_norm.init(next(ks))
            p["ffn"] = self.ffn.init(next(ks))
        return p

    def _mixer(self, params, h, ctx, memory, force_full=None):
        if self.kind == "hybrid":
            a = self.attn(params["attn"], h, ctx, force_full=force_full)
            m = self.mamba(params["mamba"], h, ctx)
            a = self.attn_out_norm(params["attn_out_norm"], a)
            m = self.mamba_out_norm(params["mamba_out_norm"], m)
            return 0.5 * (a + m)
        if self.kind == "mamba":
            return self.mamba(params["mamba"], h, ctx)
        return self.attn(params["attn"], h, ctx, force_full=force_full)

    def __call__(self, params, x, ctx=None, *, memory=None, force_full=None):
        """Returns (y, aux) where aux is the MoE load-balance loss (0 else)."""
        h = self.pre_norm(params["pre_norm"], x)
        x = x + self._mixer(params, h, ctx, memory, force_full)
        if self.cross:
            h = self.cross_norm(params["cross_norm"], x)
            x = x + self.cross_attn(params["cross_attn"], h, ctx, memory=memory)
        aux = jnp.zeros((), jnp.float32)
        if self.ffn_kind != "none":
            h = self.ffn_norm(params["ffn_norm"], x)
            if self.ffn_kind == "moe":
                y, aux = self.ffn(params["ffn"], h, ctx)
            else:
                y = self.ffn(params["ffn"], h, ctx)
            x = x + y
        return x, aux

    # -- caches -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_int8: bool = False, layout: str = "ring",
                   page_size: int = 64, extra_pages: int = 0,
                   kv_bits: int = 8) -> dict:
        c = {}
        if hasattr(self, "attn"):
            c["attn"] = self.attn.init_cache(batch, max_len, dtype,
                                             kv_int8=kv_int8, layout=layout,
                                             page_size=page_size,
                                             extra_pages=extra_pages,
                                             kv_bits=kv_bits)
        if hasattr(self, "mamba"):
            c["mamba"] = self.mamba.init_cache(batch)
        if self.cross:
            # cross memory is prefill-only traffic; stays full precision
            c["cross"] = self.cross_attn.init_cache(batch, max_len, dtype)
        return c

    def prefill(self, params, x, cache, ctx=None, *, memory=None,
                q_offset=0, lengths=None, kv_limit=None):
        """``q_offset``/``lengths``/``kv_limit`` thread chunked ragged
        prefill down to attention (SSM state folding is offset-free: it
        continues from the carried cache state, so chunking composes;
        raggedness is an attention-mask concept guarded at the step
        level)."""
        h = self.pre_norm(params["pre_norm"], x)
        new_cache = dict(cache)
        if self.kind == "hybrid":
            a, new_cache["attn"] = self.attn.prefill(
                params["attn"], h, cache["attn"], ctx,
                q_offset=q_offset, lengths=lengths, kv_limit=kv_limit)
            m = self.mamba(params["mamba"], h, ctx)
            # rebuild mamba decode state from the full prefill (rerun tail):
            new_cache["mamba"] = self._mamba_state_from_prefill(params, h,
                                                                cache, ctx)
            a = self.attn_out_norm(params["attn_out_norm"], a)
            m = self.mamba_out_norm(params["mamba_out_norm"], m)
            mix = 0.5 * (a + m)
        elif self.kind == "mamba":
            mix = self.mamba(params["mamba"], h, ctx)
            new_cache["mamba"] = self._mamba_state_from_prefill(params, h,
                                                                cache, ctx)
        else:
            mix, new_cache["attn"] = self.attn.prefill(
                params["attn"], h, cache["attn"], ctx,
                q_offset=q_offset, lengths=lengths, kv_limit=kv_limit)
        x = x + mix
        if self.cross:
            h = self.cross_norm(params["cross_norm"], x)
            y, new_cache["cross"] = self.cross_attn.prefill(
                params["cross_attn"], h, cache["cross"], ctx, memory=memory
            )
            x = x + y
        if self.ffn_kind != "none":
            h = self.ffn_norm(params["ffn_norm"], x)
            if self.ffn_kind == "moe":
                y, _ = self.ffn(params["ffn"], h, ctx)
            else:
                y = self.ffn(params["ffn"], h, ctx)
            x = x + y
        return x, new_cache

    def _mamba_state_from_prefill(self, params, h, cache, ctx=None):
        """Sequentially folds the prefill into the SSD decode state.

        Uses the chunked scan's final carry — recomputed here in one pass
        (linear cost) rather than threaded through ssd_chunked, keeping the
        train path allocation-free."""
        # cheap correct path: run decode steps over the last conv window to
        # build conv state, and a full linear scan for the ssm state.
        m = self.mamba
        bsz, l, _ = h.shape
        z, xi, bi, ci, dt = m._project(params["mamba"], h, ctx)
        xbc = jnp.concatenate([
            xi.astype(jnp.float32), bi.astype(jnp.float32),
            ci.astype(jnp.float32)], axis=-1)
        from repro.models.ssm import causal_conv1d, ssd_decode_step
        from repro.models.layers import silu as _silu
        conv_state = xbc[:, -(m.conv_width - 1):, :]
        xbc_c = _silu(causal_conv1d(xbc, params["mamba"]["conv_w"],
                                    params["mamba"]["conv_b"]))
        di, gn = m.d_inner, m.n_groups * m.d_state
        x_h = xbc_c[..., :di].reshape(bsz, l, m.n_heads, m.head_dim)
        b_h = xbc_c[..., di:di + gn].reshape(bsz, l, m.n_groups, m.d_state)
        c_h = xbc_c[..., di + gn:].reshape(bsz, l, m.n_groups, m.d_state)
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + params["mamba"]["dt_bias"])

        def step(state, inp):
            x_t, dt_t, b_t, c_t = inp
            s, _ = ssd_decode_step(state, x_t, dt_t, params["mamba"]["a_log"],
                                   b_t, c_t)
            return s, None

        init = cache["mamba"]["ssm"]
        state, _ = jax.lax.scan(
            step, init,
            (jnp.moveaxis(x_h, 1, 0), jnp.moveaxis(dt_s, 1, 0),
             jnp.moveaxis(b_h, 1, 0), jnp.moveaxis(c_h, 1, 0)),
        )
        return {"ssm": state, "conv": conv_state}

    def decode(self, params, x, cache, cur_pos, ctx=None, *, memory=None,
               slot_mask=None):
        """``cur_pos`` may be a per-slot (B,) vector and ``slot_mask`` a
        (B,) active mask (continuous batching) — both thread down to
        attention; SSM decode has no per-request position concept, so the
        scheduler guards attention-only stacks at construction."""
        h = self.pre_norm(params["pre_norm"], x)
        new_cache = dict(cache)
        if self.kind == "hybrid":
            a, new_cache["attn"] = self.attn.decode(params["attn"], h,
                                                    cache["attn"], cur_pos, ctx,
                                                    slot_mask=slot_mask)
            m, new_cache["mamba"] = self.mamba.decode(params["mamba"], h,
                                                      cache["mamba"], ctx)
            a = self.attn_out_norm(params["attn_out_norm"], a)
            m = self.mamba_out_norm(params["mamba_out_norm"], m)
            mix = 0.5 * (a + m)
        elif self.kind == "mamba":
            mix, new_cache["mamba"] = self.mamba.decode(params["mamba"], h,
                                                        cache["mamba"], ctx)
        else:
            mix, new_cache["attn"] = self.attn.decode(params["attn"], h,
                                                      cache["attn"], cur_pos, ctx,
                                                      slot_mask=slot_mask)
        x = x + mix
        if self.cross:
            h = self.cross_norm(params["cross_norm"], x)
            y, _ = self.cross_attn.decode(params["cross_attn"], h,
                                          cache["cross"], cur_pos, ctx,
                                          memory=memory)
            x = x + y
        if self.ffn_kind != "none":
            h = self.ffn_norm(params["ffn_norm"], x)
            if self.ffn_kind == "moe":
                y, _ = self.ffn(params["ffn"], h, ctx)
            else:
                y = self.ffn(params["ffn"], h, ctx)
            x = x + y
        return x, new_cache


    def verify(self, params, x, cache, cur_pos, ctx=None, *,
               slot_mask=None):
        """Speculative-verify: s draft tokens per slot at per-slot
        offsets — the multi-token sibling of ``decode``.  Attention-only
        (SSM state stepping has no rewind, so strategies guard the config
        upstream, same as the slot decode loop)."""
        if self.kind not in ("attn", "attn_local") or self.cross:
            raise ValueError(
                f"{self.path}: speculative verify covers attention-only "
                f"causal stacks (got kind={self.kind!r}, "
                f"cross={self.cross})")
        h = self.pre_norm(params["pre_norm"], x)
        new_cache = dict(cache)
        mix, new_cache["attn"] = self.attn.verify(
            params["attn"], h, cache["attn"], cur_pos, ctx,
            slot_mask=slot_mask)
        x = x + mix
        if self.ffn_kind != "none":
            h = self.ffn_norm(params["ffn_norm"], x)
            if self.ffn_kind == "moe":
                y, _ = self.ffn(params["ffn"], h, ctx)
            else:
                y = self.ffn(params["ffn"], h, ctx)
            x = x + y
        return x, new_cache


class Stack(Module):
    """A stack of Blocks with optional remat and a final norm.

    Two parameter layouts:
      * unrolled (default; smoke scale): one params subtree per layer,
        heterogeneous blocks allowed.
      * scanned (cfg.scan_layers; production): all layer params stacked
        with a leading (L,) axis under params['layers'], forward is one
        ``lax.scan`` over layers — HLO size (and compile time) becomes
        O(1) in depth instead of O(L), which is what makes the 48-60 layer
        dry-runs compile.  Per-layer structural differences (gemma3's 5:1
        local:global, hymba's 3 global layers) are expressed by a traced
        ``force_full`` flag vector + lax.cond inside the body, since param
        *shapes* are identical across layers in every assigned arch.
    """

    def __init__(self, cfg, *, path: str, cross: bool = False,
                 n_layers: int | None = None, causal: bool = True):
        self.cfg = cfg
        self.path = path
        self.cross = cross
        self.n_layers = n_layers or cfg.n_layers
        if cfg.scan_layers:
            # one template block; layer differences via force_full flags
            self.template = Block(cfg, self._template_idx(), path=f"{path}/layers",
                                  cross=cross)
            self.blocks = [self.template]
        else:
            self.blocks = [
                Block(cfg, i, path=f"{path}/layer{i}", cross=cross)
                for i in range(self.n_layers)
            ]
        norm_cls = LayerNorm if cfg.norm == "layernorm" else RMSNorm
        self.final_norm = norm_cls(cfg.d_model, path=f"{path}/final_norm",
                                   dtype=cfg.dtype)

    def _template_idx(self) -> int:
        """Pick a layer index whose window is non-None if any layer has one
        (the template must carry the SWA machinery)."""
        for i in range(self.n_layers):
            if self.cfg.attn_window(i) is not None:
                return i
        return 0

    def _force_full_flags(self):
        """None if all layers share the template's window, else (L,) bool."""
        if not self.cfg.scan_layers:
            return None
        tw = self.cfg.attn_window(self._template_idx())
        flags = [self.cfg.attn_window(i) is None and tw is not None
                 for i in range(self.n_layers)]
        if not any(flags):
            return None
        return jnp.asarray(flags)

    @property
    def scanned(self) -> bool:
        return bool(self.cfg.scan_layers)

    def param_children(self):
        if self.scanned:
            c = {"layers": self.template}
        else:
            c = {f"layer{i}": b for i, b in enumerate(self.blocks)}
        c["final_norm"] = self.final_norm
        return c

    def init(self, key):
        if self.scanned:
            k1, k2 = jax.random.split(key)
            ks = jax.random.split(k1, self.n_layers)
            p = {"layers": jax.vmap(self.template.init)(ks)}
            p["final_norm"] = self.final_norm.init(k2)
            return p
        ks = jax.random.split(key, self.n_layers + 1)
        p = {f"layer{i}": b.init(ks[i]) for i, b in enumerate(self.blocks)}
        p["final_norm"] = self.final_norm.init(ks[-1])
        return p

    # -- scan helpers ---------------------------------------------------------
    def _stack_qparams(self, ctx):
        """Subset of ctx.qparams belonging to the scanned stack (leading L)."""
        if ctx is None:
            return {}
        prefix = self.template.path
        return {p: e for p, e in ctx.qparams.items() if p.startswith(prefix)}

    def _scan_call(self, params, x, ctx, memory, remat):
        from repro.core.api import QuantCtx

        flags = self._force_full_flags()
        qs = self._stack_qparams(ctx)
        mode = ctx.mode if ctx is not None else "none"
        policy = ctx.policy if ctx is not None else None

        from repro.dist.constraints import constrain_activation

        def body(x, xs):
            lp, lq, flag = xs
            # barrier: stops XLA hoisting per-layer transforms of the
            # sliced params (e.g. the fake-quant f32 upcast) out of the
            # loop, which would materialize (L, ...) f32 stacks
            lp = jax.lax.optimization_barrier(lp)
            lctx = None
            if ctx is not None:
                lctx = QuantCtx(mode=mode, policy=policy, qparams=lq)
            # carry enters sequence-sharded (SP residual stack), is
            # gathered for the block, and leaves sequence-sharded again
            x = constrain_activation(x, carry=True)
            y, aux = self.template(lp, constrain_activation(x), lctx,
                                   memory=memory, force_full=flag)
            y = constrain_activation(y, carry=True)
            ys = (aux, lctx.updates if lctx is not None else {})
            return y, ys

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        # lax.scan requires every xs leaf to have leading L; flags may be None
        if flags is None:
            def body2(x, xs2):
                lp, lq = xs2
                return body(x, (lp, lq, None))
            x, (auxs, updates) = jax.lax.scan(body2, x, (params["layers"], qs))
        else:
            x, (auxs, updates) = jax.lax.scan(
                body, x, (params["layers"], qs, flags))
        if ctx is not None and mode == "calibrate":
            ctx.updates.update(updates)
        return x, jnp.sum(auxs)

    def __call__(self, params, x, ctx=None, *, memory=None, remat: bool = False):
        if self.scanned:
            x, aux_total = self._scan_call(params, x, ctx, memory, remat)
            return self.final_norm(params["final_norm"], x), aux_total
        aux_total = jnp.zeros((), jnp.float32)
        for i, blk in enumerate(self.blocks):
            if remat:
                fn = jax.checkpoint(
                    lambda p, h, m, _blk=blk: _blk(p, h, ctx, memory=m),
                    policy=jax.checkpoint_policies.nothing_saveable,
                )
                x, aux = fn(params[f"layer{i}"], x, memory)
            else:
                x, aux = blk(params[f"layer{i}"], x, ctx, memory=memory)
            aux_total = aux_total + aux
        return self.final_norm(params["final_norm"], x), aux_total

    # -- serve structure ------------------------------------------------------
    @property
    def serve_homogeneous(self) -> bool:
        """True when every layer shares kind+window (cache shapes equal) —
        the scanned serve path applies (mixtral, mamba2, dense archs)."""
        kinds = {self.cfg.layer_kind(i) for i in range(self.n_layers)}
        wins = {self.cfg.attn_window(i) for i in range(self.n_layers)}
        return len(kinds) == 1 and len(wins) == 1 and not self.cross

    def _serve_blocks(self):
        """Per-layer block views for the unrolled serve path in scan mode.

        All views share the template's path: qparams entries are stacked
        (L, ...) and the serve loop slices both params and qparams per
        layer."""
        if not self.scanned:
            return self.blocks
        if not hasattr(self, "_serve_blocks_cache"):
            self._serve_blocks_cache = [
                Block(self.cfg, i, path=self.template.path, cross=self.cross)
                for i in range(self.n_layers)
            ]
        return self._serve_blocks_cache

    def _layer_view(self, params, ctx, i):
        """(sliced params, sliced ctx) for layer i of a scanned stack."""
        from repro.core.api import QuantCtx

        lp = jax.tree.map(lambda a: a[i], params["layers"])
        lctx = ctx
        if ctx is not None:
            qs = {p: jax.tree.map(lambda a: a[i], e)
                  for p, e in self._stack_qparams(ctx).items()}
            lctx = QuantCtx(mode=ctx.mode, policy=ctx.policy, qparams=qs,
                            updates=ctx.updates)
        return lp, lctx

    # -- caches -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_int8: bool = False, layout: str = "ring",
                   page_size: int = 64, extra_pages: int = 0,
                   kv_bits: int = 8):
        kw = dict(kv_int8=kv_int8, layout=layout, page_size=page_size,
                  extra_pages=extra_pages, kv_bits=kv_bits)
        if self.scanned and self.serve_homogeneous:
            one = self.template.init_cache(batch, max_len, dtype, **kw)
            # scale leaves init to ones, not zeros: a layer whose prefill
            # never runs (impossible today, defensive) must still dequant
            # to finite values
            return jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (self.n_layers,) + a.shape).astype(a.dtype), one
            )
        blocks = self._serve_blocks() if self.scanned else self.blocks
        return {
            f"layer{i}": b.init_cache(batch, max_len, dtype, **kw)
            for i, b in enumerate(blocks)
        }

    def prefill(self, params, x, cache, ctx=None, *, memory=None,
                q_offset=0, lengths=None, kv_limit=None):
        if self.scanned and self.serve_homogeneous:
            from repro.core.api import QuantCtx

            qs = self._stack_qparams(ctx)
            mode = ctx.mode if ctx is not None else "none"
            policy = ctx.policy if ctx is not None else None

            def body(x, xs):
                lp, lc, lq = xs
                lctx = QuantCtx(mode, policy, lq) if ctx is not None else None
                return self.template.prefill(lp, x, lc, lctx, memory=memory,
                                             q_offset=q_offset,
                                             lengths=lengths,
                                             kv_limit=kv_limit)

            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, qs))
            return self.final_norm(params["final_norm"], x), new_cache
        if self.scanned:
            new_cache = {}
            for i, blk in enumerate(self._serve_blocks()):
                lp, lctx = self._layer_view(params, ctx, i)
                x, new_cache[f"layer{i}"] = blk.prefill(
                    lp, x, cache[f"layer{i}"], lctx, memory=memory,
                    q_offset=q_offset, lengths=lengths, kv_limit=kv_limit)
            return self.final_norm(params["final_norm"], x), new_cache
        new_cache = {}
        for i, blk in enumerate(self.blocks):
            x, new_cache[f"layer{i}"] = blk.prefill(
                params[f"layer{i}"], x, cache[f"layer{i}"], ctx,
                memory=memory, q_offset=q_offset, lengths=lengths,
                kv_limit=kv_limit,
            )
        return self.final_norm(params["final_norm"], x), new_cache

    def decode(self, params, x, cache, cur_pos, ctx=None, *, memory=None,
               slot_mask=None):
        if self.scanned and self.serve_homogeneous:
            from repro.core.api import QuantCtx

            qs = self._stack_qparams(ctx)
            mode = ctx.mode if ctx is not None else "none"
            policy = ctx.policy if ctx is not None else None

            def body(x, xs):
                lp, lc, lq = xs
                lctx = QuantCtx(mode, policy, lq) if ctx is not None else None
                return self.template.decode(lp, x, lc, cur_pos, lctx,
                                            memory=memory,
                                            slot_mask=slot_mask)

            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, qs))
            return self.final_norm(params["final_norm"], x), new_cache
        if self.scanned:
            new_cache = {}
            for i, blk in enumerate(self._serve_blocks()):
                lp, lctx = self._layer_view(params, ctx, i)
                x, new_cache[f"layer{i}"] = blk.decode(
                    lp, x, cache[f"layer{i}"], cur_pos, lctx, memory=memory,
                    slot_mask=slot_mask)
            return self.final_norm(params["final_norm"], x), new_cache
        new_cache = {}
        for i, blk in enumerate(self.blocks):
            x, new_cache[f"layer{i}"] = blk.decode(
                params[f"layer{i}"], x, cache[f"layer{i}"], cur_pos, ctx,
                memory=memory, slot_mask=slot_mask,
            )
        return self.final_norm(params["final_norm"], x), new_cache

    def verify(self, params, x, cache, cur_pos, ctx=None, *,
               slot_mask=None):
        """Speculative-verify over the stack: the same three layouts as
        ``decode`` (scanned-homogeneous lax.scan, scanned-unrolled,
        unrolled), with each block running its multi-token per-slot
        verify window."""
        if self.scanned and self.serve_homogeneous:
            from repro.core.api import QuantCtx

            qs = self._stack_qparams(ctx)
            mode = ctx.mode if ctx is not None else "none"
            policy = ctx.policy if ctx is not None else None

            def body(x, xs):
                lp, lc, lq = xs
                lctx = QuantCtx(mode, policy, lq) if ctx is not None else None
                return self.template.verify(lp, x, lc, cur_pos, lctx,
                                            slot_mask=slot_mask)

            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, qs))
            return self.final_norm(params["final_norm"], x), new_cache
        if self.scanned:
            new_cache = {}
            for i, blk in enumerate(self._serve_blocks()):
                lp, lctx = self._layer_view(params, ctx, i)
                x, new_cache[f"layer{i}"] = blk.verify(
                    lp, x, cache[f"layer{i}"], cur_pos, lctx,
                    slot_mask=slot_mask)
            return self.final_norm(params["final_norm"], x), new_cache
        new_cache = {}
        for i, blk in enumerate(self.blocks):
            x, new_cache[f"layer{i}"] = blk.verify(
                params[f"layer{i}"], x, cache[f"layer{i}"], cur_pos, ctx,
                slot_mask=slot_mask,
            )
        return self.final_norm(params["final_norm"], x), new_cache
