"""Feed-forward blocks: SwiGLU (llama family) and classic GELU MLP.

The SwiGLU block declares the paper's §3.3 cross-layer equalization pair:
``up -> down`` is linear through the elementwise gate product (the
silu(gate) path is untouched by an up-channel rescale), so per-channel
scales migrate between w_up's output channels and w_down's input rows with
zero functional change — the transformer analog of the paper's
DWS -> ReLU -> Conv rescaling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS
from repro.models.module import Dense, Module


class SwiGLU(Module):
    def __init__(self, d_model: int, d_ff: int, *, path: str, dtype=jnp.bfloat16,
                 activation: str = "silu"):
        self.d_model = d_model
        self.d_ff = d_ff
        self.path = path
        self.act = ACTIVATIONS[activation]
        self.gate = Dense(d_model, d_ff, path=f"{path}/gate",
                          logical_axes=("embed", "mlp"), dtype=dtype)
        self.up = Dense(d_model, d_ff, path=f"{path}/up",
                        logical_axes=("embed", "mlp"), dtype=dtype)
        self.down = Dense(d_ff, d_model, path=f"{path}/down",
                          logical_axes=("mlp", "embed"), dtype=dtype)

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"gate": self.gate.init(k1), "up": self.up.init(k2),
                "down": self.down.init(k3)}

    def __call__(self, params, x, ctx=None):
        g = self.act(self.gate(params["gate"], x, ctx))
        u = self.up(params["up"], x, ctx)
        return self.down(params["down"], g * u, ctx)

    def equalization_pairs(self):
        """§3.3 analog: the up->down pair is linear through the gate.

        The gate projection is 'locked' (nonlinearity in its path) —
        mirrors the paper's locked DWS channels.
        """
        return [(self.up.path, self.down.path)]


class GeluMLP(Module):
    """Classic 2-layer MLP (seamless/enc-dec style).

    fc1 -> gelu -> fc2 is NOT an equalization pair (gelu is nonlinear and
    unbounded-below; scaling does not commute) — the paper's restriction
    applies, so this block declares no pairs.
    """

    def __init__(self, d_model: int, d_ff: int, *, path: str, dtype=jnp.bfloat16,
                 activation: str = "gelu"):
        self.d_model = d_model
        self.d_ff = d_ff
        self.path = path
        self.act = ACTIVATIONS[activation]
        self.fc1 = Dense(d_model, d_ff, path=f"{path}/fc1", bias=True,
                         logical_axes=("embed", "mlp"), dtype=dtype)
        self.fc2 = Dense(d_ff, d_model, path=f"{path}/fc2", bias=True,
                         logical_axes=("mlp", "embed"), dtype=dtype,
                         act_unsigned=(activation == "relu"))

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"fc1": self.fc1.init(k1), "fc2": self.fc2.init(k2)}

    def __call__(self, params, x, ctx=None):
        return self.fc2(params["fc2"], self.act(self.fc1(params["fc1"], x, ctx)), ctx)

    def equalization_pairs(self):
        return []
