"""Minimal functional module system (no flax dependency).

Modules are plain Python objects built from configs; parameters live in
nested-dict pytrees that mirror the module tree.  Every module exposes:

  * ``init(key) -> params``   — build the parameter pytree
  * ``__call__(params, ..., ctx=...)`` — pure forward

Quantization (the paper's contribution) threads through a ``QuantCtx``
(see ``repro.core.api``): every quantizable layer carries a stable string
``path`` used to key threshold state, calibration updates and sharding
rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def split(key: jax.Array, n: int) -> Sequence[jax.Array]:
    return jax.random.split(key, n)


class Module:
    """Base class; subclasses define ``init`` and ``__call__``.

    ``iter_quant_layers`` recursively yields every quantizable leaf layer
    (``Dense``/``ExpertDense``) so the quantization state, the int8
    conversion and the sharding rules can be built by traversal.
    """

    path: str = ""

    def init(self, key: jax.Array) -> dict:  # pragma: no cover - abstract
        raise NotImplementedError

    def param_children(self) -> dict:
        """Mapping param-tree key -> child Module.

        Default: attribute name == param key (the convention throughout
        models/).  Containers that init under computed keys (Stack's
        ``layer{i}``) override this.
        """
        return {
            k: v for k, v in self.__dict__.items() if isinstance(v, Module)
        }

    def walk_with_params(self, params: dict):
        """Yield (module, params_subtree) for self and all descendants."""
        yield self, params
        for key, child in self.param_children().items():
            if isinstance(params, dict) and key in params:
                yield from child.walk_with_params(params[key])

    def iter_quant_layers(self) -> Iterator["Module"]:
        seen = set()
        stack = [self]
        while stack:
            m = stack.pop()
            if id(m) in seen:
                continue
            seen.add(id(m))
            if isinstance(m, (Dense, ExpertDense)):
                yield m
            for v in m.__dict__.values():
                if isinstance(v, Module):
                    stack.append(v)
                elif isinstance(v, (list, tuple)):
                    stack.extend(x for x in v if isinstance(x, Module))

    def iter_modules(self) -> Iterator["Module"]:
        stack = [self]
        seen = set()
        while stack:
            m = stack.pop()
            if id(m) in seen:
                continue
            seen.add(id(m))
            yield m
            for v in m.__dict__.values():
                if isinstance(v, Module):
                    stack.append(v)
                elif isinstance(v, (list, tuple)):
                    stack.extend(x for x in v if isinstance(x, Module))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def fan_in_init(key, shape, dtype):
    """LeCun-normal over the penultimate (fan-in) axis."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Dense — the quantization unit of the framework
# ---------------------------------------------------------------------------


class Dense(Module):
    """y = x @ W (+ b), quantizable per the paper's scheme.

    Logical axis names (``logical_axes``) drive sharding rules, e.g.
    ("embed", "mlp") for an up-projection.  ``act_unsigned`` marks inputs
    known to be non-negative (post-ReLU family) so the unsigned integer
    range is used (paper eq. 9).
    """

    def __init__(
        self,
        in_dim: int,
        out_dim: int,
        *,
        path: str,
        bias: bool = False,
        dtype=jnp.bfloat16,
        quantize: bool = True,
        act_unsigned: bool = False,
        logical_axes: tuple[str, str] = ("in", "out"),
        init_fn: Callable = fan_in_init,
    ):
        self.in_dim = in_dim
        self.out_dim = out_dim
        self.path = path
        self.bias = bias
        self.dtype = dtype
        self.quantize = quantize
        self.act_unsigned = act_unsigned
        self.logical_axes = logical_axes
        self.init_fn = init_fn

    # number of output channels for per-channel ("vector") thresholds
    @property
    def channels(self) -> int:
        return self.out_dim

    def init(self, key: jax.Array) -> dict:
        p = {"w": self.init_fn(key, (self.in_dim, self.out_dim), self.dtype)}
        if self.bias:
            p["b"] = jnp.zeros((self.out_dim,), self.dtype)
        return p

    def __call__(self, params: dict, x: jax.Array, ctx=None) -> jax.Array:
        from repro.core import api  # local import to avoid cycle

        return api.dense_forward(self, params, x, ctx)


class ExpertDense(Module):
    """Batched expert weights (E, in, out) for MoE layers.

    Quantized in vector mode with per-(expert, out-channel) thresholds —
    the natural generalization of the paper's per-filter thresholds.
    """

    def __init__(
        self,
        num_experts: int,
        in_dim: int,
        out_dim: int,
        *,
        path: str,
        dtype=jnp.bfloat16,
        quantize: bool = True,
        logical_axes: tuple[str, str, str] = ("expert", "in", "out"),
    ):
        self.num_experts = num_experts
        self.in_dim = in_dim
        self.out_dim = out_dim
        self.path = path
        self.dtype = dtype
        self.quantize = quantize
        self.act_unsigned = False
        self.logical_axes = logical_axes

    @property
    def channels(self) -> int:
        # flattened (expert, out) channel count for vector thresholds
        return self.num_experts * self.out_dim

    def init(self, key: jax.Array) -> dict:
        std = 1.0 / np.sqrt(self.in_dim)
        w = (
            jax.random.normal(
                key, (self.num_experts, self.in_dim, self.out_dim), jnp.float32
            )
            * std
        ).astype(self.dtype)
        return {"w": w}

    def __call__(self, params: dict, x: jax.Array, ctx=None) -> jax.Array:
        """x: (E, C, in) -> (E, C, out); einsum over per-expert blocks."""
        from repro.core import api

        return api.expert_dense_forward(self, params, x, ctx)
