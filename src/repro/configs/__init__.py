"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec, cell_applicable, all_cells

_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "stablelm-12b": "stablelm_12b",
    "granite-8b": "granite_8b",
    "gemma3-12b": "gemma3_12b",
    "smollm-135m": "smollm_135m",
    "mamba2-780m": "mamba2_780m",
    "llava-next-34b": "llava_next_34b",
    "hymba-1.5b": "hymba_1_5b",
}

ARCHS = list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG
