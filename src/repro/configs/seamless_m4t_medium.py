"""seamless-m4t-medium [audio] — enc-dec, 12L d1024 16H (kv=16) d_ff=4096
vocab=256206. Audio frontend is a stub: input_specs() provides precomputed
frame embeddings (B, S_enc, frame_dim). [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    modality="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    ffn="gelu",
    norm="layernorm",
    mlp_activation="gelu",
    frame_dim=1024,
    dec_ratio=8,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-medium-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    frame_dim=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
