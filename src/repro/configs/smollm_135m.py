"""smollm-135m [dense] — 30L d576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

Also the scale used by the end-to-end FAT training example
(examples/train_fat_qat.py) — ~100M params trains on CPU for a few
hundred distillation steps.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="smollm-135m-smoke",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=3,
    head_dim=16,
    d_ff=128,
    vocab=256,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
