"""gemma3-12b [dense] — 48L d3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local(SWA 1024):global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    local_global_ratio=(5, 1),
    window=1024,
    rope_base=1e6,
    tie_embeddings=True,
    mlp_activation="gelu",  # gemma uses gelu-gated (geglu)
)

SMOKE = CONFIG.replace(
    name="gemma3-12b-smoke",
    n_layers=6,  # one full 5:1 local:global period
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    window=16,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
