"""granite-8b [dense] — 36L d4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-arch, code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="granite-8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
