"""stablelm-12b [dense] — 40L d5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    norm="layernorm",
)

SMOKE = CONFIG.replace(
    name="stablelm-12b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
