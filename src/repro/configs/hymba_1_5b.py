"""hymba-1.5b [hybrid] — 32L d1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16, parallel attention + mamba heads per layer, SWA everywhere
except three global-attention layers. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    kind="hybrid",
    window=1024,
    global_attn_layers=(0, 15, 31),
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    ssm_expand=1,   # ssm branch width == d_model (25 x 64 = 1600)
    ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="hymba-1.5b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    window=16,
    global_attn_layers=(0,),
    ssm_state=16,
    ssm_heads=4,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
