"""mixtral-8x7b [moe] — 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    ffn="moe",
    n_experts=8,
    top_k=2,
    window=4096,
    window_all=True,
    rope_base=1e6,
)

SMOKE = CONFIG.replace(
    name="mixtral-8x7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_experts=4,
    top_k=2,
    window=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
