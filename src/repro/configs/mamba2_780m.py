"""mamba2-780m [ssm] — 48L d1536, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    n_layers=48,
    d_model=1536,
    n_heads=1,      # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    kind="mamba",
    ffn="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,   # d_inner = 3072, 48 SSD heads
    ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="mamba2-780m-smoke",
    n_layers=2,
    d_model=64,
    vocab=256,
    ssm_state=16,
    ssm_head_dim=16,  # d_inner=128, 8 heads
    ssm_chunk=16,
    loss_chunk=16,
)
