"""ModelConfig — single dataclass describing every assigned architecture.

Layer-pattern helpers (``layer_kind``/``attn_window``/``ffn_kind``) express
gemma3's 5:1 local:global attention, hymba's hybrid layers with three
global-attention layers, mixtral's all-layer SWA, and mamba2's attention-free
stack — all through one Stack implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    family: str = "causal"        # causal | encdec
    modality: str = "text"        # text | vlm | audio
    kind: str = "attn"            # attn | mamba | hybrid
    ffn: str = "swiglu"           # swiglu | gelu | moe | none
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    mlp_activation: str = "silu"
    causal: bool = True
    tie_embeddings: bool = False
    rope_base: float = 10000.0

    # --- attention window structure ---
    window: Optional[int] = None
    window_all: bool = False                    # SWA on every layer (mixtral)
    local_global_ratio: Optional[Tuple[int, int]] = None  # gemma3 (5, 1)
    global_attn_layers: Tuple[int, ...] = ()    # hymba full-attn layers

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 128
    ssm_heads: Optional[int] = None
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 128

    # --- modality frontends (stubs) ---
    mm_dim: int = 0        # vlm patch embedding dim
    mm_patches: int = 0    # patches prepended (counted inside seq_len)
    frame_dim: int = 0     # audio frame embedding dim
    dec_ratio: int = 8     # enc-dec: decoder len = seq_len // dec_ratio

    # --- perf knobs (hillclimb surface) ---
    dtype: jnp.dtype = jnp.bfloat16
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    loss_chunk: int = 256
    remat: bool = True
    scan_layers: bool = False  # lax.scan over stacked layer params (prod)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 128 (TPU lane width; also
        guarantees divisibility by the 16-way model axis).  Padded logits
        are masked to -inf at readout."""
        return -(-self.vocab // 128) * 128

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- per-layer structure ----
    def layer_kind(self, i: int) -> str:
        if self.kind == "mamba":
            return "mamba"
        if self.kind == "hybrid":
            return "hybrid"
        if self.local_global_ratio:
            l, g = self.local_global_ratio
            return "attn_local" if (i % (l + g)) < l else "attn"
        return "attn"

    def attn_window(self, i: int) -> Optional[int]:
        if i in self.global_attn_layers:
            return None
        if self.local_global_ratio:
            l, g = self.local_global_ratio
            return self.window if (i % (l + g)) < l else None
        if self.window_all or self.kind == "hybrid":
            return self.window
        return None

    def ffn_kind(self, i: int) -> str:
        return self.ffn

    # ---- sizing helpers (roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        """Total parameter count N (approximate, matches construction)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, h, kv = self.head_dim, self.n_heads, self.n_kv_heads
        per_layer = 0
        n_attn = sum(
            1 for i in range(self.n_layers)
            if self.layer_kind(i) in ("attn", "attn_local", "hybrid")
        )
        n_mamba = sum(
            1 for i in range(self.n_layers)
            if self.layer_kind(i) in ("mamba", "hybrid")
        )
        attn_p = d * h * hd + 2 * d * kv * hd + h * hd * d
        di = (self.ssm_heads or 1) * self.ssm_head_dim if self.ssm_heads else \
            self.ssm_expand * d
        gn = self.ssm_groups * self.ssm_state
        sh = self.ssm_heads or (di // self.ssm_head_dim)
        mamba_p = 2 * d * di + 2 * d * gn + d * sh + di * d
        if self.ffn == "moe":
            ffn_p = self.n_layers * (self.n_experts * 3 * d * f + d * self.n_experts)
        elif self.ffn == "swiglu":
            ffn_p = self.n_layers * 3 * d * f
        elif self.ffn == "gelu":
            ffn_p = self.n_layers * 2 * d * f
        else:
            ffn_p = 0
        total = n_attn * attn_p + n_mamba * mamba_p + ffn_p + v * d
        if not self.tie_embeddings:
            total += v * d
        if self.family == "encdec":
            total += self.n_layers * (attn_p + 2 * d * f)  # encoder
            total += self.n_layers * attn_p  # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE rooflines (6*N_active*D)."""
        if self.ffn != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * f
        return int(dense + self.n_layers * self.top_k * 3 * d * f)
