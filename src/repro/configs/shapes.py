"""Assigned input-shape set (one per shape cell).

  train_4k     seq 4096,   global batch 256  — training      (train_step)
  prefill_32k  seq 32768,  global batch 32   — inference     (prefill_step)
  decode_32k   seq 32768,  global batch 128  — decode        (serve_step)
  long_500k    seq 524288, global batch 1    — long decode   (serve_step)

``decode_*``/``long_*`` lower one new token against a KV cache of seq_len.
``long_500k`` requires sub-quadratic attention: it runs only for archs with
an SSM or sliding-window component (mamba2, hymba, gemma3, mixtral); pure
full-attention archs skip it (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs with a sub-quadratic path (SSM state or sliding-window KV) — the
# only ones for which a 512k-token decode cell is defined.
SUB_QUADRATIC = {"mamba2-780m", "hymba-1.5b", "gemma3-12b", "mixtral-8x7b"}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(applicable, reason-if-not)."""
    if shape == "long_500k" and arch not in SUB_QUADRATIC:
        return False, (
            "pure full-attention arch: 512k dense-KV decode has no "
            "sub-quadratic path (skip noted in DESIGN.md)"
        )
    return True, ""


def all_cells(archs: list[str]) -> list[tuple[str, str]]:
    cells = []
    for a in archs:
        for s in SHAPES:
            ok, _ = cell_applicable(a, s)
            if ok:
                cells.append((a, s))
    return cells
