"""llava-next-34b [vlm] — 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres tiling. Vision frontend is a stub: input_specs() provides
precomputed patch embeddings (B, P, mm_dim); P counts toward seq_len.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    modality="vlm",
    mm_dim=1024,       # vision tower (CLIP-L) hidden size
    mm_patches=2880,   # anyres: 5 tiles x 576 patches
)

SMOKE = CONFIG.replace(
    name="llava-next-34b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    mm_dim=32,
    mm_patches=8,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
