"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    ffn="moe",
    n_experts=40,
    top_k=8,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="granite-moe-3b-a800m-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab=256,
    n_experts=8,
    top_k=4,
    attn_q_chunk=16,
    attn_kv_chunk=16,
    loss_chunk=16,
)
