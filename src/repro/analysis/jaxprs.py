"""Shared jaxpr plumbing for the static analyzers.

Every checker in this package walks traced jaxprs of the serving entry
points (repro.analysis.entrypoints).  This module owns the one recursive
walk — into ``scan``/``while``/``cond``/``pjit``/``custom_vjp`` bodies
and into ``pallas_call`` kernel jaxprs — so each analyzer is a flat pass
over equations, and all of them agree on what "the whole graph" means.

Source attribution is best-effort: JAX equations carry a traceback; we
surface the innermost repo frame as ``file:line`` and the full frame
function-name list for allowlist scoping (an
:class:`repro.analysis.dtype_drift.AllowRule` can match "somewhere under
``compressed_psum``" without hardcoding line numbers).
"""
from __future__ import annotations

from typing import Iterator

import jax


def subjaxprs(eqn) -> list:
    """All jaxprs nested in an equation's params (scan/while/cond bodies,
    pjit calls, custom_vjp branches, pallas kernel bodies, ...)."""
    out = []
    for val in eqn.params.values():
        for item in (val if isinstance(val, (list, tuple)) else [val]):
            if isinstance(item, jax.core.ClosedJaxpr):
                out.append(item.jaxpr)
            elif isinstance(item, jax.core.Jaxpr):
                out.append(item)
            elif hasattr(item, "jaxpr") and isinstance(
                    getattr(item, "jaxpr", None), jax.core.Jaxpr):
                out.append(item.jaxpr)
    return out


def iter_eqns(jaxpr) -> Iterator:
    """Depth-first walk over every equation, recursing into subjaxprs.
    Accepts a Jaxpr or ClosedJaxpr."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub)


def find_eqns(jaxpr, primitive_name: str) -> list:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == primitive_name]


def _frames(eqn):
    tb = getattr(getattr(eqn, "source_info", None), "traceback", None)
    if tb is None:
        return []
    try:
        return list(tb.frames)
    except Exception:
        return []


def eqn_location(eqn) -> str:
    """Innermost repo frame as ``file:line`` (falls back to the innermost
    frame of any kind, or "")."""
    frames = _frames(eqn)
    best = ""
    for fr in frames:
        loc = f"{fr.file_name}:{fr.line_num}"
        if "/repro/" in fr.file_name or "/tests/" in fr.file_name:
            return loc
        if not best:
            best = loc
    return best


def eqn_function_names(eqn) -> list[str]:
    """Function names on the equation's traceback, innermost first —
    the scoping key for declarative allowlists."""
    return [fr.function_name for fr in _frames(eqn)]


def var_dtype(v):
    return getattr(getattr(v, "aval", None), "dtype", None)


def var_shape(v):
    return getattr(getattr(v, "aval", None), "shape", None)


def producer_map(jaxpr) -> dict:
    """var -> producing eqn, one flat (non-recursive) map per jaxpr
    block.  Backward walks (quantize-ancestry) stay within a block:
    values entering a block are parameters/consts there, which is exactly
    the conservative boundary we want."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out[v] = eqn
    return out


def ancestor_prims(var, producers: dict, *, max_depth: int = 12) -> set:
    """Primitive names reachable backward from ``var`` within one jaxpr
    block, up to ``max_depth`` producer hops.  Call-like producers
    (jnp.round/jnp.clip arrive as one-eqn ``pjit[name=round]`` wrappers)
    contribute the primitives INSIDE their subjaxprs too, so a quantizer
    reads as {"pjit", "round", "max", "min", ...} rather than opaque
    "pjit" hops."""
    seen_prims: set = set()
    frontier = [var]
    seen_vars = set()
    for _ in range(max_depth):
        nxt = []
        for v in frontier:
            eqn = producers.get(v)
            if eqn is None:
                continue
            seen_prims.add(eqn.primitive.name)
            for sub in subjaxprs(eqn):
                seen_prims.update(e.primitive.name for e in iter_eqns(sub))
            for iv in eqn.invars:
                if not isinstance(iv, jax.core.Var) or iv in seen_vars:
                    continue
                seen_vars.add(iv)
                nxt.append(iv)
        if not nxt:
            break
        frontier = nxt
    return seen_prims


def blocks(jaxpr) -> Iterator:
    """Every jaxpr block in the graph (the top jaxpr plus each subjaxpr),
    for analyses that need per-block producer maps."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in subjaxprs(eqn):
            yield from blocks(sub)
