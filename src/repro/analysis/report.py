"""The analysis report: one schema for every analyzer's findings.

Every checker in ``repro.analysis`` — and the source lint in
``scripts/repro_lint.py`` — emits :class:`Finding` records; the CI lane
serializes them into one JSON artifact and fails on any ``error``
finding (or on an empty entry-point set, mirroring the property lane's
zero-collection guard).  The schema is validated in-process before the
file is written, so a malformed report is itself a failure, never a
silently-green artifact.

Report schema (version 1)::

    {
      "schema_version": 1,
      "tool": "repro.analysis" | "repro_lint",
      "backend": "cpu" | "tpu" | ...,
      "entry_points": ["prefill", "decode_block", ...],
      "n_entry_points": 7,
      "counts": {"error": 0, "warning": 0},
      "findings": [
        {"analyzer": "dtype_drift", "code": "drift.promote",
         "severity": "error", "entry_point": "prefill",
         "message": "...", "location": "models/attention.py:531"},
        ...
      ]
    }

``validate_report`` is pure structural checking (stdlib only — the CI
lane and the docs lane import it without jax).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Sequence

SCHEMA_VERSION = 1
SEVERITIES = ("error", "warning")
ANALYZER_NAMES = ("dtype_drift", "budgets", "pallas_contracts", "donation",
                  "lint")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer verdict.  ``code`` is the stable machine-readable
    rule id (``drift.promote``, ``budget.retrace``, ...); ``message`` is
    the human explanation with enough context to fix the violation
    without re-running the pass."""
    analyzer: str
    code: str
    message: str
    entry_point: str = ""      # "" for repo-level (lint, budgets-decl)
    location: str = ""         # file:line or jaxpr source hint, best effort
    severity: str = "error"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def make_report(findings: Iterable[Finding], *, tool: str,
                entry_points: Sequence[str] = (),
                backend: str = "") -> dict:
    findings = list(findings)
    counts = {s: 0 for s in SEVERITIES}
    for f in findings:
        counts[f.severity] += 1
    report = {
        "schema_version": SCHEMA_VERSION,
        "tool": tool,
        "backend": backend,
        "entry_points": list(entry_points),
        "n_entry_points": len(entry_points),
        "counts": counts,
        "findings": [f.to_dict() for f in findings],
    }
    errors = validate_report(report)
    if errors:  # a checker bug, not a checked-code bug — fail loudly
        raise ValueError("analysis report failed its own schema: "
                         + "; ".join(errors))
    return report


def validate_report(obj) -> list[str]:
    """Structural schema check; returns [] when valid.  Kept dependency-
    free so CI can validate the artifact without installing anything."""
    errors = []
    if not isinstance(obj, dict):
        return [f"report must be a dict, got {type(obj).__name__}"]
    if obj.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version must be {SCHEMA_VERSION}, got "
                      f"{obj.get('schema_version')!r}")
    if not isinstance(obj.get("tool"), str) or not obj.get("tool"):
        errors.append("tool must be a non-empty string")
    eps = obj.get("entry_points")
    if not isinstance(eps, list) or not all(isinstance(e, str) for e in eps):
        errors.append("entry_points must be a list of strings")
    elif obj.get("n_entry_points") != len(eps):
        errors.append("n_entry_points does not match entry_points length")
    counts = obj.get("counts")
    if (not isinstance(counts, dict)
            or set(counts) != set(SEVERITIES)
            or not all(isinstance(v, int) and v >= 0
                       for v in counts.values())):
        errors.append(f"counts must map exactly {SEVERITIES} to ints >= 0")
    findings = obj.get("findings")
    if not isinstance(findings, list):
        return errors + ["findings must be a list"]
    tally = {s: 0 for s in SEVERITIES}
    for i, f in enumerate(findings):
        if not isinstance(f, dict):
            errors.append(f"findings[{i}] must be a dict")
            continue
        for key in ("analyzer", "code", "message", "entry_point",
                    "location", "severity"):
            if not isinstance(f.get(key), str):
                errors.append(f"findings[{i}].{key} must be a string")
        if f.get("severity") not in SEVERITIES:
            errors.append(f"findings[{i}].severity must be one of "
                          f"{SEVERITIES}, got {f.get('severity')!r}")
        else:
            tally[f["severity"]] += 1
        for key in ("analyzer", "code", "message"):
            if isinstance(f.get(key), str) and not f[key]:
                errors.append(f"findings[{i}].{key} must be non-empty")
    if isinstance(counts, dict) and not errors and tally != counts:
        errors.append(f"counts {counts} do not match findings tally {tally}")
    return errors


def write_report(path: str, report: dict) -> None:
    errors = validate_report(report)
    if errors:
        raise ValueError("refusing to write invalid report: "
                         + "; ".join(errors))
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
