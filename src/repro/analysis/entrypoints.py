"""The serving entry points, traced for static analysis.

The analyzers in this package work on jaxprs; this module owns the one
place that says *which* graphs constitute "the serving surface" and at
what shapes they are traced.  Smoke shapes (B=2, S=32, chunk=8 — the
same grid the tier-1 tests pin) are enough: every invariant the
analyzers check (dtype flow, BlockSpec divisibility, prefetch arity,
freeze state) is shape-generic, so a violation at smoke shapes is the
violation.

Entry points per variant:

- ``prefill``             dense one-shot prefill
- ``chunked_prefill``     the scan-over-chunks ragged-prompt prefill
- ``decode_loop``         single-stream whole-generation scan
- ``decode_block``        continuous-batching slot decode block
- ``resume``              preemption re-admission (chunked prefill at
                          the resume buffer — a distinct trace shape)
- ``speculative_verify``  the windowed verify step of prompt-lookup
                          speculative decoding

``build_entry_points`` assembles a real converted engine (calibrate →
finalize → int8 conversion, exactly ``engine.prepare_int8``) and traces
each entry with ``jax.make_jaxpr`` — tracing only, no compiles, so a
full three-variant sweep stays cheap.  ``run_analysis`` is the CI
driver: every analyzer over every entry point of the default variant
sweep (int8+pallas, int8+jnp, int4+pallas), plus the repo-level source
and budget checks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import budgets as BU
from repro.analysis import donation as DO
from repro.analysis import dtype_drift as DD
from repro.analysis import pallas_contracts as PC
from repro.analysis.report import Finding

# the tier-1 smoke grid (tests/test_scheduler.py pins the same shapes)
B, S, CHUNK, CACHE, GEN = 2, 32, 8, 64, 4


@dataclasses.dataclass
class EntryPoint:
    """One traced serving graph plus the metadata the analyzers need."""
    name: str
    jaxpr: object               # ClosedJaxpr from jax.make_jaxpr
    hidden_dtype: str           # cfg.dtype — the residual-stream dtype
    d_model: int
    kv_bits: int                # 8, or 4 (packed nibbles)
    uses_pallas: bool           # policy routed through the fused kernels
    expect_interpret: bool      # kernels.ops backend selection


def _assemble(arch: str, *, use_pallas: bool, kv_bits: int):
    from repro.configs import get_config
    from repro.core import api as A
    from repro.launch.engine import prepare_int8
    from repro.models import build_model

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True, kv_bits=kv_bits,
                           use_pallas=use_pallas)
    serve_params, qp = prepare_int8(model, cfg, policy, params,
                                    [{"tokens": toks}])
    cache = model.init_cache(B, CACHE, cfg.dtype, kv_int8=True,
                             kv_bits=kv_bits)
    return cfg, model, policy, serve_params, qp, toks, cache


def build_entry_points(arch: str = "smollm-135m", *,
                       use_pallas: bool = True, kv_bits: int = 8,
                       mode: str = "int8",
                       include: Optional[Sequence[str]] = None,
                       ) -> list[EntryPoint]:
    """Trace the serving surface of one engine variant.  ``include``
    restricts to a subset of entry names (None = all)."""
    from repro.kernels import ops
    from repro.launch import steps as ST
    from repro.launch import strategies as STR

    cfg, model, policy, serve_params, qp, toks, cache = _assemble(
        arch, use_pallas=use_pallas, kv_bits=kv_bits)
    expect_interpret = ops._interpret()
    meta = dict(hidden_dtype=str(cfg.dtype), d_model=cfg.d_model,
                kv_bits=kv_bits, uses_pallas=use_pallas,
                expect_interpret=expect_interpret)

    lengths = jnp.asarray([S, S - CHUNK], jnp.int32)
    tok0 = jnp.zeros((B,), jnp.int32)
    pos0 = jnp.full((B,), S, jnp.int32)
    active0 = jnp.ones((B,), bool)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": toks}

    def trace(fn, *args):
        return jax.make_jaxpr(fn)(*args)

    builders = {
        "prefill": lambda: trace(
            ST.make_prefill_step(model, cfg, policy, mode),
            serve_params, qp, batch, cache),
        "chunked_prefill": lambda: trace(
            ST.make_prefill_step(model, cfg, policy, mode,
                                 prefill_chunk=CHUNK),
            serve_params, qp, batch, cache, lengths),
        "decode_loop": lambda: trace(
            ST.make_decode_loop(model, cfg, policy, mode, n_steps=GEN),
            serve_params, qp, tok0, cache, jnp.int32(S)),
        "decode_block": lambda: trace(
            ST.make_slot_decode_loop(model, cfg, policy, mode, n_steps=3),
            serve_params, qp, tok0, cache, pos0, active0, key),
        # preemption re-admission: the same chunked-prefill maker traced
        # at the resume buffer (prompt + generated so far, re-padded) —
        # the scheduler's 'resume' piece, a genuinely distinct shape
        "resume": lambda: trace(
            ST.make_prefill_step(model, cfg, policy, mode,
                                 prefill_chunk=CHUNK),
            serve_params, qp,
            {"tokens": jnp.zeros((B, S + CHUNK), jnp.int32)}, cache,
            jnp.asarray([S + GEN, S - 1], jnp.int32)),
        "speculative_verify": lambda: trace(
            STR.SpeculativeStrategy(model, cfg, policy, mode).verify,
            serve_params, qp, tok0, jnp.zeros((B, 4), jnp.int32),
            cache, pos0, active0),
    }
    out = []
    for name, build in builders.items():
        if include is not None and name not in include:
            continue
        out.append(EntryPoint(name=name, jaxpr=build(), **meta))
    return out


def build_sharded_entry_points(arch: str = "smollm-135m", *, tp: int = 2,
                               kv_bits: int = 8, mode: str = "int8",
                               include: Optional[Sequence[str]] = None,
                               ) -> list[EntryPoint]:
    """Trace the serving surface through a tensor-parallel
    :class:`~repro.shard.model.ShardedModel` over a tp-way host-local
    mesh, so the drift checkers see REAL collectives (the row-epilogue
    psums, inside shard_map subjaxprs) instead of an unsharded graph
    where ``drift.collective`` is vacuously green.

    Needs ``jax.device_count() >= tp`` (CI sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``); returns []
    on smaller hosts so the sweep degrades instead of erroring.  The
    smoke config's 3 heads are rounded to a tp-divisible head grid —
    the analyzers are shape-generic, so the exact head count is
    immaterial.
    """
    if jax.device_count() < tp:
        return []
    from repro.configs import get_config
    from repro.core import api as A
    from repro.kernels import ops
    from repro.launch import steps as ST
    from repro.launch.engine import prepare_int8
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.shard.model import ShardedModel

    cfg = get_config(arch, smoke=True)
    cfg = cfg.replace(n_heads=2 * tp, n_kv_heads=tp, head_dim=cfg.head_dim)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True, kv_bits=kv_bits, use_pallas=False)
    serve_params, qp = prepare_int8(model, cfg, policy, params,
                                    [{"tokens": toks}])
    sharded = ShardedModel(model, cfg, make_serving_mesh(tp), tp=tp)
    cache = sharded.init_cache(B, CACHE, cfg.dtype, kv_int8=True,
                               kv_bits=kv_bits)
    meta = dict(hidden_dtype=str(cfg.dtype), d_model=cfg.d_model,
                kv_bits=kv_bits, uses_pallas=False,
                expect_interpret=ops._interpret())
    lengths = jnp.asarray([S, S - CHUNK], jnp.int32)
    tok0 = jnp.zeros((B,), jnp.int32)
    pos0 = jnp.full((B,), S, jnp.int32)
    active0 = jnp.ones((B,), bool)
    batch = {"tokens": toks}

    def trace(fn, *args):
        return jax.make_jaxpr(fn)(*args)

    builders = {
        "sharded_prefill": lambda: trace(
            ST.make_prefill_step(sharded, cfg, policy, mode),
            serve_params, qp, batch, cache),
        "sharded_chunked_prefill": lambda: trace(
            ST.make_prefill_step(sharded, cfg, policy, mode,
                                 prefill_chunk=CHUNK),
            serve_params, qp, batch, cache, lengths),
        "sharded_decode_block": lambda: trace(
            ST.make_slot_decode_loop(sharded, cfg, policy, mode, n_steps=3),
            serve_params, qp, tok0, cache, pos0, active0,
            jax.random.PRNGKey(0)),
    }
    out = []
    for name, build in builders.items():
        if include is not None and name not in include:
            continue
        out.append(EntryPoint(name=name, jaxpr=build(), **meta))
    return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def analyze_entry_points(eps: Sequence[EntryPoint]) -> list[Finding]:
    """All jaxpr-level analyzers over each traced entry point."""
    findings: list[Finding] = []
    for ep in eps:
        findings += DD.check_dtype_drift(ep.jaxpr, entry_point=ep.name)
        findings += PC.check_pallas_jaxpr(
            ep.jaxpr, entry_point=ep.name,
            expect_interpret=ep.expect_interpret)
        findings += DO.check_no_fake_quant(ep.jaxpr, entry_point=ep.name)
    return findings


def _scheduler_session_findings(arch: str) -> list[Finding]:
    """Run a real mixed-admission scheduler session at smoke shapes and
    hold its executable counts to the declared budgets; then re-run the
    same traffic and require ZERO fresh backend compiles (the warm-path
    compile budget)."""
    import numpy as np

    from repro.configs import get_config
    from repro.core import api as A
    from repro.launch import steps as ST
    from repro.launch.scheduler import Request, SlotScheduler
    from repro.models import build_model

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True)
    qp = A.init_qparams(model, params, policy)
    qp = ST.make_calibrate_step(model, cfg, policy)(params, qp,
                                                    {"tokens": toks})
    qp = A.finalize_calibration(qp, policy)

    def reqs():
        # ragged lengths on purpose: the no-retrace contract is exactly
        # that these are data, not trace keys
        return [Request(rid=r, tokens=np.asarray(toks[r % B, :n]),
                        max_gen=GEN) for r, n in enumerate([S, S - 12, 9])]

    sched = SlotScheduler(model, cfg, policy, params, qp, mode="none",
                          max_slots=2, prompt_cap=S, gen_cap=GEN + 2,
                          prefill_chunk=CHUNK, block_steps=3)
    list(sched.run(reqs()))
    findings = BU.check_executable_budgets(sched.executable_counts(),
                                           entry_point="scheduler_session")
    with BU.CompileWatch() as w:
        list(sched.run(reqs()))
    findings += w.check(max_compiles=0,
                        what="repeat of an identical scheduler session",
                        entry_point="scheduler_session")
    findings += BU.check_executable_budgets(sched.executable_counts(),
                                            entry_point="scheduler_session")
    return findings


def _sharded_scheduler_session_findings(arch: str, *,
                                        tp: int = 2) -> list[Finding]:
    """The scheduler-budget session over a tp-way ShardedModel: the
    no-retrace contract must survive sharding (shard_map wrapping is
    per-trace, so a per-shard retrace would show up as count > 1).
    Counts report under ``sharded_*`` keys so the budget table keeps
    the sharded executables as first-class declared pieces."""
    import numpy as np

    from repro.configs import get_config
    from repro.core import api as A
    from repro.launch.engine import prepare_int8
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.scheduler import Request, SlotScheduler
    from repro.models import build_model
    from repro.shard.model import ShardedModel

    cfg = get_config(arch, smoke=True)
    cfg = cfg.replace(n_heads=2 * tp, n_kv_heads=tp, head_dim=cfg.head_dim)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True, use_pallas=False)
    serve_params, qp = prepare_int8(model, cfg, policy, params,
                                    [{"tokens": toks}])
    sharded = ShardedModel(model, cfg, make_serving_mesh(tp), tp=tp)

    def reqs():
        return [Request(rid=r, tokens=np.asarray(toks[r % B, :n]),
                        max_gen=GEN) for r, n in enumerate([S, S - 12, 9])]

    sched = SlotScheduler(sharded, cfg, policy, serve_params, qp,
                          mode="int8", max_slots=2, prompt_cap=S,
                          gen_cap=GEN + 2, prefill_chunk=CHUNK,
                          block_steps=3)
    list(sched.run(reqs()))

    def prefixed():
        return {f"sharded_{k}": v
                for k, v in sched.executable_counts().items()}

    findings = BU.check_executable_budgets(
        prefixed(), entry_point="sharded_scheduler_session")
    with BU.CompileWatch() as w:
        list(sched.run(reqs()))
    findings += w.check(max_compiles=0,
                        what="repeat of an identical sharded scheduler "
                             "session",
                        entry_point="sharded_scheduler_session")
    findings += BU.check_executable_budgets(
        prefixed(), entry_point="sharded_scheduler_session")
    return findings


def run_analysis(arch: str = "smollm-135m", *,
                 with_scheduler: bool = True) -> tuple[list[Finding],
                                                       list[str]]:
    """The full CI sweep.  Returns (findings, entry point names)."""
    variants = (
        dict(use_pallas=True, kv_bits=8),
        dict(use_pallas=False, kv_bits=8),   # the jnp fallback path
        dict(use_pallas=True, kv_bits=4),    # packed-nibble KV
    )
    findings: list[Finding] = []
    names: list[str] = []
    for v in variants:
        eps = build_entry_points(arch, **v)
        tag = f"pallas={v['use_pallas']},kv{v['kv_bits']}"
        names += [f"{ep.name}[{tag}]" for ep in eps]
        findings += analyze_entry_points(eps)

    # sharded surface: real collectives under shard_map (empty on
    # single-device hosts — the sharded CI lane sets XLA_FLAGS)
    sharded_eps = build_sharded_entry_points(arch)
    names += [f"{ep.name}[tp2]" for ep in sharded_eps]
    findings += analyze_entry_points(sharded_eps)

    # repo-level: kernel source contracts + freeze state of a converted
    # engine + donated-cache aliasing
    findings += PC.check_kernel_sources()
    cfg, model, policy, serve_params, qp, toks, cache = _assemble(
        "smollm-135m", use_pallas=True, kv_bits=8)
    findings += DO.check_frozen_qparams(qp, entry_point="served_qparams")
    findings += DO.check_duplicate_donation(cache, entry_point="cache",
                                            what="donated KV cache")
    names += ["served_qparams", "cache"]

    if with_scheduler:
        findings += _scheduler_session_findings(arch)
        names += ["scheduler_session"]
        if jax.device_count() >= 2:
            findings += _sharded_scheduler_session_findings(arch)
            names += ["sharded_scheduler_session"]
    return findings, names
