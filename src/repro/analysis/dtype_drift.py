"""Dtype-drift checker: no f32 leaks into the quantized serving graph.

The engine's numerics contract (docs/serving.md) is that the serving
graph computes in the model dtype (bf16) with *explicitly bounded* f32
islands — softmax statistics, dequant scales, the optimizer — and that
every int8/int4 value is produced by a real quantizer (round + clip
against a calibrated threshold), never by a bare cast.  PR 4 shipped a
violation of the first rule (the quantized jnp attention path returned
f32 into the bf16 residual stream, breaking layer-scanned stacks), and
the fix was a one-line cast that nothing machine-checked.  This pass
makes that class of bug a CI failure:

``drift.promote``
    A binary elementwise op whose output is a *wider* float than one of
    its float operands — the signature of an implicit promotion (bf16
    residual + f32 attention output -> f32 residual).  Entering f32 via
    an explicit ``convert_element_type`` is sanctioned (that is how the
    softmax/scale islands are written); silently widening through
    arithmetic is drift.

``drift.raw-int-cast``
    A float -> int8 ``convert_element_type`` with no ``round`` in its
    ancestry: a value entered the quantized domain without passing
    through a quantizer.  The sanctioned pattern is
    ``clip(round(x / scale)).astype(int8)``.

``drift.collective``
    A cross-replica collective moving floating-point payload.  The
    engine's sharding story (dist/collectives.py) is that the
    interconnect moves *quantized* bytes: int8 payloads, int32
    accumulators.  A raw f32 psum is the regression this catches; the
    one sanctioned float collective — the single-scalar shared-threshold
    ``pmax`` of ``compressed_psum`` — is a declarative
    :class:`AllowRule`, so the analyzer documents the contract instead
    of special-casing the module.

Allowlist rules match on (finding code, producing primitive, traceback
function scope, element count) — declarative and reviewable, with the
"why" carried in the rule itself.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.analysis.jaxprs import (ancestor_prims, blocks, eqn_function_names,
                                   eqn_location, producer_map, var_dtype,
                                   var_shape)
from repro.analysis.report import Finding

# elementwise binary primitives where implicit promotion can smuggle a
# wide dtype into a narrow stream
_BINARY_ELEMENTWISE = ("add", "sub", "mul", "div", "max", "min", "rem",
                       "pow", "atan2")
_COLLECTIVES = ("psum", "pmax", "pmin", "ppermute", "all_gather",
                "all_to_all", "psum_scatter")
_FLOAT_WIDTH = {"bfloat16": 1, "float16": 1, "float32": 2, "float64": 3}
# quantizer evidence: some rounding op must sit upstream of an int8 cast
_ROUND_PRIMS = {"round", "round_nearest_even", "nearbyint"}


@dataclasses.dataclass(frozen=True)
class AllowRule:
    """One declarative exemption.  ``scope`` substring-matches any
    function name on the equation's traceback; ``primitive`` pins the
    producing op; ``max_elems`` bounds the value size (a one-scalar
    exemption cannot silently grow into a tensor-sized hole).  ``note``
    is the documented contract — it is surfaced in reports, so an
    allowlist entry IS documentation."""
    code: str
    note: str
    primitive: Optional[str] = None
    scope: Optional[str] = None
    max_elems: Optional[int] = None

    def matches(self, code: str, eqn, n_elems: int) -> bool:
        if code != self.code:
            return False
        if self.primitive is not None and eqn.primitive.name != self.primitive:
            return False
        if self.max_elems is not None and n_elems > self.max_elems:
            return False
        if self.scope is not None:
            names = eqn_function_names(eqn)
            if not any(self.scope in n for n in names):
                return False
        return True


DEFAULT_ALLOWLIST: tuple[AllowRule, ...] = (
    # dist/collectives.py::compressed_psum — the int8-compressed gradient
    # reduction shares ONE max-abs threshold across participants via an
    # f32 scalar pmax (paper eq. 2 applied to the wire).  The payload
    # psum itself is int32 (integer accumulator contract, stated in the
    # module) and passes the float-collective check by construction.
    AllowRule(
        code="drift.collective", primitive="pmax",
        scope="compressed_psum", max_elems=1,
        note="compressed_psum shared-scale scalar: one f32 pmax "
             "establishes the common int8 threshold; payload bytes stay "
             "int8/int32 (dist/collectives.py contract)"),
    # repro.shard.partial_softmax::sp_partial_combine — sequence-parallel
    # flash-decode merges per-shard (m, l, acc) partials with f32
    # all_gathers.  These are GATHERS, not reductions (the HLO-level
    # integer-all-reduce assertion is untouched), and the payload is the
    # per-token partial state — KV*G*(D+2) floats per slot, orders of
    # magnitude below the S-sized K/V stream sharding avoids moving.
    AllowRule(
        code="drift.collective", primitive="all_gather",
        scope="sp_partial_combine",
        note="sequence-parallel partial-softmax merge: f32 (m, l, acc) "
             "flash partials gather across shards; exactness is the "
             "online-softmax identity (repro.shard.partial_softmax)"),
    # Sanctioned f32 islands for implicit promotion, scoped to the code
    # that owns them.  Softmax statistics and the Adam moment math are
    # *written* with explicit converts today (so these rules are
    # currently dormant), but the islands are part of the documented
    # numerics contract — keeping them declarative here means a future
    # refactor that leans on promotion inside these scopes changes an
    # allowlist line, not the analyzer.
    AllowRule(code="drift.promote", scope="softmax",
              note="softmax statistics island: max/exp/normalize runs f32 "
                   "regardless of stream dtype"),
    AllowRule(code="drift.promote", scope="adam_update",
              note="optimizer island: moments and updates are f32 over "
                   "bf16 params by design"),
)


def _numel(shape) -> int:
    return math.prod(shape) if shape else 1


def _allowed(allowlist: Sequence[AllowRule], code: str, eqn,
             n_elems: int) -> bool:
    return any(r.matches(code, eqn, n_elems) for r in allowlist)


def check_dtype_drift(jaxpr, *, entry_point: str = "",
                      allowlist: Sequence[AllowRule] = DEFAULT_ALLOWLIST,
                      ) -> list[Finding]:
    """Run all three drift checks over every block of ``jaxpr`` (a
    ClosedJaxpr/Jaxpr, typically from ``jax.make_jaxpr`` of a serving
    entry point)."""
    findings: list[Finding] = []
    for block in blocks(jaxpr):
        producers = None  # built lazily, only when an int8 cast appears
        for eqn in block.eqns:
            name = eqn.primitive.name
            if name in _BINARY_ELEMENTWISE:
                out = eqn.outvars[0]
                out_dt = var_dtype(out)
                out_w = _FLOAT_WIDTH.get(str(out_dt))
                if out_w is None:
                    continue
                narrow = [str(var_dtype(v)) for v in eqn.invars
                          if _FLOAT_WIDTH.get(str(var_dtype(v)), out_w)
                          < out_w]
                if not narrow:
                    continue
                n = _numel(var_shape(out))
                if _allowed(allowlist, "drift.promote", eqn, n):
                    continue
                findings.append(Finding(
                    analyzer="dtype_drift", code="drift.promote",
                    entry_point=entry_point, location=eqn_location(eqn),
                    message=f"'{name}' implicitly promotes {narrow[0]} to "
                            f"{out_dt} (shape {var_shape(out)}): a wide "
                            "value entered the narrow stream through "
                            "arithmetic instead of an explicit convert — "
                            "cast the wide operand back to the stream "
                            "dtype (the PR-4 residual leak pattern)"))
            elif name == "convert_element_type":
                new_dt = eqn.params.get("new_dtype")
                if new_dt not in (jnp.int8, jnp.uint8):
                    continue
                src = eqn.invars[0]
                src_dt = str(var_dtype(src))
                if src_dt not in _FLOAT_WIDTH:
                    continue  # int->int repacks are not quantization
                if producers is None:
                    producers = producer_map(block)
                if _ROUND_PRIMS & ancestor_prims(src, producers):
                    continue
                n = _numel(var_shape(src))
                if _allowed(allowlist, "drift.raw-int-cast", eqn, n):
                    continue
                findings.append(Finding(
                    analyzer="dtype_drift", code="drift.raw-int-cast",
                    entry_point=entry_point, location=eqn_location(eqn),
                    message=f"{src_dt} value (shape {var_shape(src)}) cast "
                            "straight to int8 with no round() upstream: "
                            "values enter the quantized domain only "
                            "through a quantizer "
                            "(clip(round(x/scale)).astype(int8))"))
            elif name in _COLLECTIVES:
                for v in eqn.invars:
                    dt = str(var_dtype(v))
                    if dt not in _FLOAT_WIDTH:
                        continue
                    n = _numel(var_shape(v))
                    if _allowed(allowlist, "drift.collective", eqn, n):
                        continue
                    findings.append(Finding(
                        analyzer="dtype_drift", code="drift.collective",
                        entry_point=entry_point,
                        location=eqn_location(eqn),
                        message=f"collective '{name}' moves {dt} payload "
                                f"(shape {var_shape(v)}, {n} elems): the "
                                "interconnect contract is quantized bytes "
                                "— compress the payload "
                                "(dist/collectives.py::compressed_psum) "
                                "or add a scoped AllowRule stating why "
                                "this collective must stay float"))
                    break  # one finding per collective eqn
    return findings
