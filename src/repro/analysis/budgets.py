"""Compile-budget / retrace detector.

The scheduler's no-retrace contract has been prose (scheduler docstrings,
docs/serving.md) and per-test assertions since PR 3; this module turns
it into one declarative table plus two enforcement surfaces:

**Declared budgets** (``SCHEDULER_BUDGETS``) — every jitted scheduler
piece with the (min, max) number of traced variants it may accumulate
over an arbitrary serving session.  The steady-state pieces pin to
exactly 1 (shapes are static by construction: block-table rows, masks,
nan-step vectors are all *data*); ``resume`` and the paged-layout pieces
are 0-or-1 because they trace lazily on first use.
``check_executable_budgets`` diffs a live ``executable_counts()`` dict
against the table — over budget is a retrace (a shape or python value
leaked into trace inputs), under budget means a piece never ran, and a
piece missing from the table is itself a finding: new jitted pieces must
declare a budget to ship.

**CompileWatch** — counts real XLA compiles via the
``/jax/core/compile/backend_compile_duration`` monitoring event (fires
once per backend compile, not per cache hit).  JAX has no listener
*unregistration* API, so one module-level listener registers on first
use and stays; each ``CompileWatch`` reads counter snapshots.  This
catches what trace counters cannot: cache-key churn below the trace
layer (new avals from weak types, donation-signature drift).
"""
from __future__ import annotations

import threading
from typing import Mapping, Optional

from repro.analysis.report import Finding

# piece -> (min, max) traced-variant budget across one scheduler lifetime
SCHEDULER_BUDGETS: dict = {
    "prefill": (1, 1),
    "decode": (1, 1),
    "insert": (1, 1),
    "resume": (0, 1),      # traces on first preemption re-admission
    "set_row": (0, 1),     # paged layout only
    "copy_page": (0, 1),   # paged layout only
    # the same pieces over a ShardedModel (analysis.entrypoints reports
    # them under a sharded_ prefix): shard_map wraps per trace, so a
    # per-SHARD retrace — specs or mesh leaking into trace keys — would
    # blow these exactly like a shape leak blows the unsharded ones
    "sharded_prefill": (1, 1),
    "sharded_decode": (1, 1),
    "sharded_insert": (1, 1),
    "sharded_resume": (0, 1),
    "sharded_set_row": (0, 1),
    "sharded_copy_page": (0, 1),
}


def check_executable_budgets(counts: Mapping[str, int],
                             budgets: Optional[Mapping] = None, *,
                             entry_point: str = "",
                             require_all_ran: bool = False) -> list[Finding]:
    """Diff live ``SlotScheduler.executable_counts()`` against declared
    budgets.  With ``require_all_ran`` each piece must also have traced
    at least its declared minimum (use after a session that exercised
    everything; leave off for partial sessions)."""
    if budgets is None:
        budgets = SCHEDULER_BUDGETS
    findings: list[Finding] = []
    for piece, n in sorted(counts.items()):
        if piece not in budgets:
            findings.append(Finding(
                analyzer="budgets", code="budget.undeclared",
                entry_point=entry_point,
                message=f"jitted piece '{piece}' has no declared budget in "
                        "analysis.budgets.SCHEDULER_BUDGETS — new pieces "
                        "declare their traced-variant budget to ship"))
            continue
        lo, hi = budgets[piece]
        if n > hi:
            findings.append(Finding(
                analyzer="budgets", code="budget.retrace",
                entry_point=entry_point,
                message=f"'{piece}' traced {n}x against a budget of "
                        f"{hi}: a shape or python value is leaking into "
                        "its trace inputs (the no-retrace contract says "
                        "admission patterns, masks and fault plans are "
                        "data, never trace keys)"))
        elif require_all_ran and n < lo:
            findings.append(Finding(
                analyzer="budgets", code="budget.never-traced",
                entry_point=entry_point,
                message=f"'{piece}' traced {n}x but its budget floor is "
                        f"{lo}: the session claimed to exercise it and "
                        "it never compiled"))
    return findings


# ---------------------------------------------------------------------------
# real-compile counting
# ---------------------------------------------------------------------------

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_lock = threading.Lock()
_compiles = 0
_registered = False


def _on_event(event: str, duration: float, **kw) -> None:
    global _compiles
    if _COMPILE_EVENT in event:
        with _lock:
            _compiles += 1


def _ensure_listener() -> None:
    """Register the module-level listener exactly once.  jax.monitoring
    has no unregister-one API (only clear-all), so the listener is
    permanent and watchers read counter snapshots."""
    global _registered
    with _lock:
        if _registered:
            return
        import jax.monitoring
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _registered = True


def compile_count() -> int:
    """Total backend compiles observed since the listener registered."""
    _ensure_listener()
    with _lock:
        return _compiles


class CompileWatch:
    """Context manager counting real XLA compiles in its scope::

        with CompileWatch() as w:
            scheduler.step(...)
        findings = w.check(max_compiles=0, what="steady-state decode")

    ``w.count`` is the number of backend compiles that happened inside
    the block — 0 on a warm path, one per executable on a cold one.
    """

    def __init__(self):
        self._start = 0
        self.count = 0

    def __enter__(self):
        _ensure_listener()
        self._start = compile_count()
        return self

    def __exit__(self, *exc):
        self.count = compile_count() - self._start
        return False

    def check(self, *, max_compiles: int, what: str,
              entry_point: str = "") -> list[Finding]:
        if self.count <= max_compiles:
            return []
        return [Finding(
            analyzer="budgets", code="budget.compile",
            entry_point=entry_point,
            message=f"{what}: {self.count} backend compile(s) against a "
                    f"budget of {max_compiles} — compilation happened "
                    "below the trace layer (cache-key churn: weak types, "
                    "donation drift, or a cold path on what should be a "
                    "warm one)")]
