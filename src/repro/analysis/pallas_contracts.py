"""Pallas kernel contract checker.

Two passes over the kernel layer, both static:

**Jaxpr pass** (``check_pallas_jaxpr``) — walks every ``pallas_call``
equation in a traced serving entry point and validates the launch
contract the kernels document in their module docstrings:

``pallas.block-divide``
    Every BlockSpec block shape must divide its operand shape exactly.
    The kernels pick grid-friendly shapes upstream (128-tiled cache
    lengths, chunk multiples) precisely so no pallas-inserted padding
    lands on the hot path — a non-dividing block means a silent copy or
    masked garbage per grid step.

``pallas.prefetch-arity``
    Each index map must take ``len(grid) + num_scalar_prefetch``
    arguments.  The scalar-prefetch operands (block tables, q_start,
    kv_len) ride in SMEM and are appended to every index map's
    signature; an arity mismatch means a table is being ignored or
    misread.

``pallas.int4-packing``
    The ``dp = D/2`` invariant: an int8-dtype KV pool operand must be
    exactly as wide as the float query head dim (kv_bits=8) or exactly
    half (packed int4 nibbles) — any other ratio means the packing and
    the BlockSpec disagree about where bytes live.

``pallas.interpret``
    Every ``pallas_call`` must carry the interpret flag the entry point
    expects (True on this CPU container, False on TPU) — a kernel that
    hardcodes it works in exactly one environment.

``pallas.kernel-closure``
    The kernel jaxpr must be closed (no constvars): a constvar means the
    kernel body captured a traced array from the enclosing scope instead
    of taking it as a Ref — it would be baked into the executable as a
    constant, pinning stale data across calls.

**Source pass** (``check_kernel_sources``) — AST over
``repro.kernels.PALLAS_MODULES``:

``pallas.interpret-threading``
    Every function containing a ``pallas_call`` must expose an
    ``interpret`` parameter and pass ``interpret=`` through — the knob
    must thread end-to-end from ops.py to the launch.

``pallas.static-capture``
    Kernel-body partial bindings and index maps may reference the
    builder's *static* parameters (static_argnames) and shape-derived
    locals, never its array parameters — a direct array-parameter
    reference is tracer capture in the making.

``pallas.module-registry``
    Every module under ``src/repro/kernels`` that calls ``pallas_call``
    must be listed in ``PALLAS_MODULES`` (coverage guard: a kernel
    cannot opt out of contract checking by not being enumerated).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Optional, Sequence

from repro.analysis.jaxprs import eqn_location, iter_eqns
from repro.analysis.report import Finding

_INT_POOL_DTYPES = ("int8", "uint8")


# ---------------------------------------------------------------------------
# jaxpr pass
# ---------------------------------------------------------------------------

def _block_mapping_findings(eqn, gm, entry_point: str) -> list[Finding]:
    out: list[Finding] = []
    loc = eqn_location(eqn)
    want_arity = len(gm.grid) + gm.num_index_operands
    for bi, bm in enumerate(gm.block_mappings):
        arr = bm.array_shape_dtype
        block = bm.block_shape
        for dim, (b, a) in enumerate(zip(block, arr.shape)):
            if b is None or not isinstance(b, int):
                continue  # squeezed / element-indexed dims
            if b <= 0 or a % b:
                out.append(Finding(
                    analyzer="pallas_contracts", code="pallas.block-divide",
                    entry_point=entry_point, location=loc,
                    message=f"pallas_call operand {bi}: block shape "
                            f"{tuple(block)} does not divide operand shape "
                            f"{tuple(arr.shape)} at dim {dim} ({b} does not "
                            f"divide {a}) — pallas would pad/mask this "
                            "operand per grid step"))
                break
        arity = len(bm.index_map_jaxpr.jaxpr.invars)
        if arity != want_arity:
            out.append(Finding(
                analyzer="pallas_contracts", code="pallas.prefetch-arity",
                entry_point=entry_point, location=loc,
                message=f"pallas_call operand {bi}: index map takes {arity} "
                        f"args but the launch has {len(gm.grid)} grid axes "
                        f"+ {gm.num_index_operands} scalar-prefetch "
                        f"operands = {want_arity} — a prefetch table is "
                        "being dropped or misindexed"))
    return out


def _packing_findings(eqn, gm, entry_point: str) -> list[Finding]:
    """dp = D / (8 / kv_bits): int pools must be exactly D or D/2 wide."""
    in_maps = gm.block_mappings[:gm.num_inputs]
    q_widths = [bm.array_shape_dtype.shape[-1] for bm in in_maps
                if len(bm.array_shape_dtype.shape) == 4
                and str(bm.array_shape_dtype.dtype) not in _INT_POOL_DTYPES
                and "float" in str(bm.array_shape_dtype.dtype)
                or len(bm.array_shape_dtype.shape) == 4
                and str(bm.array_shape_dtype.dtype) == "bfloat16"]
    pools = [bm for bm in in_maps
             if len(bm.array_shape_dtype.shape) == 4
             and str(bm.array_shape_dtype.dtype) in _INT_POOL_DTYPES]
    if not q_widths or not pools:
        return []
    d = max(q_widths)  # the query head dim (logical width)
    out = []
    for bm in pools:
        dp = bm.array_shape_dtype.shape[-1]
        if dp not in (d, d // 2 if d % 2 == 0 else -1):
            out.append(Finding(
                analyzer="pallas_contracts", code="pallas.int4-packing",
                entry_point=entry_point, location=eqn_location(eqn),
                message=f"KV pool operand is {dp} bytes wide against a "
                        f"query head dim of {d}: storage width must be D "
                        f"(int8) or D/2 (packed int4 nibbles) — "
                        "pack/BlockSpec disagreement"))
    return out


def check_pallas_jaxpr(jaxpr, *, entry_point: str = "",
                       expect_interpret: Optional[bool] = None,
                       ) -> list[Finding]:
    """Validate every pallas_call equation reachable from ``jaxpr``."""
    findings: list[Finding] = []
    n_calls = 0
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        n_calls += 1
        gm = eqn.params["grid_mapping"]
        findings += _block_mapping_findings(eqn, gm, entry_point)
        findings += _packing_findings(eqn, gm, entry_point)
        if (expect_interpret is not None
                and bool(eqn.params.get("interpret")) != expect_interpret):
            findings.append(Finding(
                analyzer="pallas_contracts", code="pallas.interpret",
                entry_point=entry_point, location=eqn_location(eqn),
                message=f"pallas_call has interpret="
                        f"{bool(eqn.params.get('interpret'))} but this "
                        f"entry point expects {expect_interpret} (backend-"
                        "selected via kernels.ops) — the flag is not "
                        "threaded end-to-end"))
        kernel_jaxpr = eqn.params.get("jaxpr")
        consts = getattr(kernel_jaxpr, "constvars", ())
        if consts:
            findings.append(Finding(
                analyzer="pallas_contracts", code="pallas.kernel-closure",
                entry_point=entry_point, location=eqn_location(eqn),
                message=f"kernel jaxpr captured {len(consts)} constant(s) "
                        "from the enclosing scope "
                        f"({[str(getattr(c, 'aval', c)) for c in consts]}): "
                        "kernel bodies read arrays through Refs only — a "
                        "captured tracer bakes stale data into the "
                        "executable"))
    return findings


# ---------------------------------------------------------------------------
# source pass
# ---------------------------------------------------------------------------

def _static_argnames(fn: ast.FunctionDef) -> Optional[set]:
    """static_argnames from a @functools.partial(jax.jit, ...) decorator;
    None when the function is not jit-decorated that way."""
    for dec in fn.decorator_list:
        if not (isinstance(dec, ast.Call) and isinstance(dec.func,
                                                         ast.Attribute)
                and dec.func.attr == "partial"):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames" and isinstance(
                    kw.value, (ast.Tuple, ast.List)):
                return {c.value for c in kw.value.elts
                        if isinstance(c, ast.Constant)}
    return None


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _contains_pallas_call(node) -> list[ast.Call]:
    calls = []
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and ((isinstance(sub.func, ast.Attribute)
                      and sub.func.attr == "pallas_call")
                     or (isinstance(sub.func, ast.Name)
                         and sub.func.id == "pallas_call"))):
            calls.append(sub)
    return calls


def _loaded_names(node) -> set:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _check_builder(fn: ast.FunctionDef, path: str) -> list[Finding]:
    findings: list[Finding] = []
    calls = _contains_pallas_call(fn)
    if not calls:
        return findings
    loc = f"{path}:{fn.lineno}"
    params = _param_names(fn)
    if "interpret" not in params:
        findings.append(Finding(
            analyzer="pallas_contracts", code="pallas.interpret-threading",
            location=loc,
            message=f"{fn.name}: contains pallas_call but takes no "
                    "'interpret' parameter — the backend-selection knob "
                    "must thread end-to-end (kernels/ops.py picks it)"))
    for call in calls:
        if not any(kw.arg == "interpret" for kw in call.keywords):
            findings.append(Finding(
                analyzer="pallas_contracts",
                code="pallas.interpret-threading",
                location=f"{path}:{call.lineno}",
                message=f"{fn.name}: pallas_call without an explicit "
                        "interpret= keyword — it would silently default "
                        "to compiled mode on every backend"))
    # static-capture: partial(_kernel, ...) bindings and index-map
    # lambdas/defs may reference static params and locals, never the
    # builder's array parameters
    statics = _static_argnames(fn) or set()
    array_params = [p for p in params
                    if p not in statics and p != "interpret"]
    suspects: list[tuple[str, int, set]] = []
    for sub in ast.walk(fn):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "partial" and sub.args
                and isinstance(sub.args[0], ast.Name)
                and sub.args[0].id.startswith("_")):  # partial(_kernel, ...)
            used = set()
            for kw in sub.keywords:
                used |= _loaded_names(kw.value)
            suspects.append(("kernel partial binding", sub.lineno, used))
        if isinstance(sub, ast.Lambda):
            suspects.append(("index map", sub.lineno,
                             _loaded_names(sub.body)
                             - {a.arg for a in sub.args.args}))
    for what, lineno, used in suspects:
        captured = sorted(used & set(array_params))
        if captured:
            findings.append(Finding(
                analyzer="pallas_contracts", code="pallas.static-capture",
                location=f"{path}:{lineno}",
                message=f"{fn.name}: {what} references array "
                        f"parameter(s) {captured} — only static args "
                        "(static_argnames) and shape-derived locals may "
                        "be closed over; array data reaches the kernel "
                        "through Refs"))
    return findings


def check_kernel_sources(kernels_dir: Optional[str] = None) -> list[Finding]:
    """AST contract pass over ``repro.kernels.PALLAS_MODULES`` (plus the
    registry coverage guard)."""
    import repro.kernels as K

    if kernels_dir is None:
        kernels_dir = Path(K.__file__).parent
    kernels_dir = Path(kernels_dir)
    findings: list[Finding] = []
    listed = set(K.PALLAS_MODULES)
    for py in sorted(kernels_dir.glob("*.py")):
        tree = ast.parse(py.read_text())
        rel = f"kernels/{py.name}"
        has_calls = bool(_contains_pallas_call(tree))
        if has_calls and py.stem not in listed:
            findings.append(Finding(
                analyzer="pallas_contracts", code="pallas.module-registry",
                location=rel,
                message=f"{py.stem} contains pallas_call but is not in "
                        "repro.kernels.PALLAS_MODULES — register it so "
                        "the contract checker covers it"))
        if py.stem not in listed:
            continue
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                findings += _check_builder(node, rel)
    return findings


def check_source_text(src: str, path: str = "<fixture>") -> list[Finding]:
    """Run the source pass over one module's text (test fixtures)."""
    tree = ast.parse(src)
    findings = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            findings += _check_builder(node, path)
    return findings
