"""Donation/aliasing and freeze-contract checker.

Two families of invariants, both about buffers the serving loop is
allowed to destroy or must never touch:

**Donation** — ``generate_batch`` and the slot scheduler donate the KV
cache into every step (``donate_argnums``), which is only sound if each
donated buffer appears exactly once in the donated pytree.  A duplicated
buffer (two pytree leaves backed by the same device allocation — easy to
create with ``tree_map(lambda x: x, ...)`` shortcuts or a cache layout
that shares a pool across views) means XLA either refuses the aliasing
or, worse, one leaf reads the other's overwritten bytes.

``donate.duplicate-buffer``
    Two or more leaves of a donated pytree share a device buffer.

**Freeze (TQT contract)** — after ``core.api.freeze_thresholds`` the
serving qparams are static: no trained ``log2_t`` leaves survive, the
trainable mask is all-False, and the serving graph contains no
``fake_quant`` custom_vjp applications (fake-quant is the QAT training
construct; serving quantizes for real).  A violated freeze means the
engine is silently carrying — and potentially updating or donating —
parameters that training still claims.

``freeze.log2_t-leaf``
    A ``log2_t`` leaf is reachable in the serving qparams.

``freeze.trainable-mask``
    ``core.api.trainable_mask`` marks some serving-qparams leaf
    trainable.

``freeze.fake-quant-eqn``
    The traced serving graph applies a fake-quant custom_vjp.
"""
from __future__ import annotations

from collections import defaultdict

import jax

from repro.analysis.jaxprs import eqn_function_names, eqn_location, iter_eqns
from repro.analysis.report import Finding


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _buffer_id(leaf):
    """A stable identity for the device allocation behind a leaf; None
    for non-array leaves (python scalars in a config pytree)."""
    try:
        return leaf.unsafe_buffer_pointer()
    except Exception:
        return None


def check_duplicate_donation(tree, *, entry_point: str = "",
                             what: str = "donated pytree") -> list[Finding]:
    """Flag leaves of ``tree`` that share a device buffer.  ``tree`` is
    the pytree that will be donated (e.g. the cache passed under
    ``donate_argnums``)."""
    groups: dict = defaultdict(list)
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        bid = _buffer_id(leaf)
        if bid is not None:
            groups[bid].append(_path_str(path))
    findings = []
    for bid, paths in sorted(groups.items()):
        if len(paths) > 1:
            findings.append(Finding(
                analyzer="donation", code="donate.duplicate-buffer",
                entry_point=entry_point,
                message=f"{what}: leaves {paths} share one device buffer "
                        f"(ptr={bid:#x}) — donating it aliases the same "
                        "allocation to multiple outputs; deep-copy the "
                        "shared leaf or restructure the pytree"))
    return findings


def check_frozen_qparams(qparams, *, entry_point: str = "") -> list[Finding]:
    """The post-``freeze_thresholds`` contract: nothing trainable left."""
    from repro.core import api as A

    findings: list[Finding] = []
    log2_paths = [
        _path_str(path)
        for path, _ in jax.tree_util.tree_flatten_with_path(qparams)[0]
        if "log2_t" in _path_str(path)
    ]
    if log2_paths:
        findings.append(Finding(
            analyzer="donation", code="freeze.log2_t-leaf",
            entry_point=entry_point,
            message=f"serving qparams still carry trained log2_t leaves "
                    f"({log2_paths[:4]}{'...' if len(log2_paths) > 4 else ''})"
                    " — freeze_thresholds was skipped; the engine would "
                    "serve off the raw training parameterization"))
    # scope to KV entries: activation/weight alphas are FAT-trained scale
    # factors that legitimately ride in serving qparams as static data —
    # the freeze contract is about the TQT KV thresholds specifically
    mask = A.trainable_mask(qparams)
    kv_mask = {p: e for p, e in mask.items() if A.is_kv_path(p)}
    live = [
        _path_str(path)
        for path, m in jax.tree_util.tree_flatten_with_path(kv_mask)[0]
        if m
    ]
    if live:
        findings.append(Finding(
            analyzer="donation", code="freeze.trainable-mask",
            entry_point=entry_point,
            message=f"trainable_mask marks {len(live)} KV serving-qparams "
                    f"leaf(s) trainable (e.g. {live[0]}): frozen KV "
                    "thresholds must be invisible to the optimizer"))
    return findings


def check_no_fake_quant(jaxpr, *, entry_point: str = "") -> list[Finding]:
    """No fake-quant custom_vjp applications in a serving graph."""
    findings = []
    for eqn in iter_eqns(jaxpr):
        if not eqn.primitive.name.startswith("custom_vjp_call"):
            continue
        # the custom_vjp eqn itself binds from jax internals, so its own
        # traceback may carry no user frame — the primal jaxpr's eqns were
        # traced inside the decorated function and name it reliably
        names = list(eqn_function_names(eqn))
        inner = eqn.params.get("fun_jaxpr")
        inner_eqns = getattr(getattr(inner, "jaxpr", inner), "eqns", ())
        for ie in list(inner_eqns)[:8]:
            names += eqn_function_names(ie)
        if any("fake_quant" in n for n in names):
            findings.append(Finding(
                analyzer="donation", code="freeze.fake-quant-eqn",
                entry_point=entry_point, location=eqn_location(eqn),
                message="serving graph applies a fake_quant custom_vjp "
                        f"(via {next(n for n in names if 'fake_quant' in n)})"
                        ": fake-quant is the QAT construct — serving "
                        "quantizes for real, so its presence means an "
                        "unfrozen threshold leaked into the hot path"))
    return findings
