"""CI driver: ``python -m repro.analysis [--out report.json]``.

Runs every analyzer over every serving entry point
(analysis.entrypoints.run_analysis), writes the schema-validated JSON
report, prints a summary, and exits:

- 0  clean (entry points traced, zero findings)
- 1  findings (each printed with code, entry point, location)
- 2  zero entry points analyzed — the sweep itself broke; mirrors the
     property lane's zero-collection guard (an empty analysis must never
     read as green)
"""
from __future__ import annotations

import argparse
import os
import sys

# the sharded entry points need a multi-device host; harmless default —
# an explicit XLA_FLAGS (CI lanes, TPU runs) always wins
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402

from repro.analysis.entrypoints import run_analysis
from repro.analysis.report import make_report, write_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract checks over the serving engine's "
                    "traced entry points.")
    ap.add_argument("--out", default="analysis_report.json",
                    help="path for the JSON report artifact")
    ap.add_argument("--arch", default="smollm-135m",
                    help="architecture preset to assemble (smoke shapes)")
    ap.add_argument("--no-scheduler", action="store_true",
                    help="skip the live scheduler budget session (trace-"
                         "only analysis; faster, no compiles)")
    args = ap.parse_args(argv)

    findings, names = run_analysis(args.arch,
                                   with_scheduler=not args.no_scheduler)
    report = make_report(findings, tool="repro.analysis",
                         entry_points=names,
                         backend=jax.default_backend())
    write_report(args.out, report)
    print(f"analyzed {len(names)} entry points "
          f"(backend={report['backend']}); "
          f"{report['counts']['error']} error(s), "
          f"{report['counts']['warning']} warning(s) -> {args.out}")
    for f in findings:
        where = f.entry_point or "repo"
        loc = f" [{f.location}]" if f.location else ""
        print(f"  {f.severity.upper()} {f.code} ({where}){loc}: "
              f"{f.message}")
    if not names:
        print("FATAL: zero entry points analyzed — the sweep is broken, "
              "refusing to report green", file=sys.stderr)
        return 2
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
