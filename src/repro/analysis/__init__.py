"""Static analysis over the serving engine's traced graphs.

The engine accumulated implicit invariants PR by PR — bf16-only residual
streams with bounded f32 islands, one compiled executable per scheduler
piece, Pallas launch contracts (BlockSpec divisibility, scalar-prefetch
arity, the dp=D/2 int4 packing rule, interpret threading), frozen-
threshold serving params, alias-free cache donation.  Each was enforced
by prose plus whichever test happened to trip over a violation.  This
package turns them into machine-checked contracts over jaxprs of every
serving entry point, wired as a first-class CI lane
(``python -m repro.analysis``) that emits one schema-validated JSON
report and fails on any finding.

Modules:

- ``report``           Finding record + report schema (stdlib-only)
- ``jaxprs``           shared recursive jaxpr walk + source attribution
- ``dtype_drift``      f32-leak / raw-cast / float-collective checks
- ``budgets``          retrace budgets + real-compile counting
- ``pallas_contracts`` kernel launch + source contracts
- ``donation``         buffer aliasing + TQT freeze contract
- ``entrypoints``      which graphs constitute the serving surface

The repo-wide AST lint (tracer-hostile python, undocumented flags) lives
in ``scripts/repro_lint.py`` and shares the report schema.
"""
from repro.analysis.budgets import (SCHEDULER_BUDGETS, CompileWatch,
                                    check_executable_budgets, compile_count)
from repro.analysis.donation import (check_duplicate_donation,
                                     check_frozen_qparams,
                                     check_no_fake_quant)
from repro.analysis.dtype_drift import (DEFAULT_ALLOWLIST, AllowRule,
                                        check_dtype_drift)
from repro.analysis.pallas_contracts import (check_kernel_sources,
                                             check_pallas_jaxpr)
from repro.analysis.report import (Finding, make_report, validate_report,
                                   write_report)

__all__ = [
    "AllowRule", "CompileWatch", "DEFAULT_ALLOWLIST", "Finding",
    "SCHEDULER_BUDGETS", "check_dtype_drift", "check_duplicate_donation",
    "check_executable_budgets", "check_frozen_qparams",
    "check_kernel_sources", "check_no_fake_quant", "check_pallas_jaxpr",
    "compile_count", "make_report", "validate_report", "write_report",
]
