"""Checkpoint manager: atomic, keep-N, mesh-agnostic restore.

Fault-tolerance contract (1000-node posture):
  * atomic: temp-dir write + os.replace — a killed writer never corrupts
    the latest checkpoint;
  * keep-N: bounded disk;
  * mesh-agnostic: arrays are saved unsharded-logical (device_get) and
    resharded on load against whatever mesh the restarted job built —
    elastic restarts across different pod counts re-shard for free;
  * the data-pipeline step and RNG state ride along, so the restored run
    consumes the *exact* remaining stream;
  * restore_latest() scans for the newest complete checkpoint, so losing
    the most recent one (half-written at crash) falls back to n-1.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np


# separator must never collide with tree keys: quantization-state keys are
# layer paths that contain "/" themselves
_SEP = "\x1f"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            assert _SEP not in str(k), k
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        keys = path.split(_SEP)
        d = root
        for k in keys[:-1]:
            d = d.setdefault(k, {})
        d[keys[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: dict, metadata: dict | None = None):
        flat = _flatten(tree)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            meta = dict(metadata or {})
            meta["step"] = int(step)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            # completion marker written last inside the temp dir
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            final = os.path.join(self.dir, f"ckpt_{step:010d}")
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        ckpts = self.list_steps()
        for s in ckpts[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def list_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def restore(self, step: int, shardings=None):
        path = os.path.join(self.dir, f"ckpt_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        tree = _unflatten(flat)
        if shardings is not None:
            tree = _shard_tree(tree, shardings)
        return tree, meta

    def restore_latest(self, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None, None
        return self.restore(steps[-1], shardings)


def _shard_tree(tree, shardings):
    """Re-place restored host arrays onto the (possibly different) mesh."""

    def put(x, s):
        if s is None:
            return jax.numpy.asarray(x)
        return jax.device_put(jax.numpy.asarray(x), s)

    return jax.tree.map(put, tree, shardings)
