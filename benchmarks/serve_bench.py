"""Serving-path benchmark: prefill / decode timing across the int8 grid.

Measures steady-state (post-compile) wall time for the serving
configurations of the two-kernel engine:

  * weights: bf16 vs int8
  * KV cache: bf16 vs int8
  * prefill: chunked-jnp flash vs fused Pallas flash-prefill
    (quantize-once int8 attention) vs chunked ragged pipeline
  * decode driver: per-token Python loop vs single lax.scan
  * ragged traffic: mixed-length requests streaming through the
    slot-based continuous-batching scheduler (tokens/s under streaming
    admission — the multi-user serving number)
  * paged prefix reuse: repeated prompts through the PAGED cache layout —
    admissions ride the prefix store's shared pages instead of running
    prefill (the ``paged_prefix_reuse`` entry records hits and skipped
    prefill calls; CI requires it)
  * speculative decoding: repetitive prompts through the prompt-lookup
    ``SpeculativeStrategy`` vs greedy (the ``speculative_decode`` entry
    records draft acceptance rate, tok/s vs greedy, and the
    tokens-match-greedy bit; CI requires it well-formed)
  * degraded traffic: mixed-priority staggered arrivals under a
    deterministic fault plan (launch/faults.py) — one injected fault per
    class, a bounded admission queue, deadlines, and preemption; the
    ``degraded_traffic`` entry records goodput, the per-status census,
    deadline hit rate, and re-admit overhead (CI requires it)
  * crash recovery: a journaled run killed at a decode-block boundary by
    a simulated crash, then replayed via ``SlotScheduler.recover`` on a
    fresh scheduler — the ``crash_recovery`` entry records the cold
    recovery wall time, replayed tokens, and the bit-parity check
    against an uninterrupted run (CI requires it)

Each grid point is one ``Engine`` (launch/engine.py) — the same assembly
the serving CLI runs, so the bench measures the served configuration,
not a reimplementation of it.  The fine-grained pieces (loop-vs-scan,
fused-vs-jnp prefill) are timed on the engine's own step functions.

and writes ``BENCH_serve.json`` so the perf trajectory is tracked across
PRs.  The headline numbers are prefill ms / tokens-per-s per config plus
decode ms/token; the scan/loop ratio is the dispatch-overhead win, the
int8/bf16 and fused/jnp ratios are the bandwidth win (visible on real
HBM-bound hardware; on this CPU container the Pallas numbers run the
interpret lowering, so they track correctness and grid overhead, not the
2x byte reduction).

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--gen 32]
     [--prompt-len 512] [--prefill-chunk 128] [--max-slots 4]
     [--page-size 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.launch.engine import Engine
from repro.launch.serve import ragged_requests


def _bench(fn, *args, iters=2):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _engine(memo, arch, *, int8_weights, kv_int8, calib_batches,
            **engine_kw):
    """One Engine per (weights, kv) config, memoized so the ragged and
    paged scenarios reuse the grid's calibration + conversion instead of
    paying a second end-to-end prepare pass."""
    key = (bool(int8_weights), bool(kv_int8))
    if key not in memo:
        memo[key] = Engine.from_checkpoint(
            arch, smoke=True, fp=not int8_weights, kv_int8=kv_int8,
            use_pallas=False, calib_batches=calib_batches, **engine_kw)
    return memo[key]


def bench_config(engine: Engine, batch, *, requests, prompt_len, gen,
                 prefill_chunk=None):
    model, cfg, policy = engine.model, engine.cfg, engine.policy
    mode = engine.mode
    serve_params, qparams = engine.serve_params, engine.qparams

    prefill = jax.jit(ST.make_prefill_step(model, cfg, policy, mode=mode))
    step = jax.jit(ST.make_serve_step(model, cfg, policy, mode=mode))
    loop = jax.jit(ST.make_decode_loop(model, cfg, policy, mode=mode,
                                       n_steps=gen))
    max_len = prompt_len + gen
    kv_int8 = bool(policy.kv_int8)
    cache0 = model.init_cache(requests, max_len, cfg.dtype, kv_int8=kv_int8)

    prefill_s = _bench(prefill, serve_params, qparams, batch, cache0)
    n_prompt = requests * prompt_len
    extra = {}
    if mode == "int8" or kv_int8:
        # fused flash-prefill: quantize-once attention over the int8 (or
        # unit-scale bf16) KV tiles via the Pallas kernel
        pol_f = dataclasses.replace(policy, use_pallas=True)
        prefill_f = jax.jit(ST.make_prefill_step(model, cfg, pol_f,
                                                 mode=mode))
        fused_s = _bench(prefill_f, serve_params, qparams, batch, cache0)
        extra["prefill_fused_ms"] = fused_s * 1e3
        extra["prefill_fused_tokens_per_s"] = n_prompt / fused_s
    if prefill_chunk:
        ctoks, lengths = ST.pad_for_chunked_prefill(batch["tokens"],
                                                    prefill_chunk)
        cbatch = {"tokens": ctoks}
        # cache sized for the PADDED prompt (whole chunks are written)
        cache_c = model.init_cache(requests, ctoks.shape[1] + gen, cfg.dtype,
                                   kv_int8=kv_int8)
        prefill_c = jax.jit(ST.make_prefill_step(
            model, cfg, policy, mode=mode, prefill_chunk=prefill_chunk))
        chunked_s = _bench(prefill_c, serve_params, qparams, cbatch, cache_c,
                           lengths)
        extra["prefill_chunked_ms"] = chunked_s * 1e3
        extra["prefill_chunked_tokens_per_s"] = n_prompt / chunked_s

    logits, cache = prefill(serve_params, qparams, batch, cache0)
    tok0 = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    def python_loop(tok0, cache):
        tok = tok0
        for i in range(gen - 1):
            tok, _, cache = step(serve_params, qparams, tok[:, None], cache,
                                 prompt_len + i)
        return tok

    def scan_loop(tok0, cache):
        toks, _ = loop(serve_params, qparams, tok0, cache, prompt_len)
        return toks

    loop_s = _bench(python_loop, tok0, cache)
    scan_s = _bench(scan_loop, tok0, cache)
    n_tok = max(gen - 1, 1)
    return {
        "prefill_ms": prefill_s * 1e3,
        "prefill_tokens_per_s": n_prompt / prefill_s,
        **extra,
        "decode_loop_ms_per_tok": loop_s / n_tok * 1e3,
        "decode_scan_ms_per_tok": scan_s / n_tok * 1e3,
        "decode_scan_tokens_per_s": requests * n_tok / scan_s,
        "scan_speedup_vs_loop": loop_s / scan_s,
    }


def _run_sched(engine, reqs, *, max_slots, prompt_len, gen, block_steps):
    """Warm once, then time a steady-state scheduler run."""
    engine.generate(list(reqs), max_slots=max_slots, prompt_cap=prompt_len,
                    gen_cap=gen, block_steps=block_steps)
    t0 = time.perf_counter()
    completions = engine.generate(list(reqs), max_slots=max_slots,
                                  prompt_cap=prompt_len, gen_cap=gen,
                                  block_steps=block_steps)
    wall = time.perf_counter() - t0
    sched = engine.make_scheduler(max_slots=max_slots,
                                  prompt_cap=prompt_len, gen_cap=gen,
                                  block_steps=block_steps)
    return completions, wall, sched


def bench_ragged_traffic(engine: Engine, *, requests, max_slots, prompt_len,
                         gen, block_steps=8):
    """Continuous-batching throughput: ``requests`` mixed-length requests
    stream through ``max_slots`` slots (launch/scheduler.py).  The first
    run compiles the three scheduler executables; the timed run is
    steady-state.  Records generated tokens/s — the multi-user serving
    headline — plus the executable counts (must be 1 each: raggedness is
    data, not shape)."""
    shape = ShapeSpec("bench", "train", prompt_len, requests)
    spec = DP.spec_for(engine.cfg, shape)
    reqs = ragged_requests(spec, requests, prompt_len, gen)
    completions, wall, sched = _run_sched(
        engine, reqs, max_slots=max_slots, prompt_len=prompt_len, gen=gen,
        block_steps=block_steps)
    n_new = sum(len(c.tokens) for c in completions)
    n_prompt = sum(c.prompt_len for c in completions)
    return {
        "requests": requests,
        "max_slots": max_slots,
        "block_steps": block_steps,
        "prompt_lens": sorted({c.prompt_len for c in completions}),
        "generated_tokens": n_new,
        "wall_ms": wall * 1e3,
        "gen_tokens_per_s": n_new / wall,
        "total_tokens_per_s": (n_new + n_prompt) / wall,
        "executables": sched.executable_counts(),
    }


def bench_speculative_decode(greedy_eng: Engine, spec_eng: Engine, *,
                             requests, max_slots, prompt_len, gen,
                             block_steps=8):
    """Prompt-lookup speculative decoding vs greedy through the slot
    scheduler, on REPETITIVE prompts (a short token pattern tiled to the
    prompt length — the lookup needs recurring n-grams to draft from).
    Records the draft acceptance rate, tok/s for both strategies, and
    ``tokens_match`` — speculative output must be BIT-IDENTICAL to
    greedy under the deterministic accept rule, so the bench doubles as
    an end-to-end correctness check.  On this CPU container the verify
    window runs emulated, so treat the speedup as a dispatch-count
    proxy, not an HBM-bandwidth number (that is what the int8 cache
    halves on real hardware)."""
    shape = ShapeSpec("bench", "train", prompt_len, requests)
    spec = DP.spec_for(greedy_eng.cfg, shape)
    reqs = []
    for r in ragged_requests(spec, requests, prompt_len, gen):
        t = np.asarray(r.tokens)
        pat = t[:min(8, len(t))]
        tiled = np.tile(pat, -(-len(t) // len(pat)))[:len(t)]
        reqs.append(dataclasses.replace(r, tokens=tiled.astype(np.int32)))

    g_done, g_wall, _ = _run_sched(
        greedy_eng, reqs, max_slots=max_slots, prompt_len=prompt_len,
        gen=gen, block_steps=block_steps)
    s_done, s_wall, sched = _run_sched(
        spec_eng, reqs, max_slots=max_slots, prompt_len=prompt_len,
        gen=gen, block_steps=block_steps)
    g_tokens = {c.rid: c.tokens for c in g_done}
    s_tokens = {c.rid: c.tokens for c in s_done}
    n_new = sum(len(t) for t in s_tokens.values())
    stats = sched.spec_stats()
    return {
        "requests": requests,
        "max_slots": max_slots,
        "spec_k": stats["draft_k"],
        "spec_ngram": spec_eng.spec_ngram,
        "generated_tokens": n_new,
        "wall_ms": s_wall * 1e3,
        "gen_tokens_per_s": n_new / s_wall,
        "greedy_gen_tokens_per_s":
            sum(len(t) for t in g_tokens.values()) / g_wall,
        "speedup_vs_greedy": g_wall / s_wall,
        "acceptance_rate": stats["acceptance_rate"],
        "tokens_per_window": stats["tokens_per_window"],
        "tokens_match": g_tokens == s_tokens,
        "executables": sched.executable_counts(),
    }


def bench_paged_prefix_reuse(engine: Engine, *, requests, max_slots,
                             prompt_len, gen, block_steps=8):
    """Prefix sharing under the paged layout: a queue where every request
    carries the SAME prompt.  After the first admission registers the
    prompt's pages, every later admission attaches them with zero prefill
    FLOPs — ``prefill_calls_saved`` counts the skipped executions and
    ``shared_tokens`` the prompt tokens served from shared pages."""
    shape = ShapeSpec("bench", "train", prompt_len, requests)
    spec = DP.spec_for(engine.cfg, shape)
    base = ragged_requests(spec, 1, prompt_len, gen)[0]
    reqs = [dataclasses.replace(base, rid=r) for r in range(requests)]
    completions, wall, sched = _run_sched(
        engine, reqs, max_slots=max_slots, prompt_len=prompt_len, gen=gen,
        block_steps=block_steps)
    n_new = sum(len(c.tokens) for c in completions)
    stats = sched.prefix_stats()
    calls = sched.call_counts()
    admissions = 2 * requests          # warm run + timed run
    return {
        "requests": requests,
        "max_slots": max_slots,
        "page_size": sched.page_size,
        "generated_tokens": n_new,
        "wall_ms": wall * 1e3,
        "gen_tokens_per_s": n_new / wall,
        "prefill_calls": calls["prefill"],
        "prefill_calls_saved": admissions - calls["prefill"],
        "prefix_hits": stats["hits"],
        "shared_tokens": stats["shared_tokens"],
        "executables": sched.executable_counts(),
    }


def bench_degraded_traffic(engine: Engine, *, prompt_len, gen,
                           block_steps=4):
    """Resilience scenario: fixed-seed staggered arrivals with mixed
    priorities and deadlines through a 2-slot scheduler under a
    deterministic :class:`repro.launch.faults.FaultPlan` — one injected
    fault per class (bad request, NaN logits mid-decode, forced prefix
    exhaustion, forced preemption) plus a bounded admission queue that
    sheds under overload and a virtual clock (``ms_per_block``) that
    makes the deadline/arrival interleaving bit-reproducible.

    Records GOODPUT (tokens from requests that finished ok, per second),
    the per-status census, the deadline hit rate, and the preemption
    re-admit overhead (``resume`` prefill calls) — the numbers that show
    faults stay contained to the requests that own them."""
    n = 9
    shape = ShapeSpec("bench", "train", prompt_len, n)
    spec = DP.spec_for(engine.cfg, shape)
    base = ragged_requests(spec, n, prompt_len, gen)
    half = max(1, prompt_len // 2)

    def mk(r, **kw):
        return dataclasses.replace(base[r], **kw)

    reqs = [
        # long-running low-priority resident: forced-preempted at block 1,
        # priority-preempted when rid4 arrives, re-admitted both times
        mk(0, tokens=np.asarray(base[0].tokens)[:half], max_gen=2 * gen,
           arrive_ms=0.0),
        # same shape but with a generous deadline it makes -> the "hit"
        mk(1, tokens=np.asarray(base[1].tokens)[:half], max_gen=2 * gen,
           deadline_ms=500.0, arrive_ms=0.0),
        # malformed arrival: empty prompt -> rejected at admission
        mk(2, tokens=np.zeros((0,), np.int32), arrive_ms=0.0),
        # NaN logits injected at its decode step 1 -> failed, isolated
        mk(3, arrive_ms=0.0),
        # priority arrival mid-run -> preempts the lowest-priority slot
        mk(4, priority=1, arrive_ms=15.0),
        # tight deadline spent waiting in the queue -> timeout
        mk(5, deadline_ms=15.0, arrive_ms=0.0),
        mk(6, arrive_ms=5.0),
        mk(7, arrive_ms=5.0),
        # ninth arrival against queue_cap=5 -> shed
        mk(8, arrive_ms=5.0),
    ]

    sched = engine.make_scheduler(max_slots=2, prompt_cap=prompt_len,
                                  gen_cap=2 * gen, block_steps=block_steps)
    # warm run compiles the executables; the fault plan is a pure function
    # of (rid, block, step) so both runs see identical injections
    engine.generate(list(reqs), max_slots=2, prompt_cap=prompt_len,
                    gen_cap=2 * gen, block_steps=block_steps)
    h0, c0 = dict(sched.health_stats()), dict(sched.call_counts())
    t0 = time.perf_counter()
    completions = engine.generate(list(reqs), max_slots=2,
                                  prompt_cap=prompt_len, gen_cap=2 * gen,
                                  block_steps=block_steps)
    wall = time.perf_counter() - t0
    h1, c1 = sched.health_stats(), sched.call_counts()
    dh = {k: h1[k] - h0.get(k, 0) for k in h1}

    statuses: dict = {}
    for c in completions:
        statuses[c.status] = statuses.get(c.status, 0) + 1
    ok_tokens = sum(len(c.tokens) for c in completions if c.status == "ok")
    with_deadline = [c for c in completions
                     if next(r for r in reqs if r.rid == c.rid).deadline_ms
                     is not None]
    hits = sum(1 for c in with_deadline if c.status == "ok")
    return {
        "requests": n,
        "max_slots": 2,
        "block_steps": block_steps,
        "queue_cap": engine.queue_cap,
        "shed_policy": engine.shed_policy,
        "fault_plan": engine.fault_plan.describe(),
        "statuses": statuses,
        "generated_ok_tokens": ok_tokens,
        "wall_ms": wall * 1e3,
        "goodput_tokens_per_s": ok_tokens / wall,
        "deadline_hit_rate": hits / max(len(with_deadline), 1),
        "preemptions": dh.get("preemptions", 0),
        "readmits": dh.get("readmits", 0),
        "resume_prefill_calls": c1.get("resume", 0) - c0.get("resume", 0),
        "deadline_misses": dh.get("deadline_misses", 0),
        "prefix_exhausted": dh.get("prefix_exhausted", 0),
        "executables": sched.executable_counts(),
    }


def bench_crash_recovery(engine: Engine, *, prompt_len, gen,
                         block_steps=4, crash_block=1):
    """Durability scenario: the same mixed-length queue is served three
    times — an uninterrupted reference run, a journaled run killed by a
    :class:`SimulatedCrash` at ``crash_block``, and a journal-replay
    recovery (``SlotScheduler.recover``) on a FRESH scheduler.  Recovery
    is COLD on purpose: a real restarted process pays executable
    compilation + journal replay + resume prefills + the remaining
    decode, and ``recovery_ms`` measures exactly that (the reference run
    is equally cold, so ``clean_wall_ms`` is the like-for-like number).
    ``tokens_match`` asserts the acceptance property end to end: the
    recovered completions are bit-identical to the uninterrupted run,
    statuses included.  ``crash_block`` defaults to the first boundary —
    every admitted resident is still mid-generation there (any
    ``gen > block_steps + 1``), so the replay always exercises the
    re-admission path."""
    import tempfile

    from repro.launch.faults import FaultPlan, SimulatedCrash
    from repro.launch.scheduler import SlotScheduler

    n = 6
    shape = ShapeSpec("bench", "train", prompt_len, n)
    spec = DP.spec_for(engine.cfg, shape)
    reqs = ragged_requests(spec, n, prompt_len, gen)

    def sched(**extra):
        return SlotScheduler(
            engine.model, engine.cfg, engine.policy, engine.serve_params,
            engine.qparams, mode=engine.mode, max_slots=2,
            prompt_cap=prompt_len, gen_cap=gen, block_steps=block_steps,
            prefill_chunk=engine.prefill_chunk, **extra)

    with tempfile.TemporaryDirectory() as tmp:
        journal = f"{tmp}/requests.jsonl"
        t0 = time.perf_counter()
        ref = sched().run(list(reqs))
        clean_wall = time.perf_counter() - t0

        crashed = sched(journal=journal,
                        fault_plan=FaultPlan(crash=(crash_block,)))
        try:
            crashed.run(list(reqs))
            raise RuntimeError("fault plan failed to crash the run")
        except SimulatedCrash:
            pass

        rec = sched(journal=journal)
        t0 = time.perf_counter()
        done = rec.recover()
        recovery_wall = time.perf_counter() - t0

    def by_rid(cs):
        return {c.rid: (tuple(c.tokens), c.status, c.finished_by)
                for c in cs}

    health, calls = rec.health_stats(), rec.call_counts()
    return {
        "requests": n,
        "max_slots": 2,
        "block_steps": block_steps,
        "crash_block": crash_block,
        "generated_tokens": sum(len(c.tokens) for c in done),
        "clean_wall_ms": clean_wall * 1e3,
        "recovery_ms": recovery_wall * 1e3,
        "recoveries": health["recoveries"],
        "replayed_tokens": health["replayed_tokens"],
        "resume_prefill_calls": calls.get("resume", 0),
        "tokens_match": by_rid(done) == by_rid(ref),
        "executables": rec.executable_counts(),
    }


def bench_sharded_decode(arch, *, requests, prompt_len, gen):
    """Sharded serving scenario: tp=2 vs tp=1 on a host-local mesh.

    Both engines are built from the SAME seed at a shard-divisible head
    grid (the smoke preset's 3 heads can't split), so ``tokens_match``
    is the tensor-parallel exactness claim — int32 row epilogues make
    tp=2 bit-identical, not merely close.  The timing columns are
    dispatch proxies (on CPU the mesh is emulated host-local devices;
    real interconnect wins need hardware), and the byte columns come
    from the compiled HLO via ``dry_run_report``: total collective
    bytes per executable plus the integer-all-reduce verdict CI gates
    on.  Returns None below 2 devices (the bench-smoke lane sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)."""
    if jax.device_count() < 2:
        return None
    from repro.shard.engine import ShardedEngine

    cfg = get_config(arch, smoke=True)
    cfg = cfg.replace(n_heads=4, n_kv_heads=2, head_dim=cfg.head_dim)
    kw = dict(cfg=cfg, smoke=True, cache_layout="dense", use_pallas=False)
    base = Engine.from_checkpoint(arch, **kw)
    tp2 = ShardedEngine.from_checkpoint(arch, tp=2, **kw)

    shape = ShapeSpec("bench", "train", prompt_len, requests)
    spec = DP.spec_for(cfg, shape)
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)

    def run(eng):
        eng.generate_batch(batch, gen, prompt_len=prompt_len)  # compile
        return eng.generate_batch(batch, gen, prompt_len=prompt_len)

    r1, r2 = run(base), run(tp2)
    hlo = tp2.dry_run_report(batch=requests, prompt_len=prompt_len)
    ex = hlo["executables"]
    return {
        "requests": requests,
        "prompt_len": prompt_len,
        "gen": gen,
        "tp": 2,
        "tokens_match": bool(np.array_equal(np.asarray(r1.tokens),
                                            np.asarray(r2.tokens))),
        "prefill_ms_tp1": r1.prefill_s * 1e3,
        "prefill_ms_tp2": r2.prefill_s * 1e3,
        "decode_ms_per_tok_tp1": r1.decode_s / max(gen - 1, 1) * 1e3,
        "decode_ms_per_tok_tp2": r2.decode_s / max(gen - 1, 1) * 1e3,
        "prefill_collective_bytes": ex["prefill"]["collective_bytes"],
        "decode_collective_bytes": ex["decode"]["collective_bytes"],
        "decode_all_reduce_bytes": float(sum(
            b for _, b in ex["decode"]["all_reduce_payloads"])),
        "int8_all_reduces_ok": bool(hlo["int8_all_reduces_ok"]),
    }


def bench_int4_kv(eng8: Engine, *, requests, prompt_len, gen):
    """int4 packed KV cache vs int8: exact byte halving of the quantized
    KV payload, plus serving throughput of the packed lane.

    Both engines share the memoized int8 preparation — the §2 max-abs
    thresholds are bit-width independent (T = max|x|; only the derived
    scale T/levels changes), so swapping ``kv_bits`` on the policy is
    the whole reconfiguration.  The kernels fold the nibble unpack into
    their dequant epilogue, so the dispatch count per generated token is
    identical to int8 — tokens/s is the honest throughput proxy while
    the cache byte counts carry the capacity story (2x more resident
    sequence per HBM byte)."""
    eng4 = Engine(eng8.model, eng8.cfg,
                  dataclasses.replace(eng8.policy, kv_bits=4),
                  eng8.serve_params, eng8.qparams, mode=eng8.mode,
                  cache_layout="dense")
    eng8d = Engine(eng8.model, eng8.cfg, eng8.policy, eng8.serve_params,
                   eng8.qparams, mode=eng8.mode, cache_layout="dense")

    def kv_cache_bytes(eng):
        # shape-only: the quantized KV payload is exactly the int8 leaves
        # (packed nibbles ride int8 storage at kv_bits=4)
        max_len = eng._cache_len(prompt_len, gen)
        abstract = jax.eval_shape(lambda: eng.init_cache(requests, max_len))
        return int(sum(l.size for l in jax.tree.leaves(abstract)
                       if l.dtype == jnp.int8))

    b8, b4 = kv_cache_bytes(eng8d), kv_cache_bytes(eng4)
    shape = ShapeSpec("bench_i4", "train", prompt_len, requests)
    spec = DP.spec_for(eng8.cfg, shape)
    batch = DP.make_batch(spec, 777)
    batch.pop("labels", None)
    r8 = eng8d.generate_batch(batch, gen, prompt_len=prompt_len)
    r4 = eng4.generate_batch(batch, gen, prompt_len=prompt_len)
    return {
        "requests": requests,
        "prompt_len": prompt_len,
        "gen": gen,
        "cache_bytes_int8": b8,
        "cache_bytes_int4": b4,
        "cache_bytes_ratio": b8 / b4,
        "prefill_ms_int4": r4.prefill_s * 1e3,
        "gen_tokens_per_s_int8": r8.gen_tokens / max(r8.decode_s, 1e-9),
        "gen_tokens_per_s_int4": r4.gen_tokens / max(r4.decode_s, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    help="architecture preset to benchmark")
    ap.add_argument("--requests", type=int, default=4,
                    help="synthetic requests per scenario")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="tokens per synthetic prompt")
    ap.add_argument("--gen", type=int, default=32,
                    help="tokens to generate per request")
    ap.add_argument("--quick", action="store_true",
                    help="only the production config (int8 w + int8 kv)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="also time the chunked ragged prefill pipeline")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="slots for the ragged-traffic scenario "
                         "(default: requests // 2, min 2)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="page size for the paged prefix-reuse scenario")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft-window length for the speculative scenario")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="prompt-lookup n-gram for the speculative scenario")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="path for the benchmark JSON report")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    shape = ShapeSpec("cli", "train", args.prompt_len, args.requests)
    spec = DP.spec_for(cfg, shape)
    calib_batches = DP.calibration_batches(spec, 2)
    for b in calib_batches:
        b.pop("labels", None)
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)

    grid = [("int8_w_int8_kv", True, True)]
    if not args.quick:
        grid += [
            ("bf16_w_bf16_kv", False, False),
            ("bf16_w_int8_kv", False, True),
            ("int8_w_bf16_kv", True, False),
        ]

    report = {
        "arch": args.arch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "prefill_chunk": args.prefill_chunk,
        "backend": jax.default_backend(),
        "configs": {},
    }
    memo = {}   # (int8_weights, kv_int8) -> Engine
    for name, int8_w, kv8 in grid:
        eng = _engine(memo, args.arch, int8_weights=int8_w, kv_int8=kv8,
                      calib_batches=calib_batches)
        r = bench_config(
            eng, batch, requests=args.requests, prompt_len=args.prompt_len,
            gen=args.gen, prefill_chunk=args.prefill_chunk)
        report["configs"][name] = r
        fused = (f" | fused {r['prefill_fused_ms']:.1f} ms"
                 if "prefill_fused_ms" in r else "")
        chunked = (f" | chunked {r['prefill_chunked_ms']:.1f} ms"
                   if "prefill_chunked_ms" in r else "")
        print(f"{name}: prefill {r['prefill_ms']:.1f} ms "
              f"({r['prefill_tokens_per_s']:.0f} tok/s){fused}{chunked} | "
              f"loop {r['decode_loop_ms_per_tok']:.2f} ms/tok | "
              f"scan {r['decode_scan_ms_per_tok']:.2f} ms/tok "
              f"({r['scan_speedup_vs_loop']:.2f}x, "
              f"{r['decode_scan_tokens_per_s']:.0f} tok/s)")

    cfgs = report["configs"]
    ref = cfgs.get("bf16_w_bf16_kv")
    # bf16_w_int8_kv isolates the fused int8 ATTENTION (the int8-weight
    # configs also swap every matmul kernel under use_pallas, which on the
    # CPU interpret lowering is a separate, unrelated cost)
    fus = cfgs.get("bf16_w_int8_kv") or next(
        (c for n, c in cfgs.items()
         if "int8_kv" in n and "prefill_fused_ms" in c), None)
    if ref and fus and "prefill_fused_ms" in fus:
        ratio = ref["prefill_ms"] / fus["prefill_fused_ms"]
        report["fused_int8_prefill_speedup_vs_bf16_jnp"] = ratio
        print(f"fused int8 prefill vs bf16 jnp prefill: {ratio:.2f}x")

    # continuous batching: stream 2x the slot count of mixed-length
    # requests through the scheduler (the multi-user serving scenario)
    slots = args.max_slots or max(2, args.requests // 2)
    n_reqs = max(args.requests, 2 * slots)
    block = min(8, max(2, args.gen // 2))
    eng = _engine(memo, args.arch, int8_weights=True, kv_int8=True,
                  calib_batches=calib_batches)
    eng.prefill_chunk = args.prefill_chunk
    rt = bench_ragged_traffic(
        eng, requests=n_reqs, max_slots=slots, prompt_len=args.prompt_len,
        gen=args.gen, block_steps=block)
    report["ragged_traffic"] = rt
    print(f"ragged traffic: {rt['requests']} reqs / {rt['max_slots']} slots "
          f"| lens {rt['prompt_lens']} | {rt['generated_tokens']} tokens in "
          f"{rt['wall_ms']:.1f} ms ({rt['gen_tokens_per_s']:.0f} gen tok/s) "
          f"| executables {rt['executables']}")

    # speculative decoding: repetitive prompts through the prompt-lookup
    # strategy vs greedy — a fresh engine (own scheduler) sharing the
    # memoized int8 preparation; tokens must match greedy bit-for-bit
    spec_eng = Engine(eng.model, eng.cfg, eng.policy, eng.serve_params,
                      eng.qparams, mode=eng.mode,
                      decode_strategy="speculative", spec_k=args.spec_k,
                      spec_ngram=args.spec_ngram,
                      prefill_chunk=args.prefill_chunk)
    sd = bench_speculative_decode(
        eng, spec_eng, requests=n_reqs, max_slots=slots,
        prompt_len=args.prompt_len, gen=args.gen, block_steps=block)
    report["speculative_decode"] = sd
    print(f"speculative decode: {sd['requests']} reqs / {sd['max_slots']} "
          f"slots | k={sd['spec_k']} ngram={sd['spec_ngram']} | acceptance "
          f"{sd['acceptance_rate']:.2f} ({sd['tokens_per_window']:.2f} "
          f"tok/window) | {sd['gen_tokens_per_s']:.0f} vs greedy "
          f"{sd['greedy_gen_tokens_per_s']:.0f} gen tok/s "
          f"({sd['speedup_vs_greedy']:.2f}x) | tokens_match="
          f"{sd['tokens_match']} | executables {sd['executables']}")

    # paged prefix reuse: the SAME prompt repeated — a fresh paged engine
    # (own scheduler/prefix store) sharing the memoized int8 preparation
    paged = Engine(eng.model, eng.cfg, eng.policy, eng.serve_params,
                   eng.qparams, mode=eng.mode, cache_layout="paged",
                   page_size=args.page_size,
                   prefill_chunk=args.prefill_chunk)
    pr = bench_paged_prefix_reuse(
        paged, requests=n_reqs, max_slots=slots,
        prompt_len=args.prompt_len, gen=args.gen, block_steps=block)
    report["paged_prefix_reuse"] = pr
    print(f"paged prefix reuse: {pr['requests']} identical prompts / "
          f"{pr['max_slots']} slots | {pr['prefill_calls']} prefill calls "
          f"({pr['prefill_calls_saved']} saved, {pr['shared_tokens']} "
          f"tokens from shared pages) | {pr['gen_tokens_per_s']:.0f} gen "
          f"tok/s | executables {pr['executables']}")

    # degraded traffic: staggered mixed-priority arrivals under a
    # deterministic fault plan — a fresh paged engine with a bounded
    # admission queue (own scheduler) sharing the int8 preparation
    from repro.launch.faults import FaultPlan
    deg_eng = Engine(eng.model, eng.cfg, eng.policy, eng.serve_params,
                     eng.qparams, mode=eng.mode, cache_layout="paged",
                     page_size=args.page_size,
                     prefill_chunk=args.prefill_chunk,
                     queue_cap=5, shed_policy="shed",
                     fault_plan=FaultPlan(nan_decode=((3, 1),),
                                          preempt=((1, 0),),
                                          exhaust_prefix=True,
                                          ms_per_block=10.0))
    dg = bench_degraded_traffic(deg_eng, prompt_len=args.prompt_len,
                                gen=args.gen)
    report["degraded_traffic"] = dg
    print(f"degraded traffic: {dg['requests']} reqs / {dg['max_slots']} "
          f"slots under faults | statuses {dg['statuses']} | goodput "
          f"{dg['goodput_tokens_per_s']:.0f} tok/s | deadline hit rate "
          f"{dg['deadline_hit_rate']:.2f} | {dg['preemptions']} preemptions "
          f"/ {dg['readmits']} readmits ({dg['resume_prefill_calls']} "
          f"resume prefills) | executables {dg['executables']}")

    # crash recovery: a journaled run killed mid-flight, then replayed on
    # a fresh scheduler — cost of coming back plus the bit-parity check
    cr = bench_crash_recovery(eng, prompt_len=args.prompt_len, gen=args.gen)
    report["crash_recovery"] = cr
    print(f"crash recovery: {cr['requests']} reqs crashed at block "
          f"{cr['crash_block']} | replay {cr['replayed_tokens']} tokens "
          f"({cr['resume_prefill_calls']} resume prefills) | recovery "
          f"{cr['recovery_ms']:.1f} ms vs clean {cr['clean_wall_ms']:.1f} "
          f"ms | tokens_match={cr['tokens_match']} | executables "
          f"{cr['executables']}")

    # int4 packed KV: exact cache-byte halving vs int8 + packed-lane
    # throughput, sharing the memoized int8 preparation
    i4 = bench_int4_kv(eng, requests=args.requests,
                       prompt_len=args.prompt_len, gen=args.gen)
    report["int4_kv"] = i4
    print(f"int4 kv: cache {i4['cache_bytes_int4']} B vs int8 "
          f"{i4['cache_bytes_int8']} B ({i4['cache_bytes_ratio']:.1f}x) | "
          f"{i4['gen_tokens_per_s_int4']:.0f} vs int8 "
          f"{i4['gen_tokens_per_s_int8']:.0f} gen tok/s")

    # sharded decode: tp=2 vs tp=1 bit parity + the compiled-HLO
    # collective audit (needs >= 2 devices; the CI lane forces 4)
    sh = bench_sharded_decode(args.arch, requests=args.requests,
                              prompt_len=args.prompt_len, gen=args.gen)
    report["sharded_decode"] = sh
    if sh is None:
        print("sharded decode: skipped (single device — set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    else:
        print(f"sharded decode: tp={sh['tp']} | tokens_match="
              f"{sh['tokens_match']} | decode "
              f"{sh['decode_ms_per_tok_tp2']:.2f} vs tp1 "
              f"{sh['decode_ms_per_tok_tp1']:.2f} ms/tok | collectives "
              f"prefill {sh['prefill_collective_bytes']:.0f} B / decode "
              f"{sh['decode_collective_bytes']:.0f} B (all-reduce "
              f"{sh['decode_all_reduce_bytes']:.0f} B) | "
              f"int8_all_reduces_ok={sh['int8_all_reduces_ok']}")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
