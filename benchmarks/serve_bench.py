"""Serving-path benchmark: prefill / decode timing across the int8 grid.

Measures steady-state (post-compile) wall time for the four serving
configurations the decode fast path introduces:

  * weights: bf16 vs int8
  * KV cache: bf16 vs int8
  * decode driver: per-token Python loop vs single lax.scan

and writes ``BENCH_serve.json`` so the perf trajectory is tracked across
PRs.  The headline numbers are decode ms/token and tokens/s; the scan/loop
ratio is the dispatch-overhead win, the int8/bf16 ratios are the bandwidth
win (visible on real HBM-bound hardware; on this CPU container they mostly
track correctness, not the 2x byte reduction).

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--gen 32]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model


def _bench(fn, *args, iters=2):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_config(model, cfg, params, batch, *, requests, prompt_len, gen,
                 int8_weights, kv_int8, calib_batches):
    from repro.launch.serve import prepare_int8

    policy = A.QuantPolicy(kv_int8=kv_int8)
    mode = "int8" if int8_weights else "none"
    if int8_weights or kv_int8:
        # same deployment pipeline the serving CLI runs — the bench must
        # measure the served configuration, not a reimplementation of it
        serve_params, qparams = prepare_int8(model, cfg, policy, params,
                                             calib_batches,
                                             convert=int8_weights)
    else:
        # pure-bf16 baseline consumes no thresholds; skip the calibration
        # forward passes
        serve_params = params
        qparams = A.finalize_calibration(
            A.init_qparams(model, params, policy), policy)

    prefill = jax.jit(ST.make_prefill_step(model, cfg, policy, mode=mode))
    step = jax.jit(ST.make_serve_step(model, cfg, policy, mode=mode))
    loop = jax.jit(ST.make_decode_loop(model, cfg, policy, mode=mode,
                                       n_steps=gen))
    max_len = prompt_len + gen
    cache0 = model.init_cache(requests, max_len, cfg.dtype, kv_int8=kv_int8)

    prefill_s = _bench(prefill, serve_params, qparams, batch, cache0)
    logits, cache = prefill(serve_params, qparams, batch, cache0)
    tok0 = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    def python_loop(tok0, cache):
        tok = tok0
        for i in range(gen - 1):
            tok, _, cache = step(serve_params, qparams, tok[:, None], cache,
                                 prompt_len + i)
        return tok

    def scan_loop(tok0, cache):
        toks, _ = loop(serve_params, qparams, tok0, cache, prompt_len)
        return toks

    loop_s = _bench(python_loop, tok0, cache)
    scan_s = _bench(scan_loop, tok0, cache)
    n_tok = max(gen - 1, 1)
    return {
        "prefill_ms": prefill_s * 1e3,
        "decode_loop_ms_per_tok": loop_s / n_tok * 1e3,
        "decode_scan_ms_per_tok": scan_s / n_tok * 1e3,
        "decode_scan_tokens_per_s": requests * n_tok / scan_s,
        "scan_speedup_vs_loop": loop_s / scan_s,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quick", action="store_true",
                    help="only the production config (int8 w + int8 kv)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeSpec("cli", "train", args.prompt_len, args.requests)
    spec = DP.spec_for(cfg, shape)
    calib_batches = DP.calibration_batches(spec, 2)
    for b in calib_batches:
        b.pop("labels", None)
    batch = DP.make_batch(spec, 12345)
    batch.pop("labels", None)

    grid = [("int8_w_int8_kv", True, True)]
    if not args.quick:
        grid += [
            ("bf16_w_bf16_kv", False, False),
            ("bf16_w_int8_kv", False, True),
            ("int8_w_bf16_kv", True, False),
        ]

    report = {
        "arch": args.arch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "backend": jax.default_backend(),
        "configs": {},
    }
    for name, int8_w, kv8 in grid:
        r = bench_config(
            model, cfg, params, batch, requests=args.requests,
            prompt_len=args.prompt_len, gen=args.gen,
            int8_weights=int8_w, kv_int8=kv8, calib_batches=calib_batches,
        )
        report["configs"][name] = r
        print(f"{name}: prefill {r['prefill_ms']:.1f} ms | "
              f"loop {r['decode_loop_ms_per_tok']:.2f} ms/tok | "
              f"scan {r['decode_scan_ms_per_tok']:.2f} ms/tok "
              f"({r['scan_speedup_vs_loop']:.2f}x, "
              f"{r['decode_scan_tokens_per_s']:.0f} tok/s)")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
