"""Benchmark harness — one function per paper table/figure.

  table1_scalar_modes   — paper Table 1 (8-bit scalar: symmetric vs
                          asymmetric) on a reduced LM backbone
  table2_vector_modes   — paper Table 2 (8-bit vector modes)
  dws_rescaling         — §3.3/§4.2 sequence: scalar collapse -> rescale
                          recovery -> pointwise fine-tune recovery
  fat_convergence       — §3.2/§4.1.2 training: RMSE distillation loss
                          decreases when training only threshold scales
  kernels_micro         — per-kernel timing (interpret mode on CPU)

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import api as A
from repro.core import quant as Q
from repro.core.distill import rmse_distill_loss
from repro.models import build_model
from repro.optim.adam import adam_init, adam_update, cosine_restarts

from benchmarks.dws_model import DWSNet


def _timeit(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _agreement(teacher_logits, student_logits):
    """Top-1 agreement — the label-free analog of the paper's top-1
    accuracy (teacher defines the reference prediction)."""
    return float(jnp.mean(
        (jnp.argmax(teacher_logits, -1) == jnp.argmax(student_logits, -1)
         ).astype(jnp.float32)))


def _lm_quant_quality(policy: A.QuantPolicy, batches=4, seed=0):
    """Teacher/student fidelity for one quantization policy on a reduced
    LM backbone (smollm family)."""
    cfg = get_config("smollm-135m", smoke=True).replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=384)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    qp = A.init_qparams(model, params, policy)

    def batch_for(i):
        return {"tokens": jax.random.randint(
            jax.random.PRNGKey(100 + i), (8, 64), 0, cfg.vocab)}

    # calibration (paper: ~100 unlabeled samples)
    for i in range(batches):
        ctx = A.make_ctx("calibrate", policy, qp)
        model(params, batch_for(i), ctx)
        for path, obs in ctx.updates.items():
            qp[path] = {**qp[path], "act": obs}
    qp = A.finalize_calibration(qp, policy)

    eval_batch = batch_for(99)
    teacher, _ = model(params, eval_batch)
    student, _ = model(params, eval_batch, A.make_ctx("fake", policy, qp))
    rmse = float(rmse_distill_loss(teacher, student))
    agree = _agreement(teacher, student)
    return rmse, agree


def table1_scalar_modes():
    """Table 1 analog: 8-bit SCALAR quantization, symmetric vs asymmetric.

    Paper finding: asymmetric >= symmetric; scalar mode is the weak
    configuration."""
    rows = []
    for name, sym in (("symmetric", True), ("asymmetric", False)):
        t0 = time.perf_counter()
        rmse, agree = _lm_quant_quality(
            A.QuantPolicy(act_symmetric=sym, weight_per_channel=False))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table1_scalar_{name}", us,
                     f"rmse={rmse:.4f};top1_agree={agree:.3f}"))
    return rows


def table2_vector_modes():
    """Table 2 analog: 8-bit VECTOR (per-channel) quantization.

    Paper finding: vector mode is within noise of full precision and
    strictly better than scalar."""
    rows = []
    for name, sym in (("symmetric", True), ("asymmetric", False)):
        t0 = time.perf_counter()
        rmse, agree = _lm_quant_quality(
            A.QuantPolicy(act_symmetric=sym, weight_per_channel=True))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table2_vector_{name}", us,
                     f"rmse={rmse:.4f};top1_agree={agree:.3f}"))
    return rows


def dws_rescaling():
    """§3.3 + §4.2 sequence on the planted-outlier DWS net.

    Paper: scalar MobileNet-v2 1.6% -> +rescale 67% -> +pointwise 71%
    (FP 71.55%).  Here: top-1 agreement with the FP model."""
    net = DWSNet()
    key = jax.random.PRNGKey(0)
    params = net.init(key)
    folded = [net.fold_cell(c) for c in params["cells"]]
    x_eval = jax.random.normal(jax.random.PRNGKey(1), (64, 16, net.channels))
    x_cal = jax.random.normal(jax.random.PRNGKey(2), (16, 16, net.channels))

    fp = net.forward_folded(folded, params["head"], x_eval, None)

    rows = []
    t0 = time.perf_counter()
    scalar = net.forward_folded(folded, params["head"], x_eval,
                                {"mode": "scalar"})
    a_scalar = _agreement(fp, scalar)

    rescaled = net.rescale_cells(folded, x_cal)
    resc = net.forward_folded(rescaled, params["head"], x_eval,
                              {"mode": "scalar"})
    a_resc = _agreement(fp, resc)

    vector = net.forward_folded(folded, params["head"], x_eval,
                                {"mode": "vector"})
    a_vector = _agreement(fp, vector)

    # §4.2 pointwise fine-tune on the rescaled scalar model: train
    # per-value scales in [0.75, 1.25] against the FP teacher
    pw = [jnp.ones_like(c["dws_w"]) for c in rescaled]

    def loss_fn(pw):
        cells = [
            {**c, "dws_w": Q.apply_pointwise_scale(c["dws_w"], p)}
            for c, p in zip(rescaled, pw)
        ]
        out = net.forward_folded(cells, params["head"], x_cal,
                                 {"mode": "scalar"})
        ref = net.forward_folded(folded, params["head"], x_cal, None)
        return rmse_distill_loss(ref, out)

    opt = adam_init(pw)
    for step in range(30):
        val, g = jax.value_and_grad(loss_fn)(pw)
        pw, opt = adam_update(g, opt, pw, 2e-2)
    cells_ft = [
        {**c, "dws_w": Q.apply_pointwise_scale(c["dws_w"], p)}
        for c, p in zip(rescaled, pw)
    ]
    ft = net.forward_folded(cells_ft, params["head"], x_eval,
                            {"mode": "scalar"})
    a_ft = _agreement(fp, ft)
    us = (time.perf_counter() - t0) * 1e6

    derived = (f"scalar={a_scalar:.3f};rescaled={a_resc:.3f};"
               f"rescaled_ft={a_ft:.3f};vector={a_vector:.3f}")
    rows.append(("dws_rescaling_sequence", us, derived))
    # paper ordering asserted: collapse < rescaled <= vector-quality
    assert a_scalar < a_resc, (a_scalar, a_resc)
    assert a_vector >= a_scalar, (a_vector, a_scalar)
    return rows


def fat_convergence():
    """§3.2: RMSE between FP and quantized outputs falls when training
    ONLY the threshold scale factors (Adam + cosine annealing)."""
    cfg = get_config("smollm-135m", smoke=True).replace(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = A.QuantPolicy(weight_per_channel=False)  # stress scalar mode
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (8, 64),
                                          0, cfg.vocab)}
    qp = A.finalize_calibration(_merge_obs(model, params, policy, batch),
                                policy)
    teacher, _ = model(params, batch)

    def loss_fn(qp):
        s, _ = model(params, batch, A.make_ctx("fake", policy, qp))
        return rmse_distill_loss(teacher, s)

    mask = A.trainable_mask(qp)
    opt = adam_init(qp)
    loss0 = float(loss_fn(qp))

    @jax.jit
    def step_fn(qp, opt):
        loss, g = jax.value_and_grad(loss_fn)(qp)
        lr = cosine_restarts(opt.step, 5e-3, 20)
        qp2, opt2 = adam_update(g, opt, qp, lr, mask=mask)
        return qp2, opt2, loss

    t0 = time.perf_counter()
    for i in range(40):
        qp, opt, loss = step_fn(qp, opt)
    us = (time.perf_counter() - t0) / 40 * 1e6
    loss1 = float(loss)
    assert loss1 < loss0, (loss0, loss1)
    return [("fat_convergence_40steps", us,
             f"rmse0={loss0:.4f};rmse40={loss1:.4f};"
             f"improvement={100*(1-loss1/loss0):.1f}%")]


def _merge_obs(model, params, policy, batch):
    qp = A.init_qparams(model, params, policy)
    ctx = A.make_ctx("calibrate", policy, qp)
    model(params, batch, ctx)
    for path, obs in ctx.updates.items():
        qp[path] = {**qp[path], "act": obs}
    return qp


def kernels_micro():
    from repro.kernels import ops, ref

    rows = []
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    spec = Q.QuantSpec(bits=8, symmetric=True, per_channel=True)
    t_w = Q.max_abs_threshold(w, spec)
    w_q, w_scale = Q.quantize_weights_int8(w, t_w, jnp.ones_like(t_w), spec)
    act_scale = jnp.float32(127.0 / 3.0)
    comb = (w_scale / act_scale).astype(jnp.float32)

    us = _timeit(lambda: ops.quant_matmul(x, w_q, comb, act_scale,
                                          block_m=128, block_n=128,
                                          block_k=256))
    rows.append(("pallas_quant_matmul_interpret", us, f"shape={m}x{k}x{n}"))
    us = _timeit(lambda: ref.quant_matmul_ref(x, w_q, comb, act_scale))
    rows.append(("quant_matmul_ref_xla", us, f"shape={m}x{k}x{n}"))

    t = jnp.abs(jnp.asarray(rng.normal(size=(n,)), jnp.float32)) + 0.5
    a = jnp.full((n,), 0.8, jnp.float32)
    xx = jnp.asarray(rng.normal(size=(512, n)), jnp.float32)
    us = _timeit(lambda: ops.fake_quant(xx, t, a))
    rows.append(("pallas_fake_quant_interpret", us, f"shape=512x{n}"))
    us = _timeit(lambda: ref.fake_quant_ref(xx, t, a))
    rows.append(("fake_quant_ref_xla", us, f"shape=512x{n}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer repeats (smoke-level timing noise)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    rows = []
    rows += table1_scalar_modes()
    rows += table2_vector_modes()
    rows += dws_rescaling()
    rows += fat_convergence()
    if not args.quick:
        rows += kernels_micro()
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    # paper-ordering checks across tables (vector >= scalar fidelity)
    by = {r[0]: r[2] for r in rows}

    def rmse_of(key):
        return float(by[key].split("rmse=")[1].split(";")[0])

    assert rmse_of("table2_vector_symmetric") <= rmse_of("table1_scalar_symmetric")
    assert rmse_of("table2_vector_asymmetric") <= rmse_of("table1_scalar_asymmetric")
    print("paper_orderings,0,vector<=scalar rmse confirmed")


if __name__ == "__main__":
    main()
