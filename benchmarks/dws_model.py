"""Depthwise-separable conv stack — the paper's §3.3 setting, in miniature.

A MobileNet-style cell chain: [conv1x1 -> BN -> ReLU6 -> DWS3x3 -> BN ->
ReLU6 -> conv1x1] with planted per-channel weight outliers that reproduce
the paper's Figure 1 pathology: a handful of filters carry ~20x the weight
scale of the rest, so a *scalar* (per-tensor) int8 threshold destroys most
channels' resolution (the paper's MobileNet-v2 collapse to 1.6-8.1% top-1),
while vector thresholds / cross-layer rescaling recover it.

This model exists for benchmarks only (the LM framework covers the
production path); it exercises fold_batchnorm (§3.1.2 eqs. 10-11) and
dws_relu6_rescale (§3.3.1 steps 1-6) end to end.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core.equalization import dws_relu6_rescale
from repro.core.folding import fold_batchnorm


@dataclasses.dataclass
class DWSNet:
    """Fig.-1-style pathology: ~3% of filters carry ~100x weight scale —
    a per-tensor threshold then leaves <2 int8 levels for the other 97%."""

    channels: int = 64
    depth: int = 3
    classes: int = 64
    outlier_frac: float = 0.03
    outlier_scale: float = 100.0

    def init(self, key):
        rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
        params = {"cells": []}
        c = self.channels
        for i in range(self.depth):
            dws = rng.normal(size=(3, c)).astype(np.float32) * 0.3
            # plant outliers: a few channels dominate the weight range
            n_out = max(1, int(c * self.outlier_frac))
            idx = rng.choice(c, n_out, replace=False)
            dws[:, idx] *= self.outlier_scale
            cell = {
                "dws_w": jnp.asarray(dws),            # (K=3, C) depthwise 1D
                "dws_bn": {
                    "gamma": jnp.asarray(rng.uniform(0.5, 1.5, c), jnp.float32),
                    "beta": jnp.asarray(rng.normal(size=c) * 0.1, jnp.float32),
                    "mu": jnp.asarray(rng.normal(size=c) * 0.1, jnp.float32),
                    "var": jnp.asarray(rng.uniform(0.5, 2.0, c), jnp.float32),
                },
                "pw_w": jnp.asarray(
                    rng.normal(size=(c, c)).astype(np.float32) / np.sqrt(c)),
            }
            params["cells"].append(cell)
        params["head"] = jnp.asarray(
            rng.normal(size=(c, self.classes)).astype(np.float32) / np.sqrt(c))
        return params

    # -- building blocks ----------------------------------------------------
    @staticmethod
    def dws_conv(x, w):
        """Causal depthwise 1D conv; x: (B, T, C), w: (K, C)."""
        k = w.shape[0]
        xp = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
        return sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(k))

    @staticmethod
    def fold_cell(cell):
        """BN-fold the depthwise conv (paper §3.1.2)."""
        bn = cell["dws_bn"]
        w_f, b_f = fold_batchnorm(cell["dws_w"], bn["gamma"], bn["beta"],
                                  bn["mu"], bn["var"])
        return {"dws_w": w_f, "dws_b": b_f, "pw_w": cell["pw_w"]}

    def forward_folded(self, folded_cells, head, x, quant=None):
        """quant: None (fp32) or dict(mode='scalar'|'vector', acts=...)."""
        for cell in folded_cells:
            h = self.dws_conv(x, self._maybe_q(cell["dws_w"], quant))
            h = h + cell["dws_b"]
            h = jnp.clip(h, 0.0, 6.0)  # ReLU6
            if quant is not None:
                h = self._act_q(h, quant)
            x = h @ self._maybe_q(cell["pw_w"], quant)
        return x.mean(axis=1) @ head

    @staticmethod
    def _maybe_q(w, quant):
        if quant is None:
            return w
        spec = Q.QuantSpec(bits=8, symmetric=True,
                           per_channel=(quant["mode"] == "vector"),
                           channel_axis=-1)
        t = Q.max_abs_threshold(w, spec)
        return Q.fake_quant_symmetric(w, t, jnp.ones_like(t), spec)

    @staticmethod
    def _act_q(h, quant):
        spec = Q.QuantSpec(bits=8, symmetric=True, unsigned=True)
        return Q.fake_quant_symmetric(h, jnp.asarray(6.0), jnp.ones(()), spec)

    # -- §3.3 rescaling -------------------------------------------------------
    def rescale_cells(self, folded_cells, calib_x):
        """Apply the paper's DWS->ReLU6->Conv rescale using calibration
        activations to find per-channel output maxima (steps 2-3)."""
        out = []
        x = calib_x
        for cell in folded_cells:
            pre = self.dws_conv(x, cell["dws_w"]) + cell["dws_b"]
            act_max = jnp.max(jnp.abs(pre), axis=(0, 1))
            w_d, b_d, w_p, _ = dws_relu6_rescale(
                cell["dws_w"], cell["dws_b"], cell["pw_w"], act_max)
            out.append({"dws_w": w_d, "dws_b": b_d, "pw_w": w_p})
            x = jnp.clip(pre, 0, 6) @ cell["pw_w"]
        return out
