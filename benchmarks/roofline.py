"""Aggregate dry-run JSON reports into the §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Writes markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HEADERS = (
    "| arch | shape | mesh | peak GB/dev (tpu-est) | compute s | memory s | "
    "collective s | dominant | MODEL_FLOPS | useful ratio | one-line fix |"
)

FIX_HINTS = {
    "compute_s": "raise arithmetic intensity (int8 MXU path halves compute "
    "term; fuse fq into matmuls)",
    "memory_s": "int8 weights halve the stream; bigger fusion tiles; fewer "
    "f32 materializations",
    "collective_s": "cut SP all-gathers (act_seq=None where activations "
    "fit), overlap collectives with compute, quantize collectives",
}


def load_reports(d: str):
    reps = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            reps.append(json.load(f))
    return reps


def render(reps, mesh_filter: str | None = "16x16"):
    print(HEADERS)
    print("|" + "---|" * 11)
    for r in reps:
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        mem = r["memory_analysis"]
        peak = mem["peak_bytes_per_device"] / 1e9
        peak_t = mem.get("peak_bytes_per_device_tpu_estimate", 0) / 1e9
        dom = rf["dominant"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {peak:.1f} ({peak_t:.1f}) "
            f"| {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | {dom.replace('_s','')} "
            f"| {rf['model_flops_global']:.2e} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {FIX_HINTS[dom]} |"
        )


def summarize(reps):
    """Pick hillclimb candidates: worst roofline fraction / most
    collective-bound / most paper-representative."""
    singles = [r for r in reps if r["mesh"] == "16x16"]
    if not singles:
        return
    def frac(r):
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        return rf["compute_s"] / total if total else 0.0
    worst = min(singles, key=frac)
    coll = max(singles, key=lambda r: r["roofline"]["collective_s"])
    print()
    print(f"worst-compute-fraction: {worst['arch']} x {worst['shape']} "
          f"(compute fraction {frac(worst):.3f})")
    print(f"most-collective-bound: {coll['arch']} x {coll['shape']} "
          f"(collective {coll['roofline']['collective_s']:.3f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun",
                    help="directory of dry-run plan JSON files to read")
    ap.add_argument("--all-meshes", action="store_true",
                    help="print every mesh variant, not just the best")
    args = ap.parse_args()
    reps = load_reports(args.dir)
    if not reps:
        print(f"(no dry-run reports in {args.dir} — run "
              "`python -m repro.launch.dryrun --all --both-meshes` first)")
        return
    render(reps, None if args.all_meshes else "16x16")
    summarize(reps)


if __name__ == "__main__":
    main()
