"""Quickstart: quantize a model with FAT in ~40 lines.

Covers the paper's full §3 pipeline on a CPU-sized model:
  1. calibrate activation thresholds on unlabeled data      (§2)
  2. fine-tune threshold scale factors by distillation      (§3.1.3, §3.2)
  3. convert to int8 and compare against the FP32 teacher   (§2, eq. 20)

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import api as A
from repro.core.distill import rmse_distill_loss
from repro.data import pipeline as DP
from repro.configs.shapes import ShapeSpec
from repro.launch import steps as ST
from repro.models import build_model
from repro.optim.adam import adam_init

# 1. a model (any of the 10 archs; smoke = reduced size)
cfg = get_config("smollm-135m", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. unlabeled data stream (the paper needs no labels anywhere)
spec = DP.spec_for(cfg, ShapeSpec("qs", "train", seq_len=64, global_batch=8))

# 3. calibrate (paper: ~100 samples)
policy = A.QuantPolicy(weight_per_channel=True)   # vector mode, §3.1.5
qparams = A.init_qparams(model, params, policy)
calibrate = jax.jit(ST.make_calibrate_step(model, cfg, policy))
for batch in DP.calibration_batches(spec, n=4):
    qparams = calibrate(params, qparams, batch)
qparams = A.finalize_calibration(qparams, policy)
print(f"calibrated {len(qparams)} quantization points")

# 4. FAT fine-tune: train ONLY threshold scales against the FP teacher
train_step = jax.jit(ST.make_fat_train_step(model, cfg, policy))
opt = adam_init(qparams)
for step in range(20):
    batch = DP.make_batch(spec, step)
    qparams, opt, m = train_step(params, qparams, opt, batch)
    if step % 5 == 0:
        print(f"  step {step:3d}  distill RMSE {float(m['loss']):.4f}")

# 5. int8 conversion + fidelity check
serve_params = A.convert_to_int8(model, params, qparams, policy)
batch = DP.make_batch(spec, 999)
teacher, _ = model(params, batch)
student, _ = model(serve_params, batch, A.make_ctx("int8", policy, qparams))
agree = jnp.mean((jnp.argmax(teacher, -1) == jnp.argmax(student, -1))
                 .astype(jnp.float32))
print(f"int8 vs fp top-1 agreement: {float(agree):.3f}")
print(f"int8 logit RMSE: {float(rmse_distill_loss(teacher, student)):.4f}")
assert float(agree) > 0.9
print("OK")
