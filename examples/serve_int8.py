"""Serve a FAT-quantized model with batched requests (int8 weights).

Demonstrates the two serving surfaces:

  1. the ``serve`` CLI (thin flags over the Engine) — int8 vs bf16
     agreement, chunked ragged prefill with nucleus sampling, and the
     continuous-batching scheduler;
  2. the ``Engine`` facade directly (launch/engine.py) — one call
     assembles calibration + int8 conversion + step functions, and the
     PAGED cache layout turns repeated prompts into zero-prefill
     admissions through the prefix store.

Useful serve flags (see repro/launch/serve.py and the README flag
reference for the full list):
  --prefill-chunk N   chunked ragged prefill: one lax.scan over fixed-size
                      prompt chunks + a per-request length vector, so one
                      compiled executable serves any prompt length
  --temperature T     sampled decoding (0 = greedy); --top-p P restricts
                      sampling to the nucleus of probability mass P
  --pallas            fused Pallas kernels: flash-prefill AND flash-decode
                      attend over the cache's kernel view (identity block
                      table for dense/ring, the page table for paged)
  --max-slots N       continuous batching (launch/scheduler.py): requests
                      are admitted into free cache slots as they drain,
                      every slot decodes at its own position, and ONE
                      compiled decode executable serves the whole ragged
                      run (--block-steps / --eos-id tune the scheduler)
  --strategy speculative --spec-k K --spec-ngram N
                      prompt-lookup speculative decoding (launch/
                      strategies.py): draft K tokens from the token
                      history, verify them in one batched pass —
                      bit-identical tokens to greedy, fewer dispatches
  --cache-layout paged --page-size N
                      page-pool KV cache: block tables are data, and with
                      --max-slots a repeated prompt rides shared pages
  --kv-bits 4         packed int4 KV cache (quarter of bf16 cache bytes;
                      the fused kernels unpack nibbles in their dequant
                      epilogue) — pair with --finetune-thresholds N to
                      distill-train the per-head thresholds (paper §3)
                      before freezing, which is what keeps the 7-level
                      grid accurate

Run: PYTHONPATH=src python examples/serve_int8.py
"""
import sys

import numpy as np

from repro.launch import serve


def main():
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_int8 = serve.main()
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke", "--fp",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_fp = serve.main()
    same = (out_int8 == out_fp).mean()
    print(f"int8 vs bf16 generated-token agreement: {float(same):.2f}")

    # chunked prefill (4 chunks of 8) + nucleus sampling: same engine, one
    # executable for every prompt length up to the pad, sampled tokens
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8",
                "--prefill-chunk", "8", "--temperature", "0.8",
                "--top-p", "0.9"]
    serve.main()

    # continuous batching: 6 ragged requests stream through 2 cache slots
    # (admission runs the chunked prefill into whichever slot freed up);
    # the printed executable counts must all be 1 — no retrace, however
    # the queue happens to drain
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "6", "--prompt-len", "32", "--gen", "8",
                "--max-slots", "2", "--prefill-chunk", "8",
                "--block-steps", "4"]
    serve.main()

    # speculative decoding (DecodeStrategy protocol): prompt-lookup
    # drafting + ONE batched verify pass per window over the int8 cache.
    # Deterministic acceptance makes the tokens BIT-IDENTICAL to greedy
    # — speculation only changes how many decode dispatches they cost
    # (the printed acceptance rate is the payoff; repetitive text
    # accepts more)
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8",
                "--max-slots", "2", "--prefill-chunk", "8",
                "--strategy", "speculative", "--spec-k", "4",
                "--spec-ngram", "2"]
    serve.main()

    # int4 KV cache with distill-trained thresholds: the cache stores
    # packed nibbles (half the int8 bytes, a quarter of bf16) and one
    # epoch of §3 threshold training repairs what 7-level max-abs
    # calibration loses
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8",
                "--kv-bits", "4", "--finetune-thresholds", "1"]
    serve.main()

    # the Engine facade + paged prefix sharing: three IDENTICAL prompts
    # through a paged scheduler — the second and third admissions attach
    # the registered prompt's shared pages and run ZERO prefill FLOPs
    from repro.launch.engine import Engine
    from repro.launch.scheduler import Request

    engine = Engine.from_checkpoint("smollm-135m", smoke=True,
                                    cache_layout="paged", page_size=8,
                                    prefill_chunk=8)
    prompt = np.arange(1, 25, dtype=np.int32) % engine.cfg.vocab
    reqs = [Request(rid=r, tokens=prompt, max_gen=6) for r in range(3)]
    done = engine.generate(reqs, max_slots=2)
    sched = engine.make_scheduler(max_slots=2, prompt_cap=len(prompt),
                                  gen_cap=6)
    stats = sched.prefix_stats()
    calls = sched.call_counts()
    print(f"[engine] paged prefix sharing: {len(done)} identical prompts, "
          f"{calls['prefill']} prefill call(s), {stats['hits']} hits, "
          f"{stats['shared_tokens']} prompt tokens reused")
    assert calls["prefill"] == 1 and stats["hits"] == 2
    assert len({tuple(c.tokens) for c in done}) == 1, \
        "identical prompts must generate identical tokens"


if __name__ == "__main__":
    main()
