"""Serve a FAT-quantized model with batched requests (int8 weights).

Wraps repro.launch.serve: calibrates, converts to int8, then runs batched
prefill + greedy decode, comparing int8 against the bf16 baseline.

Run: PYTHONPATH=src python examples/serve_int8.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_int8 = serve.main()
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke", "--fp",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_fp = serve.main()
    same = (out_int8 == out_fp).mean()
    print(f"int8 vs bf16 generated-token agreement: {float(same):.2f}")


if __name__ == "__main__":
    main()
