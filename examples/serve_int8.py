"""Serve a FAT-quantized model with batched requests (int8 weights).

Wraps repro.launch.serve: calibrates, converts to int8, then runs batched
prefill + greedy decode, comparing int8 against the bf16 baseline,
demonstrates the chunked ragged prefill pipeline with sampled decoding,
and finishes with the continuous-batching scheduler: a ragged request
queue streaming through a fixed set of cache slots.

Useful serve flags (see repro/launch/serve.py and the README flag
reference for the full list):
  --prefill-chunk N   chunked ragged prefill: one lax.scan over fixed-size
                      prompt chunks + a per-request length vector, so one
                      compiled executable serves any prompt length
  --temperature T     sampled decoding (0 = greedy); --top-p P restricts
                      sampling to the nucleus of probability mass P
  --pallas            fused Pallas kernels: flash-prefill AND flash-decode
                      attend directly over the int8 KV cache tiles
  --max-slots N       continuous batching (launch/scheduler.py): requests
                      are admitted into free cache slots as they drain,
                      every slot decodes at its own position, and ONE
                      compiled decode executable serves the whole ragged
                      run (--block-steps / --eos-id tune the scheduler)

Run: PYTHONPATH=src python examples/serve_int8.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_int8 = serve.main()
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke", "--fp",
                "--requests", "4", "--prompt-len", "32", "--gen", "8"]
    out_fp = serve.main()
    same = (out_int8 == out_fp).mean()
    print(f"int8 vs bf16 generated-token agreement: {float(same):.2f}")

    # chunked prefill (4 chunks of 8) + nucleus sampling: same engine, one
    # executable for every prompt length up to the pad, sampled tokens
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "4", "--prompt-len", "32", "--gen", "8",
                "--prefill-chunk", "8", "--temperature", "0.8",
                "--top-p", "0.9"]
    serve.main()

    # continuous batching: 6 ragged requests stream through 2 cache slots
    # (admission runs the chunked prefill into whichever slot freed up);
    # the printed executable counts must all be 1 — no retrace, however
    # the queue happens to drain
    sys.argv = ["serve", "--arch", "smollm-135m", "--smoke",
                "--requests", "6", "--prompt-len", "32", "--gen", "8",
                "--max-slots", "2", "--prefill-chunk", "8",
                "--block-steps", "4"]
    serve.main()


if __name__ == "__main__":
    main()
