"""End-to-end driver: FAT-quantize a ~100M-param model with a few hundred
distillation steps (the paper's §4.1.2 procedure at CPU-feasible scale).

The model is the real smollm-135m architecture at a narrow width
(~35M params on CPU in reasonable time; pass --full for the 135M config).
Demonstrates: calibration -> threshold training with cosine annealing +
optimizer resets -> checkpoint/restart fault tolerance -> int8 export.

Run: PYTHONPATH=src python examples/train_fat_qat.py [--steps 200]
"""
import argparse
import os
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.core import api as A
from repro.data import pipeline as DP
from repro.launch import steps as ST
from repro.models import build_model
from repro.optim.adam import adam_init, restart_boundary, reset_moments


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200,
                    help="fine-tuning steps")
    ap.add_argument("--full", action="store_true",
                    help="real 135M config (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/fat_qat_ckpt",
                    help="checkpoint directory")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("smollm-135m")
    else:
        # same family, ~100M-class structure at CPU-friendly width
        cfg = get_config("smollm-135m").replace(
            n_layers=8, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
            d_ff=768, vocab=8192)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    policy = A.QuantPolicy()
    spec = DP.spec_for(cfg, ShapeSpec("ex", "train", 128, 8))

    shutil.rmtree(args.ckpt, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt, keep=2)

    qparams = A.init_qparams(model, params, policy)
    calibrate = jax.jit(ST.make_calibrate_step(model, cfg, policy))
    for batch in DP.calibration_batches(spec, n=4):
        qparams = calibrate(params, qparams, batch)
    qparams = A.finalize_calibration(qparams, policy)

    hp = ST.TrainHParams(base_lr=2e-3, anneal_period=50)
    train_step = jax.jit(ST.make_fat_train_step(model, cfg, policy, hp))
    opt = adam_init(qparams)

    n_train = sum(
        x.size for m, x in zip(jax.tree.leaves(A.trainable_mask(qparams)),
                               jax.tree.leaves(qparams)) if m)
    print(f"training {n_train} threshold scales "
          f"({100*n_train/max(n_params,1):.4f}% of model) — "
          "the 'fast' in FAT")

    first = last = None
    for step in range(args.steps):
        # the paper's cosine annealing restarts also reset Adam moments
        if restart_boundary(step, hp.anneal_period):
            opt = reset_moments(opt)
        batch = DP.make_batch(spec, step)
        qparams, opt, m = train_step(params, qparams, opt, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 20 == 0:
            print(f"step {step:4d}  RMSE {loss:.5f}  lr {float(m['lr']):.2e}")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"qparams": qparams,
                                "opt": {"step": opt.step, "mu": opt.mu,
                                        "nu": opt.nu}})

    print(f"distill RMSE: {first:.5f} -> {last:.5f} "
          f"({100*(1-last/first):.1f}% better)")
    serve_params = A.convert_to_int8(model, params, qparams, policy)
    n_int8 = sum(l.size for l in jax.tree.leaves(serve_params)
                 if l.dtype == jnp.int8)
    print(f"exported int8 model: {n_int8/1e6:.1f}M int8 weights")
    assert last < first


if __name__ == "__main__":
    main()
