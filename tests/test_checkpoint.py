"""CheckpointManager contract tests — error paths included.

The manager was written for training restarts; the durability layer
(scheduler ``save_state``/``load_state``) now routes SERVING state
through it too, so its fault-tolerance contract — atomic temp-dir +
rename writes, a COMMITTED marker gating visibility, keep-N GC, and
restore_latest falling back past incomplete checkpoints — is
load-bearing twice over and pinned here.
"""
import json
import os
import shutil

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, _flatten, _unflatten


def _tree(step):
    return {"params": {"w": np.full((2, 3), float(step)),
                       "b": np.arange(3.0)},
            "nested": [np.ones((1,)), np.zeros((2,))]}


class TestRoundTrip:
    def test_save_restore_tree_and_metadata(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(5, _tree(5), metadata={"note": "hello", "knobs": {"a": 1}})
        tree, meta = mgr.restore(5)
        np.testing.assert_array_equal(tree["params"]["w"],
                                      np.full((2, 3), 5.0))
        # list/tuple nodes come back as string-keyed dicts (flatten
        # addressing) — contents survive bit-exact
        np.testing.assert_array_equal(tree["nested"]["0"], np.ones((1,)))
        assert meta["note"] == "hello"
        assert meta["knobs"] == {"a": 1}
        assert meta["step"] == 5      # stamped by save()

    def test_flatten_unflatten_inverse(self):
        tree = {"a": {"b": 1, "c/with/slashes": 2}, "d": 3}
        assert _unflatten(_flatten(tree)) == tree

    def test_restore_latest_picks_newest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 3, 2):
            mgr.save(s, _tree(s))
        tree, meta = mgr.restore_latest()
        assert meta["step"] == 3
        np.testing.assert_array_equal(tree["params"]["w"],
                                      np.full((2, 3), 3.0))


class TestErrorPaths:
    def test_restore_latest_empty_dir(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest() == (None, None)
        assert mgr.list_steps() == []

    def test_partial_checkpoint_invisible(self, tmp_path):
        """A checkpoint dir without the COMMITTED marker (killed writer)
        is skipped: restore_latest falls back to the newest COMPLETE
        one."""
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, _tree(1))
        # hand-build a half-written step 2: arrays but no marker
        partial = tmp_path / "ckpt_0000000002"
        partial.mkdir()
        np.savez(partial / "arrays.npz", x=np.ones(3))
        assert mgr.list_steps() == [1]
        _, meta = mgr.restore_latest()
        assert meta["step"] == 1

    def test_corrupt_arrays_raise(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        path = mgr.save(1, _tree(1))
        with open(os.path.join(path, "arrays.npz"), "wb") as f:
            f.write(b"not an npz")
        with pytest.raises(Exception):
            mgr.restore(1)

    def test_metadata_must_be_json(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(TypeError):
            mgr.save(1, _tree(1), metadata={"bad": np.ones((2,))})
        # the failed save left no visible checkpoint behind
        assert mgr.list_steps() == []

    def test_failed_save_leaves_no_temp_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(TypeError):
            mgr.save(1, _tree(1), metadata={"bad": object()})
        assert [d for d in os.listdir(tmp_path)
                if d.startswith(".tmp_")] == []


class TestKeepN:
    def test_gc_keeps_newest_n(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(1, 5):
            mgr.save(s, _tree(s))
        assert mgr.list_steps() == [3, 4]
        assert sorted(os.listdir(tmp_path)) == ["ckpt_0000000003",
                                                "ckpt_0000000004"]

    def test_keep_zero_disables_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=0)
        for s in range(1, 4):
            mgr.save(s, _tree(s))
        assert mgr.list_steps() == [1, 2, 3]
