"""Unit tests for the paper's quantization math (eqs. 1-23)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Q
from repro.core import calibration as C


SPEC_SYM = Q.QuantSpec(bits=8, symmetric=True)
SPEC_ASYM = Q.QuantSpec(bits=8, symmetric=False)


class TestSTE:
    def test_round_forward(self):
        x = jnp.array([0.4, 0.6, -1.5, 2.5])
        np.testing.assert_allclose(Q.ste_round(x), jnp.round(x))

    def test_round_gradient_is_identity(self):
        # eq. 17: dI_q / dI = 1
        g = jax.grad(lambda x: jnp.sum(Q.ste_round(x) * 3.0))(jnp.arange(4.0))
        np.testing.assert_allclose(g, 3.0 * jnp.ones(4))

    def test_clip_gradient(self):
        # eq. 19: 1 inside [a, b], 0 outside
        x = jnp.array([-2.0, 0.0, 2.0])
        g = jax.grad(lambda x: jnp.sum(Q.clip_grad_passthrough(x, -1.0, 1.0)))(x)
        np.testing.assert_allclose(g, jnp.array([0.0, 1.0, 0.0]))


class TestSymmetric:
    def test_roundtrip_error_bound(self, ):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(128, 64)), jnp.float32)
        t = Q.max_abs_threshold(x, SPEC_SYM)
        y = Q.fake_quant_symmetric(x, t, jnp.ones(()), SPEC_SYM)
        # max error is half a quantization step
        step = t / SPEC_SYM.levels
        assert float(jnp.max(jnp.abs(x - y))) <= float(step) / 2 + 1e-6

    def test_alpha_scales_threshold(self):
        # alpha = 0.5 halves the threshold -> values above T/2 saturate
        x = jnp.array([1.0, 0.25])
        t = jnp.array(1.0)
        y = Q.fake_quant_symmetric(x, t, jnp.asarray(0.5), SPEC_SYM)
        assert float(y[0]) == pytest.approx(0.5, rel=1e-3)  # clipped
        assert float(y[1]) == pytest.approx(0.25, abs=0.5 / 127)

    def test_alpha_clip_range(self):
        # eq. 12: alpha saturates at [0.5, 1.0] -> alpha=0.1 behaves as 0.5
        x = jnp.linspace(-1, 1, 11)
        t = jnp.array(1.0)
        y1 = Q.fake_quant_symmetric(x, t, jnp.asarray(0.1), SPEC_SYM)
        y2 = Q.fake_quant_symmetric(x, t, jnp.asarray(0.5), SPEC_SYM)
        np.testing.assert_allclose(y1, y2)

    def test_gradient_flows_to_alpha(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(64,)), jnp.float32)
        t = Q.max_abs_threshold(x, SPEC_SYM)

        def loss(a):
            return jnp.sum(Q.fake_quant_symmetric(x, t, a, SPEC_SYM) ** 2)

        g = jax.grad(loss)(jnp.asarray(0.8))
        assert np.isfinite(g) and g != 0

    def test_per_channel_vector_mode(self):
        # §3.1.5: per-channel thresholds quantize each filter on its own scale
        spec = Q.QuantSpec(bits=8, symmetric=True, per_channel=True,
                           channel_axis=-1)
        x = jnp.stack([jnp.linspace(-1, 1, 32), 100 * jnp.linspace(-1, 1, 32)],
                      axis=-1)
        t = Q.max_abs_threshold(x, spec)
        assert t.shape == (2,)
        y = Q.fake_quant_symmetric(x, t, jnp.ones(2), spec)
        # both channels keep fine resolution despite 100x range difference
        for c, tc in enumerate(t):
            err = jnp.max(jnp.abs(x[:, c] - y[:, c]))
            assert float(err) <= float(tc) / 127 / 2 + 1e-5

    def test_unsigned_range(self):
        spec = Q.QuantSpec(bits=8, symmetric=True, unsigned=True)
        assert spec.levels == 255 and spec.qmin == 0 and spec.qmax == 255
        x = jnp.linspace(0, 6, 100)  # post-relu6 activations
        y = Q.fake_quant_symmetric(x, jnp.asarray(6.0), jnp.ones(()), spec)
        assert float(jnp.max(jnp.abs(x - y))) <= 6.0 / 255 / 2 + 1e-6


class TestAsymmetric:
    def test_limits_eq_21_23(self):
        # alpha_t=0, alpha_r=1 reproduce the calibrated limits exactly
        spec = SPEC_ASYM
        left, width = Q.asymmetric_limits(
            jnp.asarray(-2.0), jnp.asarray(6.0), jnp.asarray(0.0),
            jnp.asarray(1.0), spec)
        assert float(left) == -2.0 and float(width) == 8.0

    def test_alpha_t_range_signed_vs_unsigned(self):
        signed = Q.QuantSpec(bits=8, symmetric=False, unsigned=False)
        unsigned = Q.QuantSpec(bits=8, symmetric=False, unsigned=True)
        assert signed.signed_alpha_t_range() == (-0.2, 0.4)
        assert unsigned.signed_alpha_t_range() == (0.0, 0.4)

    def test_asym_beats_sym_on_shifted_data(self):
        # the paper's motivation for §3.1.4: one-sided distributions waste
        # half the symmetric integer range
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=4096) * 0.1 + 3.0, jnp.float32)
        t_sym = Q.max_abs_threshold(x, SPEC_SYM)
        y_sym = Q.fake_quant_symmetric(x, t_sym, jnp.ones(()), SPEC_SYM)
        y_asym = Q.fake_quant_asymmetric(
            x, jnp.min(x), jnp.max(x), jnp.zeros(()), jnp.ones(()), SPEC_ASYM)
        e_sym = float(jnp.mean((x - y_sym) ** 2))
        e_asym = float(jnp.mean((x - y_asym) ** 2))
        assert e_asym < e_sym

    def test_gradients_flow_to_both_alphas(self):
        x = jnp.asarray(np.random.default_rng(3).normal(size=(64,)), jnp.float32)

        def loss(at, ar):
            y = Q.fake_quant_asymmetric(x, jnp.min(x), jnp.max(x), at, ar,
                                        SPEC_ASYM)
            return jnp.sum((y - x) ** 2)

        gt, gr = jax.grad(loss, argnums=(0, 1))(jnp.asarray(0.1), jnp.asarray(0.8))
        assert np.isfinite(gt) and np.isfinite(gr)
        assert gr != 0


class TestIntegerPath:
    def test_weights_int8_reconstruction(self):
        w = jnp.asarray(np.random.default_rng(4).normal(size=(64, 32)), jnp.float32)
        spec = Q.QuantSpec(bits=8, symmetric=True, per_channel=True,
                           channel_axis=-1)
        t = Q.max_abs_threshold(w, spec)
        w_q, scale = Q.quantize_weights_int8(w, t, jnp.ones_like(t), spec)
        assert w_q.dtype == jnp.int8
        w_rec = w_q.astype(jnp.float32) * scale
        step = t / 127
        assert float(jnp.max(jnp.abs(w - w_rec) / step)) <= 0.51

    def test_bias_int32_eq20(self):
        b = jnp.asarray([0.5, -0.25, 1e6], jnp.float32)
        act_scale = jnp.asarray(0.01)
        w_scale = jnp.asarray([0.002, 0.002, 1e-12], jnp.float32)
        b_q = Q.quantize_bias_int32(b, act_scale, w_scale)
        assert b_q.dtype == jnp.int32
        np.testing.assert_allclose(b_q[0], round(0.5 / (0.01 * 0.002)))
        assert int(b_q[2]) == 2**31 - 1  # clipped at int32 max (eq. 20)

    def test_pointwise_scale_clip(self):
        w = jnp.ones((4,))
        p = jnp.asarray([0.1, 0.9, 1.1, 2.0])
        y = Q.apply_pointwise_scale(w, p)
        np.testing.assert_allclose(y, [0.75, 0.9, 1.1, 1.25])


class TestCalibration:
    def test_max_abs_observer(self):
        spec = SPEC_SYM
        obs = C.init_observer(spec)
        for scale in (1.0, 5.0, 2.0):
            x = jnp.asarray(np.random.default_rng(5).normal(size=64) * scale)
            obs = C.update_observer(obs, x, spec)
        th = C.observer_thresholds(obs, spec)
        assert float(th["t_max"]) > 0
        assert int(obs["count"]) == 3
        # running max is monotone >= last batch max
        assert float(th["t_max"]) >= float(jnp.max(jnp.abs(x)))

    def test_min_max_for_asymmetric(self):
        spec = SPEC_ASYM
        obs = C.init_observer(spec)
        x = jnp.asarray([-1.0, 4.0])
        obs = C.update_observer(obs, x, spec)
        th = C.observer_thresholds(obs, spec)
        assert float(th["t_l"]) == -1.0 and float(th["t_r"]) == 4.0
        # initial trained scales: alpha_t=0, alpha_r=1 (§3.1.4)
        assert float(th["alpha_t"]) == 0.0 and float(th["alpha_r"]) == 1.0

    def test_percentile_observer_robust_to_outlier(self):
        spec = SPEC_SYM
        rng = np.random.default_rng(6)
        x = rng.normal(size=100000).astype(np.float32)
        x[0] = 1000.0  # the paper's Figure 1 outlier scenario
        obs_max = C.update_observer(C.init_observer(spec), jnp.asarray(x), spec)
        obs_pct = C.update_observer(C.init_observer(spec), jnp.asarray(x), spec,
                                    kind="percentile", percentile=99.9)
        assert float(obs_max["t_max"]) == pytest.approx(1000.0)
        assert float(obs_pct["t_max"]) < 10.0


class TestZeroActivationCalibration:
    """A dead KV channel — all-zero calibration activations, or a
    NaN-poisoned observer — must not reach the serving path as a zero or
    non-finite dequant scale: dividing by it in quantize_kv would turn
    the whole int8 cache into inf/NaN.  The floor lives in TWO places
    (both tested): finalize_calibration's KV branch and the cache's
    with_scales entry point."""

    def test_finalize_floors_zero_and_nan_kv_thresholds(self):
        from repro.core import api as A

        policy = A.QuantPolicy(kv_int8=True)
        qp = {"blocks/0/attn/kv": {
            "k": {"t_max": jnp.asarray([0.0, 0.5])},       # dead channel
            "v": {"t_max": jnp.asarray([jnp.nan, 2.0])},   # poisoned obs
        }}
        out = A.finalize_calibration(qp, policy)
        tk = np.asarray(out["blocks/0/attn/kv"]["k"]["t_max"])
        tv = np.asarray(out["blocks/0/attn/kv"]["v"]["t_max"])
        assert np.isfinite(tk).all() and np.isfinite(tv).all()
        assert (tk > 0).all() and (tv > 0).all()
        # healthy thresholds pass through bit-identically
        assert tk[1] == pytest.approx(0.5) and tv[1] == pytest.approx(2.0)

    def test_with_scales_floor_keeps_cache_finite(self):
        from repro.cache import DenseCache
        from repro.cache.base import _SCALE_FLOOR

        cache = DenseCache.init(1, 8, 2, 4, dtype=jnp.int8, quantized=True)
        cache = cache.with_scales(jnp.asarray([0.0, 0.25]),
                                  jnp.asarray([jnp.nan, 0.25]))
        ks, vs = cache.scales()
        assert float(ks[0]) == pytest.approx(_SCALE_FLOOR)
        assert float(vs[0]) == pytest.approx(_SCALE_FLOOR)
        assert float(ks[1]) == pytest.approx(0.25)   # healthy untouched
        # the end-to-end hazard: a zero-activation batch through a floored
        # cache quantizes and dequantizes to exact finite zeros
        x = jnp.zeros((1, 3, 2, 4))
        kq, vq = cache.ready(x, x)
        assert np.isfinite(np.asarray(kq)).all()
        deq_k, deq_v = cache.dequantize(kq, vq)
        np.testing.assert_array_equal(np.asarray(deq_k), 0.0)
        np.testing.assert_array_equal(np.asarray(deq_v), 0.0)
