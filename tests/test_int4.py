"""int4 packed KV cache (kv_bits=4) and int4 weight matmul.

Acceptance pins for the PR's int4 lane:

  * nibble pack/unpack is lossless for every int4 value and odd lengths;
  * the fused Pallas kernels at ``kv_bits=4`` bit-match the jnp reference
    dequant oracle across dense/ring/paged kernel views (the unpack is
    folded into the dequant epilogue — index maps unchanged);
  * packed nibbles survive paged copy-on-rewind rollback and the
    ``state_dict`` round-trip (pre-int4 snapshots default to 8 bits);
  * mixed precision — int8 weights, int4 KV — passes scheduler
    single-stream parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (DenseCache, KVCache as KVCacheBase, PagedCache,
                         RingCache, make_cache, set_table_row)
from repro.cache.base import (_safe_scale, dequantize_kv, kv_levels,
                              quantize_kv)
from repro.configs import get_config
from repro.core import api as A
from repro.core import quant as Q
from repro.core.packing import pack_int4, unpack_int4
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.launch import steps as ST
from repro.launch.scheduler import Request, SlotScheduler
from repro.models import build_model


def _int4_tiles(key, b, s, kv=2, d=8):
    """Random PACKED int4 tiles: d logical values -> d//2 storage bytes."""
    raw = jax.random.randint(key, (b, s, kv, d), -7, 8, jnp.int8)
    return pack_int4(raw, axis=-1), raw


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


class TestPacking:
    def test_all_sixteen_nibbles_roundtrip(self):
        x = jnp.arange(-8, 8, dtype=jnp.int8)
        p = pack_int4(x)
        assert p.shape == (8,) and p.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(unpack_int4(p)),
                                      np.asarray(x))

    @pytest.mark.parametrize("n", [1, 3, 5, 7, 15, 33])
    def test_odd_lengths_pad_and_slice_back(self, n):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.integers(-8, 8, size=(n,)), jnp.int8)
        p = pack_int4(x)
        assert p.shape == ((n + 1) // 2,)
        np.testing.assert_array_equal(np.asarray(unpack_int4(p, size=n)),
                                      np.asarray(x))

    def test_other_axis(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-8, 8, size=(6, 5)), jnp.int8)
        p = pack_int4(x, axis=0)
        assert p.shape == (3, 5)
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(p, axis=0, size=6)), np.asarray(x))

    def test_nd_cache_shape(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.integers(-8, 8, size=(2, 16, 3, 8)), jnp.int8)
        p = pack_int4(x, axis=-1)
        assert p.shape == (2, 16, 3, 4)
        np.testing.assert_array_equal(np.asarray(unpack_int4(p, axis=-1)),
                                      np.asarray(x))


# ---------------------------------------------------------------------------
# quantize/dequantize + scale floor
# ---------------------------------------------------------------------------


class TestQuantizeKV:
    def test_kv_levels(self):
        assert kv_levels(8) == 127.0 and kv_levels(4) == 7.0
        with pytest.raises(ValueError, match="bits"):
            kv_levels(2)

    def test_int4_error_bounded_by_step(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
        t = jnp.max(jnp.abs(x), axis=(0, 1, 3))          # per-head T
        scale = t / kv_levels(4)
        q = quantize_kv(x, scale, bits=4)
        assert q.shape == (1, 32, 2, 4)                   # packed bytes
        y = dequantize_kv(q, scale, bits=4)
        err = jnp.abs(x - y)
        for h in range(2):
            assert float(jnp.max(err[:, :, h])) <= float(scale[h]) / 2 + 1e-6

    def test_int4_clips_to_seven_levels(self):
        x = jnp.full((1, 1, 1, 2), 100.0, jnp.float32)
        scale = jnp.ones((1,), jnp.float32)
        q = quantize_kv(x, scale, bits=4)
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(q, axis=-1)), 7)

    def test_scale_floor_handles_degenerate_calibration(self):
        # PR 6's threshold floor, extended to int4: zero and NaN scales
        # clamp to a positive floor -> quantize/dequantize stay finite
        bad = jnp.asarray([0.0, jnp.nan], jnp.float32)
        safe = _safe_scale(bad)
        assert bool(jnp.all(safe > 0)) and bool(jnp.all(jnp.isfinite(safe)))
        cache = DenseCache.init(1, 8, 2, 8, dtype=jnp.int8, quantized=True,
                                bits=4).with_scales(bad, bad)
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 8))
        kq, vq = cache.ready(x, x)
        k, v = cache.dequantize(kq, vq)
        assert bool(jnp.all(jnp.isfinite(k))) and bool(
            jnp.all(jnp.isfinite(v)))


# ---------------------------------------------------------------------------
# cache layouts
# ---------------------------------------------------------------------------


class TestInt4Layouts:
    def test_storage_halved_and_odd_head_dim_rejected(self):
        c8 = DenseCache.init(1, 16, 2, 8, dtype=jnp.int8, quantized=True)
        c4 = DenseCache.init(1, 16, 2, 8, dtype=jnp.int8, quantized=True,
                             bits=4)
        assert c8.k.shape[-1] == 8 and c4.k.shape[-1] == 4
        assert c4.bits == 4 and c4.kernel_view().bits == 4
        with pytest.raises(ValueError, match="even head dim"):
            DenseCache.init(1, 16, 2, 7, dtype=jnp.int8, quantized=True,
                            bits=4)
        with pytest.raises(ValueError, match="bits"):
            make_cache(1, 16, n_kv=2, head_dim=8, dtype=jnp.int8,
                       quantized=True, bits=3)

    def test_layouts_dequantize_identically(self):
        """The same float K/V readied+appended into dense, ring (window ==
        capacity) and paged int4 caches dequantize to the same tensors."""
        b, s, kv, d = 2, 32, 2, 8
        x = jax.random.normal(jax.random.PRNGKey(0), (b, s, kv, d))
        scale = jnp.max(jnp.abs(x), axis=(0, 1, 3)) / kv_levels(4)
        outs = {}
        for name, cache in (
            ("dense", DenseCache.init(b, s, kv, d, dtype=jnp.int8,
                                      quantized=True, bits=4)),
            ("ring", RingCache.init(b, s, kv, d, dtype=jnp.int8,
                                    quantized=True, bits=4)),
            ("paged", PagedCache.init(b, s, kv, d, dtype=jnp.int8,
                                      quantized=True, bits=4, page_size=8)),
        ):
            cache = cache.with_scales(scale, scale)
            kq, vq = cache.ready(x, x)
            cache = cache.append(kq, vq, 0)
            outs[name] = cache.dequantize(*cache.dense_view())
        for name in ("ring", "paged"):
            np.testing.assert_array_equal(np.asarray(outs["dense"][0]),
                                          np.asarray(outs[name][0]))
            np.testing.assert_array_equal(np.asarray(outs["dense"][1]),
                                          np.asarray(outs[name][1]))

    def test_state_dict_roundtrip_preserves_nibbles(self):
        cache = PagedCache.init(1, 32, 2, 8, dtype=jnp.int8, quantized=True,
                                bits=4, page_size=8)
        kq, raw = _int4_tiles(jax.random.PRNGKey(0), 1, 32)
        cache = cache.append(kq, kq, 0)
        back = PagedCache.from_state_dict(cache.state_dict())
        assert back.bits == 4
        np.testing.assert_array_equal(np.asarray(back.k),
                                      np.asarray(cache.k))
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(back.dense_view()[0], axis=-1)),
            np.asarray(raw))

    def test_state_dict_backcompat_defaults_to_int8(self):
        cache = DenseCache.init(1, 8, 2, 8, dtype=jnp.int8, quantized=True)
        sd = cache.state_dict()
        del sd["static"]["bits"]          # pre-int4 snapshot shape
        back = DenseCache.from_state_dict(sd)
        assert back.bits == 8


class TestInt4Rollback:
    def test_paged_copy_on_rewind_preserves_packed_nibbles(self):
        """Speculative rollback into a shared page at bits=4: the copied
        boundary block must carry the packed bytes verbatim (the rewind
        operates on storage bytes, never on logical elements)."""
        ps, nb, d = 8, 4, 8
        cache = PagedCache.init(1, nb * ps, 1, d, dtype=jnp.int8,
                                quantized=True, bits=4, page_size=ps,
                                extra_pages=2)
        fill = (jnp.arange(cache.k.size, dtype=jnp.int32) % 101 - 50
                ).astype(jnp.int8)
        cache = dataclasses.replace(cache, k=fill.reshape(cache.k.shape),
                                    v=(-fill).reshape(cache.v.shape))
        assert cache.k.shape[-1] == d // 2
        shared_page = nb
        private = jnp.arange(nb, dtype=jnp.int32)[None]
        cache = set_table_row(cache, 0,
                              private.at[0, 0].set(shared_page)[0])
        shared_k = np.asarray(cache.k[shared_page]).copy()
        rolled = cache.rollback(jnp.asarray([2], jnp.int32),
                                private_row=private)
        np.testing.assert_array_equal(np.asarray(rolled.k[shared_page]),
                                      shared_k)
        np.testing.assert_array_equal(np.asarray(rolled.k[0]), shared_k)
        # append after the rewind lands in private storage, packed
        kq, raw = _int4_tiles(jax.random.PRNGKey(7), 1, 2, kv=1, d=d)
        after = rolled.append_slots(kq, kq, jnp.asarray([2], jnp.int32))
        np.testing.assert_array_equal(np.asarray(after.k[shared_page]),
                                      shared_k)
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(after.k[0, 2:4], axis=-1)),
            np.asarray(raw[0]))


# ---------------------------------------------------------------------------
# kernel parity vs the jnp dequant oracle
# ---------------------------------------------------------------------------


def _scales(rng, kv):
    return jnp.asarray(np.abs(rng.normal(size=(kv,))) * 0.1 + 0.05,
                       jnp.float32)


class TestInt4KernelParity:
    @pytest.mark.parametrize("pos", [1, 17, 40])
    def test_decode_matches_oracle(self, pos):
        rng = np.random.default_rng(0)
        b, s, kv, g, d = 2, 40, 3, 4, 16
        q = jnp.asarray(rng.normal(size=(b, kv, g, d)), jnp.float32)
        k, _ = _int4_tiles(jax.random.PRNGKey(1), b, s, kv, d)
        v, _ = _int4_tiles(jax.random.PRNGKey(2), b, s, kv, d)
        ks, vs = _scales(rng, kv), _scales(rng, kv)
        got = ops.decode_attention(q, k, v, ks, vs, jnp.int32(pos),
                                   block_s=16, kv_bits=4)
        want = kref.decode_attention_ref(q, k, v, ks, vs, pos, kv_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_oracle_equals_unpacked_int8_oracle(self):
        """The int4 oracle IS the int8 oracle on pre-unpacked tiles —
        pins that the only difference is the nibble unpack."""
        rng = np.random.default_rng(3)
        b, s, kv, g, d = 1, 32, 2, 2, 8
        q = jnp.asarray(rng.normal(size=(b, kv, g, d)), jnp.float32)
        k, kraw = _int4_tiles(jax.random.PRNGKey(3), b, s, kv, d)
        v, vraw = _int4_tiles(jax.random.PRNGKey(4), b, s, kv, d)
        ks, vs = _scales(rng, kv), _scales(rng, kv)
        a = kref.decode_attention_ref(q, k, v, ks, vs, 17, kv_bits=4)
        b_ = kref.decode_attention_ref(q, kraw, vraw, ks, vs, 17)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))

    def test_prefill_matches_oracle(self):
        rng = np.random.default_rng(1)
        b, sq, sk, kv, g, d = 2, 32, 32, 2, 2, 16
        q = jnp.asarray(rng.normal(size=(b, sq, kv, g, d)), jnp.float32)
        k, _ = _int4_tiles(jax.random.PRNGKey(5), b, sk, kv, d)
        v, _ = _int4_tiles(jax.random.PRNGKey(6), b, sk, kv, d)
        ks, vs = _scales(rng, kv), _scales(rng, kv)
        kl = jnp.asarray([32, 20], jnp.int32)
        got = ops.prefill_attention(q, k, v, ks, vs, jnp.int32(0), kl,
                                    kv_bits=4)
        want = kref.prefill_attention_ref(q, k, v, ks, vs, 0, kl, kv_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-2)

    def test_kernel_views_dense_ring_paged_match(self):
        """One q against the SAME logical contents through all three
        layouts' kernel views: identical decode output (dense/ring use
        the identity table, paged the real one)."""
        b, s, kv, g, d = 1, 128, 2, 2, 8
        x = jax.random.normal(jax.random.PRNGKey(0), (b, s, kv, d))
        scale = jnp.max(jnp.abs(x), axis=(0, 1, 3)) / kv_levels(4)
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(b, kv, g, d)), jnp.float32)
        outs = {}
        for name, cache in (
            ("dense", DenseCache.init(b, s, kv, d, dtype=jnp.int8,
                                      quantized=True, bits=4)),
            ("ring", RingCache.init(b, s, kv, d, dtype=jnp.int8,
                                    quantized=True, bits=4)),
            ("paged", PagedCache.init(b, s, kv, d, dtype=jnp.int8,
                                      quantized=True, bits=4,
                                      page_size=128)),
        ):
            cache = cache.with_scales(scale, scale)
            cache = cache.append(*cache.ready(x, x), 0)
            view = cache.kernel_view()
            assert view.bits == 4
            ksc, vsc = cache.scales()
            outs[name] = np.asarray(ops.decode_attention_view(
                q, view, ksc, vsc, jnp.int32(100)))
        np.testing.assert_array_equal(outs["dense"], outs["ring"])
        np.testing.assert_array_equal(outs["dense"], outs["paged"])
        # and the fused output matches the oracle on the dense tiles
        cache = DenseCache.init(b, s, kv, d, dtype=jnp.int8, quantized=True,
                                bits=4).with_scales(scale, scale)
        cache = cache.append(*cache.ready(x, x), 0)
        want = kref.decode_attention_ref(q, cache.k, cache.v,
                                         *cache.scales(), 100, kv_bits=4)
        np.testing.assert_allclose(outs["dense"], np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_quant_matmul_int4_weights_match_oracle(self):
        rng = np.random.default_rng(5)
        m, k, n = 16, 64, 16
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w_raw = jnp.asarray(rng.integers(-7, 8, size=(k, n)), jnp.int8)
        w_q = pack_int4(w_raw, axis=0)          # (k//2, n) packed bytes
        w_scale = jnp.asarray(np.abs(rng.normal(size=(n,))) * 0.01 + 0.005,
                              jnp.float32)
        act_scale = jnp.float32(127.0 / 3.0)
        got = ops.quant_matmul(x, w_q, w_scale, act_scale, w_bits=4,
                               block_m=16, block_n=16, block_k=32,
                               out_dtype=jnp.float32)
        want = kref.quant_matmul_ref(x, w_q, w_scale, act_scale,
                                     out_dtype=jnp.float32, w_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # oracle parity against the hand-unpacked int8 path
        want8 = kref.quant_matmul_ref(x, w_raw, w_scale, act_scale,
                                      out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(want), np.asarray(want8),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# mixed precision end-to-end: int8 weights, int4 KV
# ---------------------------------------------------------------------------


B, S, GEN = 2, 32, 6
CHUNK = 8


class TestMixedPrecisionParity:
    def test_scheduler_matches_single_stream_int4_kv(self):
        """int8 weights + int4 KV: every request through the continuous-
        batching scheduler generates the same tokens as the single-stream
        pipeline at batch 1."""
        cfg = get_config("smollm-135m", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab)
        policy = A.QuantPolicy(kv_int8=True, kv_bits=4)
        from repro.launch.engine import prepare_int8
        serve_params, qp = prepare_int8(model, cfg, policy, params,
                                        [{"tokens": toks}])

        def single(prompt, n_gen):
            t = np.zeros((1, -(-len(prompt) // CHUNK) * CHUNK), np.int32)
            t[0, :len(prompt)] = prompt
            pre = jax.jit(ST.make_prefill_step(model, cfg, policy,
                                               mode="int8",
                                               prefill_chunk=CHUNK))
            loop = jax.jit(ST.make_decode_loop(model, cfg, policy,
                                               mode="int8", n_steps=n_gen))
            cache = model.init_cache(1, S + GEN + 2, cfg.dtype,
                                     kv_int8=True, kv_bits=4)
            assert all(c.bits == 4 for c in jax.tree.leaves(
                cache, is_leaf=lambda x: isinstance(x, KVCacheBase)))
            lg, cache = pre(serve_params, qp, {"tokens": jnp.asarray(t)},
                            cache, jnp.asarray([len(prompt)], jnp.int32))
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            out, _ = loop(serve_params, qp, tok0, cache, len(prompt))
            return np.asarray(out)[0].tolist()

        lengths = [32, 20, 9]
        reqs = [Request(rid=r, tokens=np.asarray(toks[r % B, :n]),
                        max_gen=GEN) for r, n in enumerate(lengths)]
        sched = SlotScheduler(model, cfg, policy, serve_params, qp,
                              mode="int8", max_slots=2, prompt_cap=S,
                              gen_cap=GEN + 2, prefill_chunk=CHUNK,
                              block_steps=3)
        done = {c.rid: c for c in sched.run(reqs)}
        assert len(done) == len(reqs)
        # the scheduler's batch cache really stores packed int4 tiles
        leaves = jax.tree.leaves(
            sched._cache, is_leaf=lambda x: isinstance(x, KVCacheBase))
        assert leaves and all(c.bits == 4 for c in leaves)
        for r, n in enumerate(lengths):
            want = single(np.asarray(toks[r % B, :n]).tolist(), GEN)
            assert list(done[r].tokens) == want, (r, done[r].tokens, want)
