"""Durability layer: write-ahead request journal, cache state_dict
round-trips, and bit-exact crash recovery (ISSUE 7).

The acceptance property: a crash injected at ANY decode-block boundary,
followed by journal-replay recovery on a FRESH scheduler, yields greedy
completions bit-identical to an uninterrupted run — terminal statuses
preserved, executable counts pinned (recovery rides the existing
``resume`` ragged prefill; no new widths).  Snapshot-mode recovery
(``save_state``/``load_state``/``resume_run``) passes the same parity
test.  Bit-validity is the paper's §2 determinism again: frozen
calibrated thresholds make the int8 KV cache a pure function of the
token sequence, so device state can be RECOMPUTED from journaled tokens.
"""
import json
import shutil

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import api as A
from repro.launch import steps as ST
from repro.launch.faults import FaultPlan, SimulatedCrash
from repro.launch.journal import (JournalReplay, RequestJournal,
                                  completion_from_dict, prompt_hash,
                                  request_from_dict)
from repro.launch.scheduler import Completion, Request, SlotScheduler
from repro.models import build_model

B, S, GEN = 2, 32, 6
CHUNK = 8


@pytest.fixture(scope="module")
def stack():
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True)
    qp = A.init_qparams(model, params, policy)
    qp = ST.make_calibrate_step(model, cfg, policy)(params, qp,
                                                    {"tokens": toks})
    qp = A.finalize_calibration(qp, policy)
    return cfg, model, params, qp, policy, toks


def _scheduler(model, cfg, policy, params, qp, **kw):
    kw.setdefault("mode", "none")
    kw.setdefault("max_slots", 2)
    kw.setdefault("prompt_cap", S)
    kw.setdefault("gen_cap", GEN + 2)
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("block_steps", 3)
    return SlotScheduler(model, cfg, policy, params, qp, **kw)


def _requests(toks, n=3):
    t = np.asarray(toks)
    lens = [12, 20, 8, 16]
    return [Request(rid=r, tokens=t[r % B, :lens[r % 4]], max_gen=GEN)
            for r in range(n)]


def _by_rid(completions):
    return {c.rid: (tuple(c.tokens), c.status, c.finished_by)
            for c in completions}


# -- journal unit tests (pure host — no model) ----------------------------
class TestJournal:
    def _req(self, rid, tokens=(5, 6, 7), **kw):
        return Request(rid=rid, tokens=np.asarray(tokens, np.int32), **kw)

    def test_roundtrip_and_classification(self, tmp_path):
        j = RequestJournal(str(tmp_path / "j.jsonl"))
        j.begin(1, {"max_slots": 2})
        j.enqueue(self._req(0))
        j.enqueue(self._req(1, tokens=(9, 9)))
        j.enqueue(self._req(2, arrive_ms=50.0))
        j.progress(0, [4, 2], np.asarray([1, 2], np.uint32), 3)
        j.retire(Completion(1, 2, [7], "eos"))
        j.block(2, 20.0)
        rp = j.replay()
        assert isinstance(rp, JournalReplay)
        assert rp.epoch == 1 and not rp.recovered
        assert rp.knobs == {"max_slots": 2}
        assert [d["rid"] for d in rp.done] == [1]
        assert [i["req"]["rid"] for i in rp.inflight] == [0]
        assert rp.inflight[0]["out"] == [4, 2]
        assert rp.inflight[0]["key"] == [1, 2]
        assert rp.inflight[0]["steps"] == 3
        assert [q["rid"] for q in rp.queued] == [2]
        assert rp.n_blocks == 2 and rp.vclock == 20.0
        # dict round-trips rebuild the dataclasses faithfully
        r2 = request_from_dict(rp.queued[0])
        assert r2.rid == 2 and r2.arrive_ms == 50.0
        c1 = completion_from_dict(rp.done[0])
        assert (c1.rid, c1.tokens, c1.finished_by) == (1, [7], "eos")

    def test_progress_is_absolute_newest_wins(self, tmp_path):
        j = RequestJournal(str(tmp_path / "j.jsonl"))
        j.begin(1, {})
        j.enqueue(self._req(0))
        j.progress(0, [4], [1, 1], 0)
        j.progress(0, [4, 8, 2], [3, 3], 6)
        rp = j.replay()
        assert rp.inflight[0]["out"] == [4, 8, 2]
        assert rp.inflight[0]["steps"] == 6

    def test_torn_trailing_line_dropped(self, tmp_path):
        p = tmp_path / "j.jsonl"
        j = RequestJournal(str(p))
        j.begin(1, {})
        j.enqueue(self._req(0))
        j.close()
        with open(p, "a") as f:
            f.write('{"t": "progress", "rid": 0, "ou')   # torn write
        rp = RequestJournal(str(p)).replay()
        assert [q["rid"] for q in rp.queued] == [0]
        assert rp.inflight == []

    def test_corrupt_middle_raises(self, tmp_path):
        p = tmp_path / "j.jsonl"
        j = RequestJournal(str(p))
        j.begin(1, {})
        j.enqueue(self._req(0))
        j.close()
        lines = p.read_text().splitlines()
        lines[0] = lines[0][:10]        # damage a NON-trailing record
        p.write_text("\n".join(lines) + "\n")
        with pytest.raises(ValueError, match="corrupt journal record"):
            RequestJournal(str(p)).replay()

    def test_prompt_hash_mismatch_raises(self, tmp_path):
        p = tmp_path / "j.jsonl"
        j = RequestJournal(str(p))
        j.begin(1, {})
        j.enqueue(self._req(0, tokens=(1, 2, 3)))
        j.close()
        text = p.read_text().replace("[1,2,3]", "[1,2,4]")
        p.write_text(text)
        with pytest.raises(ValueError, match="prompt hash mismatch"):
            RequestJournal(str(p)).replay()

    def test_progress_without_enqueue_raises(self, tmp_path):
        j = RequestJournal(str(tmp_path / "j.jsonl"))
        j.begin(1, {})
        j.progress(7, [4], [1, 1], 0)
        with pytest.raises(ValueError, match="without an enqueue"):
            j.replay()

    def test_replay_reads_last_epoch_only(self, tmp_path):
        j = RequestJournal(str(tmp_path / "j.jsonl"))
        j.begin(1, {"a": 1})
        j.enqueue(self._req(0))
        j.retire(Completion(0, 3, [7], "eos"))
        j.begin(2, {"a": 2}, recovered=True)
        j.enqueue(self._req(5))
        rp = j.replay()
        assert rp.epoch == 2 and rp.recovered
        assert rp.knobs == {"a": 2}
        assert rp.done == [] and [q["rid"] for q in rp.queued] == [5]
        assert j.last_epoch() == 2

    def test_missing_or_empty_journal(self, tmp_path):
        j = RequestJournal(str(tmp_path / "nope.jsonl"))
        assert j.last_epoch() == 0
        with pytest.raises(FileNotFoundError):
            j.replay()
        (tmp_path / "empty.jsonl").write_text("")
        with pytest.raises(ValueError, match="no begin record"):
            RequestJournal(str(tmp_path / "empty.jsonl")).replay()

    def test_prompt_hash_deterministic(self):
        assert prompt_hash([1, 2, 3]) == prompt_hash(
            np.asarray([1, 2, 3], np.int32))
        assert prompt_hash([1, 2, 3]) != prompt_hash([1, 2])


# -- fault-plan durability knobs (satellite: duplicate rejection) ---------
class TestFaultPlanCrash:
    def test_crash_normalized_and_queried(self):
        plan = FaultPlan(crash=[3, 1])
        assert plan.crash == (1, 3)
        assert plan.crash_at(1) and plan.crash_at(3)
        assert not plan.crash_at(2)
        assert "crash at block [1, 3]" in plan.describe()

    def test_crash_boundaries_one_based(self):
        with pytest.raises(ValueError, match="1-based"):
            FaultPlan(crash=(0,))

    def test_crash_parses_from_json(self):
        assert FaultPlan.parse('{"crash": [2]}').crash == (2,)

    def test_duplicate_nan_decode_rid_rejected(self):
        with pytest.raises(ValueError, match="exactly one decode step"):
            FaultPlan(nan_decode=[(1, 3), (1, 5)])

    def test_duplicate_preempt_pair_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            FaultPlan(preempt=[(2, 0), (2, 0)])


# -- KVCache / PrefixStore state_dict round-trips -------------------------
class TestCacheStateDict:
    def _filled_dense(self):
        import jax.numpy as jnp

        from repro.cache import DenseCache
        c = DenseCache.init(1, 16, 2, 4, quantized=True)
        rng = np.random.default_rng(0)
        kq = jnp.asarray(rng.integers(-127, 128, (1, 3, 2, 4)), jnp.int8)
        vq = jnp.asarray(rng.integers(-127, 128, (1, 3, 2, 4)), jnp.int8)
        return c.append(kq, vq, 0)

    def test_dense_roundtrip_bit_exact(self):
        from repro.cache import KVCache
        c = self._filled_dense()
        sd = c.state_dict()
        assert sd["layout"] == "dense"
        c2 = KVCache.from_state_dict(sd)
        assert type(c2) is type(c)
        for n in type(c)._child_names():
            np.testing.assert_array_equal(np.asarray(getattr(c, n)),
                                          np.asarray(getattr(c2, n)))
        assert c2._quantized == c._quantized

    def test_paged_roundtrip_keeps_statics(self):
        from repro.cache import KVCache, PagedCache
        c = PagedCache.init(1, 32, 2, 4, quantized=True, page_size=8)
        c2 = KVCache.from_state_dict(c.state_dict())
        assert type(c2) is PagedCache
        assert c2.page_size == 8 and c2._quantized

    def test_from_state_dict_validates(self):
        from repro.cache import KVCache
        sd = self._filled_dense().state_dict()
        with pytest.raises(ValueError, match="unknown cache layout"):
            KVCache.from_state_dict({**sd, "layout": "holographic"})
        broken = {**sd, "arrays": {k: v for k, v in sd["arrays"].items()
                                   if k != "k"}}
        with pytest.raises(ValueError, match="arrays"):
            KVCache.from_state_dict(broken)

    def test_roundtrip_through_checkpoint_manager(self, tmp_path):
        # the npz trip boxes scalars as 0-d arrays — _unbox must undo it
        from repro.cache import KVCache
        from repro.checkpoint.manager import CheckpointManager
        c = self._filled_dense()
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(1, {"c": c.state_dict()}, metadata={})
        tree, _ = mgr.restore_latest()
        c2 = KVCache.from_state_dict(tree["c"])
        np.testing.assert_array_equal(np.asarray(c.k), np.asarray(c2.k))
        assert c2._quantized is True

    def test_prefix_store_roundtrip(self):
        from repro.cache import PrefixEntry, PrefixStore
        ps = PrefixStore(0, 4, 8)
        logits = np.arange(7, dtype=np.float32)[None, :]
        ps.register((1, 2, 3), PrefixEntry(pages=(0,), tail_page=1,
                                           length=10, logits=logits))
        ps.lookup((1, 2, 3), slot=0)     # a hit + a live user
        sd = ps.state_dict()
        ps2 = PrefixStore(0, 4, 8)
        ps2.load_state_dict(sd)
        assert ps2.stats() == ps.stats()
        e = ps2.lookup((1, 2, 3), slot=1)
        assert e is not None and e.length == 10
        np.testing.assert_array_equal(e.logits, logits)
        with pytest.raises(ValueError, match="page_size"):
            PrefixStore(0, 4, 16).load_state_dict(sd)


# -- crash + journal-replay recovery (the acceptance property) ------------
class TestJournalRecovery:
    @pytest.fixture(scope="class")
    def clean(self, stack):
        cfg, model, params, qp, policy, toks = stack
        sched = _scheduler(model, cfg, policy, params, qp)
        return _by_rid(sched.run(_requests(toks)))

    @pytest.mark.parametrize("boundary", [1, 2, 3])
    def test_crash_any_boundary_recovers_bit_exact(
            self, stack, tmp_path, clean, boundary):
        cfg, model, params, qp, policy, toks = stack
        jp = str(tmp_path / "j.jsonl")
        crashed = _scheduler(model, cfg, policy, params, qp, journal=jp,
                             fault_plan=FaultPlan(crash=(boundary,)))
        with pytest.raises(SimulatedCrash):
            crashed.run(_requests(toks))
        fresh = _scheduler(model, cfg, policy, params, qp, journal=jp)
        done = fresh.recover()
        assert _by_rid(done) == clean
        h = fresh.health_stats()
        assert h["recoveries"] == 1
        # executables stay pinned: recovery rides the ONE resume width
        assert all(v <= 1 for v in fresh.executable_counts().values())

    def test_repeated_crashes_chain(self, stack, tmp_path, clean):
        cfg, model, params, qp, policy, toks = stack
        jp = str(tmp_path / "j.jsonl")
        plan = FaultPlan(crash=(1, 2))
        s1 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        fault_plan=plan)
        with pytest.raises(SimulatedCrash):
            s1.run(_requests(toks))
        # recovery resumes PAST boundary 1, then hits the crash at 2
        s2 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        fault_plan=plan)
        with pytest.raises(SimulatedCrash):
            s2.recover()
        s3 = _scheduler(model, cfg, policy, params, qp, journal=jp)
        assert _by_rid(s3.recover()) == clean

    def test_pre_crash_retirees_survive_with_health(
            self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        t = np.asarray(toks)
        reqs = [Request(rid=0, tokens=t[0, :12], max_gen=1),   # retires
                Request(rid=1, tokens=t[1, :20], max_gen=GEN)]  # in flight
        jp = str(tmp_path / "j.jsonl")
        s1 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        fault_plan=FaultPlan(crash=(1,)))
        with pytest.raises(SimulatedCrash):
            s1.run(reqs)
        s2 = _scheduler(model, cfg, policy, params, qp, journal=jp)
        done = _by_rid(s2.recover())
        assert set(done) == {0, 1}
        assert done[0][2] == "budget" and len(done[0][0]) == 1
        h = s2.health_stats()
        # terminal-status counters re-derive from replayed retirees
        assert h["ok"] == 2 and h["budget"] == 2
        assert h["replayed_tokens"] > 0

    def test_sampled_recovery_parity(self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        kw = dict(temperature=0.8, top_p=0.9, seed=7)
        base = _scheduler(model, cfg, policy, params, qp, **kw)
        clean = _by_rid(base.run(_requests(toks)))
        jp = str(tmp_path / "j.jsonl")
        s1 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        fault_plan=FaultPlan(crash=(2,)), **kw)
        with pytest.raises(SimulatedCrash):
            s1.run(_requests(toks))
        s2 = _scheduler(model, cfg, policy, params, qp, journal=jp, **kw)
        # the carried per-request PRNG key rides the journal, so even
        # SAMPLED streams continue bit-identically across the crash
        assert _by_rid(s2.recover()) == clean

    def test_recover_requires_journal(self, stack):
        cfg, model, params, qp, policy, toks = stack
        sched = _scheduler(model, cfg, policy, params, qp)
        with pytest.raises(ValueError, match="needs a journal"):
            sched.recover()

    def test_knob_mismatch_rejected(self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        jp = str(tmp_path / "j.jsonl")
        s1 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        fault_plan=FaultPlan(crash=(1,)))
        with pytest.raises(SimulatedCrash):
            s1.run(_requests(toks))
        s2 = _scheduler(model, cfg, policy, params, qp, journal=jp,
                        block_steps=4)
        with pytest.raises(ValueError, match="knobs do not match"):
            s2.recover()


# -- snapshot-mode recovery (save_state / load_state / resume_run) --------
class TestSnapshotRecovery:
    def test_snapshot_restore_bit_exact(self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        clean = _by_rid(_scheduler(model, cfg, policy, params, qp)
                        .run(_requests(toks)))
        sd = str(tmp_path / "snaps")
        s1 = _scheduler(model, cfg, policy, params, qp, snapshot_every=1,
                        snapshot_dir=sd, fault_plan=FaultPlan(crash=(2,)))
        with pytest.raises(SimulatedCrash):
            s1.run(_requests(toks))
        s2 = _scheduler(model, cfg, policy, params, qp, snapshot_dir=sd)
        assert s2.load_state() == 2      # restored at the crash boundary
        done = s2.resume_run()
        assert _by_rid(done) == clean
        h = s2.health_stats()
        # device state came back verbatim: nothing re-prefilled
        assert h["recoveries"] == 1 and h["replayed_tokens"] == 0

    def test_paged_snapshot_preserves_prefix_store(self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        t = np.asarray(toks)
        # same prompt twice: the second admission hits the prefix store
        reqs = [Request(rid=0, tokens=t[0, :16], max_gen=GEN),
                Request(rid=1, tokens=t[0, :16], max_gen=GEN),
                Request(rid=2, tokens=t[1, :8], max_gen=GEN)]
        kw = dict(cache_layout="paged", page_size=8, prefix_pages=8)
        clean = _by_rid(_scheduler(model, cfg, policy, params, qp, **kw)
                        .run(reqs))
        sd = str(tmp_path / "snaps")
        s1 = _scheduler(model, cfg, policy, params, qp, snapshot_every=1,
                        snapshot_dir=sd, fault_plan=FaultPlan(crash=(1,)),
                        **kw)
        with pytest.raises(SimulatedCrash):
            s1.run(reqs)
        before = s1.prefix_stats()
        s2 = _scheduler(model, cfg, policy, params, qp, snapshot_dir=sd,
                        **kw)
        s2.load_state()
        assert _by_rid(s2.resume_run()) == clean
        after = s2.prefix_stats()
        # the store's contents and counters crossed the crash
        assert after["hits"] >= before["hits"]
        assert after["shared_tokens"] >= before["shared_tokens"] > 0

    def test_save_state_requires_dir(self, stack):
        cfg, model, params, qp, policy, toks = stack
        sched = _scheduler(model, cfg, policy, params, qp)
        with pytest.raises(ValueError, match="snapshot_dir"):
            sched.save_state()
        with pytest.raises(ValueError, match="snapshot_dir"):
            sched.load_state()

    def test_load_state_empty_dir_raises(self, stack, tmp_path):
        cfg, model, params, qp, policy, toks = stack
        sched = _scheduler(model, cfg, policy, params, qp,
                           snapshot_dir=str(tmp_path / "none"))
        with pytest.raises(FileNotFoundError, match="no committed"):
            sched.load_state()

    def test_snapshot_every_needs_dir(self, stack):
        cfg, model, params, qp, policy, toks = stack
        with pytest.raises(ValueError, match="snapshot_dir"):
            _scheduler(model, cfg, policy, params, qp, snapshot_every=2)


# -- health counter semantics (satellite: cumulative + reset) -------------
class TestHealthSemantics:
    def test_cumulative_across_runs_and_reset(self, stack):
        cfg, model, params, qp, policy, toks = stack
        sched = _scheduler(model, cfg, policy, params, qp)
        sched.run(_requests(toks, n=2))
        first = sched.health_stats()
        assert first["ok"] == 2
        sched.run(_requests(toks, n=2))
        second = sched.health_stats()
        # CUMULATIVE across run() calls on one instance — pinned here
        assert second["ok"] == 4
        # health_stats returns a copy, not a live view
        second["ok"] = 99
        assert sched.health_stats()["ok"] == 4
        sched.reset_health()
        assert all(v == 0 for v in sched.health_stats().values())


# -- engine-level wiring ---------------------------------------------------
class TestEngineDurability:
    def test_engine_threads_journal_and_recovers(self, tmp_path):
        from repro.launch.engine import Engine
        jp = str(tmp_path / "j.jsonl")
        kw = dict(smoke=True, kv_int8=True, use_pallas=False,
                  calib_batch=2, calib_len=16, prefill_chunk=8)
        reqs = [Request(rid=r, tokens=np.arange(1, 9 + r, dtype=np.int32),
                        max_gen=4) for r in range(3)]
        sched_kw = dict(max_slots=2, prompt_cap=16, gen_cap=4,
                        block_steps=2)
        e0 = Engine.from_checkpoint(**kw)
        clean = _by_rid(e0.generate(reqs, **sched_kw))
        e1 = Engine.from_checkpoint(journal=jp,
                                    fault_plan={"crash": [1]}, **kw)
        with pytest.raises(SimulatedCrash):
            e1.generate(reqs, **sched_kw)
        e2 = Engine.from_checkpoint(journal=jp, **kw)
        done = e2.recover(**sched_kw)
        assert _by_rid(done) == clean
        rep = e2.health_report()
        assert rep["recoveries"] == 1

    def test_engine_validates_snapshot_knobs(self):
        from repro.launch.engine import Engine
        with pytest.raises(ValueError, match="snapshot_dir"):
            Engine.from_checkpoint(smoke=True, snapshot_every=3,
                                   calib_batch=2, calib_len=16)
