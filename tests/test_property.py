"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.cache.base import (_safe_scale, dequantize_kv, kv_levels,
                              quantize_kv)
from repro.core import quant as Q
from repro.core.equalization import pair_rescale
from repro.core.folding import fold_batchnorm
from repro.core.packing import pack_int4, unpack_int4

F32 = st.floats(min_value=-50.0, max_value=50.0, allow_nan=False,
                width=32)


@st.composite
def arrays(draw, max_rows=16, max_cols=8):
    r = draw(st.integers(2, max_rows))
    c = draw(st.integers(2, max_cols))
    vals = draw(st.lists(F32, min_size=r * c, max_size=r * c))
    return np.asarray(vals, np.float32).reshape(r, c)


class TestQuantInvariants:
    @settings(max_examples=40, deadline=None)
    @given(arrays(), st.floats(0.5, 1.0))
    def test_roundtrip_error_bounded(self, x, alpha):
        """|x - fq(x)| <= step/2 inside the threshold, and fq saturates at
        exactly alpha*T outside (paper eq. 4/12)."""
        spec = Q.QuantSpec(bits=8, symmetric=True)
        x = jnp.asarray(x)
        t = Q.max_abs_threshold(x, spec)
        if float(t) == 0.0:
            return
        y = Q.fake_quant_symmetric(x, t, jnp.asarray(alpha), spec)
        t_adj = float(t) * alpha
        step = t_adj / 127
        inside = np.abs(np.asarray(x)) <= t_adj
        err = np.abs(np.asarray(x - y))
        assert np.all(err[inside] <= step / 2 + 1e-5)
        # outside the threshold the output saturates at +-t_adj
        assert np.all(np.abs(np.asarray(y)[~inside]) <= t_adj + 1e-5)

    @settings(max_examples=40, deadline=None)
    @given(arrays())
    def test_quantization_idempotent(self, x):
        """fq(fq(x)) == fq(x) — quantized values are fixed points."""
        spec = Q.QuantSpec(bits=8, symmetric=True)
        x = jnp.asarray(x)
        t = Q.max_abs_threshold(x, spec)
        if float(t) == 0.0:
            return
        y1 = Q.fake_quant_symmetric(x, t, jnp.ones(()), spec)
        y2 = Q.fake_quant_symmetric(y1, t, jnp.ones(()), spec)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(arrays())
    def test_int8_roundtrip_matches_fake_quant(self, x):
        """Real int8 (w_q * scale) == fake-quant output (same math)."""
        spec = Q.QuantSpec(bits=8, symmetric=True, per_channel=True,
                           channel_axis=-1)
        x = jnp.asarray(x)
        t = Q.max_abs_threshold(x, spec)
        if float(jnp.min(t)) == 0.0:
            return
        alpha = jnp.ones_like(t)
        y_fake = Q.fake_quant_symmetric(x, t, alpha, spec)
        w_q, scale = Q.quantize_weights_int8(x, t, alpha, spec)
        y_int = w_q.astype(jnp.float32) * scale
        np.testing.assert_allclose(np.asarray(y_fake), np.asarray(y_int),
                                   rtol=1e-4, atol=1e-5)

    @settings(max_examples=30, deadline=None)
    @given(arrays(), st.floats(0.5, 1.0))
    def test_values_beyond_threshold_saturate(self, x, alpha):
        """Every |x| > T_adj maps exactly to ±T_adj (clip, eq. 4), and the
        saturated set grows as alpha shrinks (clipping is monotone)."""
        spec = Q.QuantSpec(bits=8, symmetric=True)
        x = jnp.asarray(x)
        t = Q.max_abs_threshold(x, spec)
        if float(t) == 0.0:
            return
        t_adj = alpha * float(t)
        y = Q.fake_quant_symmetric(x, t, jnp.asarray(alpha), spec)
        beyond = np.abs(np.asarray(x)) > t_adj * (1 + 1e-6)
        np.testing.assert_allclose(
            np.abs(np.asarray(y)[beyond]), t_adj, rtol=1e-5)


class TestInt4PackingInvariants:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(-8, 7), min_size=1, max_size=65))
    def test_pack_unpack_roundtrip_lossless(self, vals):
        """Every int4 value (all 16 nibbles), every length incl. odd —
        pack then unpack is the identity."""
        x = jnp.asarray(vals, jnp.int8)
        p = pack_int4(x)
        assert p.shape == ((len(vals) + 1) // 2,)
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(p, size=len(vals))), np.asarray(x))

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10000), st.integers(1, 4), st.integers(1, 16))
    def test_pack_unpack_roundtrip_nd(self, seed, rows, cols):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-8, 8, size=(rows, cols)), jnp.int8)
        p = pack_int4(x, axis=-1)
        np.testing.assert_array_equal(
            np.asarray(unpack_int4(p, axis=-1, size=cols)), np.asarray(x))


class TestKVQuantInvariants:
    @settings(max_examples=40, deadline=None)
    @given(arrays(), st.sampled_from([4, 8]))
    def test_quantize_dequantize_error_bounded_by_step(self, x, bits):
        """|x - dq(q(x))| <= step/2 per head for any calibrated threshold,
        at both KV bit widths (step = T / levels; 7 levels at int4)."""
        x4 = jnp.asarray(x).reshape(1, x.shape[0], 1, x.shape[1])
        if x4.shape[-1] % 2:
            x4 = x4[..., :-1]
        if x4.shape[-1] == 0:
            return
        t = jnp.max(jnp.abs(x4))
        if float(t) == 0.0:
            return
        scale = jnp.asarray([t / kv_levels(bits)], jnp.float32)
        y = dequantize_kv(quantize_kv(x4, scale, bits=bits), scale,
                          bits=bits)
        err = float(jnp.max(jnp.abs(x4 - y)))
        assert err <= float(scale[0]) / 2 + 1e-5

    @settings(max_examples=40, deadline=None)
    @given(st.floats(min_value=0.0, max_value=1e-12, allow_nan=True,
                     width=32),
           st.sampled_from([4, 8]), st.integers(0, 1000))
    def test_threshold_floor_holds_for_degenerate_calibration(
            self, bad_scale, bits, seed):
        """PR 6's zero/NaN threshold guard, extended to both bit widths:
        a degenerate calibrated scale (0, denormal-tiny, or NaN) clamps
        to a positive floor and the quantize->dequantize round trip stays
        finite."""
        s = _safe_scale(jnp.asarray([bad_scale], jnp.float32))
        assert bool(jnp.all(s > 0)) and bool(jnp.all(jnp.isfinite(s)))
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(1, 4, 1, 8)), jnp.float32)
        y = dequantize_kv(quantize_kv(x, s, bits=bits), s, bits=bits)
        assert bool(jnp.all(jnp.isfinite(y)))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10000))
    def test_healthy_scales_pass_through_floor_bit_identically(self, seed):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(np.abs(rng.normal(size=(4,))) + 1e-3, jnp.float32)
        np.testing.assert_array_equal(np.asarray(_safe_scale(s)),
                                      np.asarray(s))


class TestEqualizationInvariance:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10000))
    def test_pair_rescale_preserves_function(self, seed):
        """§3.3 core identity: rescaled (up, down) pair computes the same
        function through an elementwise product gate."""
        rng = np.random.default_rng(seed)
        d, h = 8, 12
        w_up = jnp.asarray(rng.normal(size=(d, h)), jnp.float32)
        w_gate = jnp.asarray(rng.normal(size=(d, h)), jnp.float32)
        w_down = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)

        def f(wu, wd):
            g = jax.nn.silu(x @ w_gate)
            return (g * (x @ wu)) @ wd

        y0 = f(w_up, w_down)
        wu2, wd2, res = pair_rescale(w_up, w_down)
        y1 = f(wu2, wd2)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-4)
        # thresholds after rescale are equalized (all equal to the mean)
        t_after = np.asarray(res.t_after)
        assert t_after.std() / (t_after.mean() + 1e-9) < 1e-3

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10000))
    def test_bn_fold_exact(self, seed):
        """Eqs. 10-11: folded conv == conv + BN."""
        rng = np.random.default_rng(seed)
        c = 6
        w = jnp.asarray(rng.normal(size=(3, c)), jnp.float32)
        gamma = jnp.asarray(rng.uniform(0.5, 2, c), jnp.float32)
        beta = jnp.asarray(rng.normal(size=c), jnp.float32)
        mu = jnp.asarray(rng.normal(size=c), jnp.float32)
        var = jnp.asarray(rng.uniform(0.5, 2, c), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 10, c)), jnp.float32)

        def dws(x, w):
            k = w.shape[0]
            xp = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
            return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))

        eps = 1e-5
        y_bn = (dws(x, w) - mu) / jnp.sqrt(var + eps) * gamma + beta
        w_f, b_f = fold_batchnorm(w, gamma, beta, mu, var, eps)
        y_fold = dws(x, w_f) + b_f
        np.testing.assert_allclose(np.asarray(y_bn), np.asarray(y_fold),
                                   rtol=1e-4, atol=1e-5)


class TestDWSRescaleInvariance:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10000))
    def test_relu6_rescale_preserves_unsaturated_outputs(self, seed):
        """Eq. 26-27: for channels whose activations stay below the cap,
        DWS rescaling leaves DWS->ReLU6->Conv output unchanged."""
        from repro.core.equalization import dws_relu6_rescale

        rng = np.random.default_rng(seed)
        c, f = 8, 5
        w_dws = jnp.asarray(rng.normal(size=(3, c)) * 0.2, jnp.float32)
        b_dws = jnp.asarray(rng.normal(size=c) * 0.05, jnp.float32)
        w_conv = jnp.asarray(rng.normal(size=(c, f)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 6, c)) * 0.5, jnp.float32)

        def net(wd, bd, wc):
            k = wd.shape[0]
            xp = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
            pre = sum(xp[:, i:i + x.shape[1], :] * wd[i] for i in range(k))
            pre = pre + bd
            return jnp.clip(pre, 0, 6) @ wc, pre

        y0, pre = net(w_dws, b_dws, w_conv)
        act_max = jnp.max(jnp.abs(pre), axis=(0, 1))
        wd2, bd2, wc2, res = dws_relu6_rescale(w_dws, b_dws, w_conv, act_max)
        y1, _ = net(wd2, bd2, wc2)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-4)
