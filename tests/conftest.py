"""Shared test fixtures.

NOTE: XLA_FLAGS / host-platform device-count tricks are deliberately NOT
set here — smoke tests and benches must see the 1 real CPU device; only
launch/dryrun.py requests 512 placeholder devices (and only when run as a
script).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.linalg.norm(a - b) / (np.linalg.norm(a) + 1e-12))
