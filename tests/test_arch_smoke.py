"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one FAT train step on CPU, asserting output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# full ARCHS sweep x (forward, train, int8) — minutes of compile on CPU;
# the fast CI lane skips it, the tier-1 lane runs it
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, get_config
from repro.core import api as A
from repro.core.distill import rmse_distill_loss
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (batch, seq, cfg.frame_dim)).astype(cfg.dtype)
        return {"frames": frames, "tokens": toks[:, : max(seq // cfg.dec_ratio, 4)]}
    if cfg.modality == "vlm":
        patches = jax.random.normal(
            key, (batch, cfg.mm_patches, cfg.mm_dim)
        ).astype(cfg.dtype)
        return {"patches": patches, "tokens": toks[:, : seq - cfg.mm_patches]}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model(params, batch)
    n_pos = batch["tokens"].shape[1] + (
        cfg.mm_patches if cfg.modality == "vlm" else 0
    )
    assert logits.shape == (B, n_pos, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_fat_train_step(arch):
    """One full FAT distillation step: calibrate -> fake-quant student ->
    RMSE loss -> grads land on threshold alphas and are finite."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = A.QuantPolicy()
    qp = A.init_qparams(model, params, policy)
    assert len(qp) > 0
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    # calibration pass
    ctx = A.make_ctx("calibrate", policy, qp)
    model(params, batch, ctx)
    for path, obs in ctx.updates.items():
        e = dict(qp[path])
        e["act"] = obs
        qp[path] = e
    qp = A.finalize_calibration(qp, policy)

    teacher, _ = model(params, batch)

    def loss_fn(qp):
        s, _ = model(params, batch, A.make_ctx("fake", policy, qp))
        return rmse_distill_loss(teacher, s)

    loss, grads = jax.value_and_grad(loss_fn)(qp)
    assert np.isfinite(float(loss))
    # gradients reach at least one alpha and are finite everywhere
    alpha_norms = [
        float(jnp.sum(jnp.abs(e["w"]["alpha"]))) + float(jnp.sum(jnp.abs(e["act"]["alpha"])))
        for e in jax.tree.map(jnp.asarray, grads).values()
    ]
    assert all(np.isfinite(x) for x in alpha_norms)
    assert any(x > 0 for x in alpha_norms)


@pytest.mark.parametrize("arch", ARCHS)
def test_int8_serving_close_to_teacher(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = A.QuantPolicy()
    qp = A.init_qparams(model, params, policy)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    ctx = A.make_ctx("calibrate", policy, qp)
    model(params, batch, ctx)
    for path, obs in ctx.updates.items():
        qp[path] = {**qp[path], "act": obs}
    qp = A.finalize_calibration(qp, policy)
    teacher, _ = model(params, batch)
    p8 = A.convert_to_int8(model, params, qp, policy)
    out8, _ = model(p8, batch, A.make_ctx("int8", policy, qp))
    rel = float(
        jnp.linalg.norm((teacher - out8).astype(jnp.float32))
        / (jnp.linalg.norm(teacher.astype(jnp.float32)) + 1e-9)
    )
    assert rel < 0.3, f"{arch}: int8 rel err {rel}"
    # int8 weights actually stored as int8
    leaves = jax.tree.leaves(p8)
    assert any(l.dtype == jnp.int8 for l in leaves)
