"""Sharded serving parity suite (ISSUE 10 acceptance).

Tensor-parallel (tp=2) and sequence-parallel (sp=2) engines must produce
TOKEN-IDENTICAL greedy output to the unsharded engine across every
serving path — one-shot prefill, chunked/scheduler prefill, decode,
preemption resume, speculative decode, journal-replay recovery — and the
post-optimization HLO of the sharded executables must move only integer
all-reduce payloads (the int8-on-the-wire contract,
launch/hlo_analysis.py::check_integer_all_reduces).

Multi-device tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the CI ``sharded`` lane sets it); on a single-device host they skip.
The partial-softmax kernel/merge tests run everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config

S, GEN = 16, 8
NDEV = jax.device_count()

needs2 = pytest.mark.skipif(
    NDEV < 2, reason="needs 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _cfg():
    # shard-divisible head grid (the smoke preset's 3 heads can't split)
    cfg = get_config("smollm-135m", smoke=True)
    return cfg.replace(n_heads=4, n_kv_heads=2, head_dim=cfg.head_dim)


def _toks(cfg, b=3):
    return jax.random.randint(jax.random.PRNGKey(1), (b, S), 0, cfg.vocab)


def _requests(toks):
    from repro.launch.scheduler import Request

    return [Request(rid=r, tokens=np.asarray(toks[r % toks.shape[0], :n]),
                    max_gen=GEN) for r, n in enumerate([S, S - 5, 9])]


def _by_rid(completions):
    return {c.rid: (c.status, tuple(int(t) for t in c.tokens))
            for c in completions}


@pytest.fixture(scope="module")
def engines():
    """(unsharded, tp=2, sp=2) engines over identical weights/thresholds
    — from_checkpoint is seed-deterministic, so the three builds share
    params bit-for-bit."""
    if NDEV < 2:
        pytest.skip("needs 2 devices")
    from repro.launch.engine import Engine
    from repro.shard.engine import ShardedEngine

    kw = dict(cfg=_cfg(), smoke=True, cache_layout="dense",
              use_pallas=False)
    return (Engine.from_checkpoint("smollm-135m", **kw),
            ShardedEngine.from_checkpoint("smollm-135m", tp=2, **kw),
            ShardedEngine.from_checkpoint("smollm-135m", sp=2, **kw))


class TestTokenParity:
    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_one_shot_prefill_decode(self, engines, which):
        base, tp2, sp2 = engines
        sharded = tp2 if which == "tp" else sp2
        batch = {"tokens": _toks(base.cfg)}
        want = base.generate_batch(batch, GEN, prompt_len=S).tokens
        got = sharded.generate_batch(batch, GEN, prompt_len=S).tokens
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_scheduler_continuous_batching(self, engines, which):
        """Ragged admission through 2 slots: chunked prefill + slot
        decode blocks, all under shard_map, token-identical."""
        base, tp2, sp2 = engines
        sharded = tp2 if which == "tp" else sp2
        toks = _toks(base.cfg)
        want = _by_rid(base.generate(_requests(toks), max_slots=2,
                                     block_steps=3))
        got = _by_rid(sharded.generate(_requests(toks), max_slots=2,
                                       block_steps=3))
        assert got == want
        # the no-retrace contract survives sharding
        counts = sharded.make_scheduler(
            max_slots=2, prompt_cap=S, gen_cap=GEN,
            block_steps=3).executable_counts()
        assert all(v <= 1 for v in counts.values()), counts

    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_preemption_resume(self, engines, which, tmp_path):
        """A forced preemption re-admits through the resume prefill —
        the re-prefill of prompt+generated must reproduce the exact
        unsharded continuation."""
        from repro.launch.faults import FaultPlan
        from repro.launch.scheduler import SlotScheduler

        base, tp2, sp2 = engines
        sharded = tp2 if which == "tp" else sp2
        toks = _toks(base.cfg)
        plan = FaultPlan(preempt=((1, 0),))
        out = {}
        for key, eng in (("base", base), ("sharded", sharded)):
            sched = SlotScheduler(
                eng.model, eng.cfg, eng.policy, eng.serve_params,
                eng.qparams, mode=eng.mode, max_slots=2, prompt_cap=S,
                gen_cap=GEN, prefill_chunk=8, block_steps=3,
                fault_plan=plan)
            out[key] = _by_rid(sched.run(_requests(toks)))
        assert out["sharded"] == out["base"]

    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_speculative_decode(self, engines, which):
        """Prompt-lookup speculative decoding (draft + batched verify)
        under shard_map — still bit-identical to greedy."""
        from repro.launch.scheduler import SlotScheduler

        base, tp2, sp2 = engines
        sharded = tp2 if which == "tp" else sp2
        toks = _toks(base.cfg)
        out = {}
        for key, eng in (("base", base), ("sharded", sharded)):
            sched = SlotScheduler(
                eng.model, eng.cfg, eng.policy, eng.serve_params,
                eng.qparams, mode=eng.mode, max_slots=2, prompt_cap=S,
                gen_cap=GEN, prefill_chunk=8, block_steps=3,
                strategy="speculative", spec_k=3)
            out[key] = _by_rid(sched.run(_requests(toks)))
        assert out["sharded"] == out["base"]

    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_journal_crash_recovery(self, engines, which, tmp_path):
        """Crash mid-run, journal-replay on a FRESH sharded scheduler:
        the durability story holds under shard_map (thresholds frozen,
        so the sharded int8 cache recomputes from journaled tokens)."""
        from repro.launch.faults import FaultPlan, SimulatedCrash
        from repro.launch.scheduler import SlotScheduler

        base, tp2, sp2 = engines
        sharded = tp2 if which == "tp" else sp2
        toks = _toks(base.cfg)
        clean = _by_rid(base.generate(_requests(toks), max_slots=2,
                                      block_steps=3))

        def sched(**kw):
            return SlotScheduler(
                sharded.model, sharded.cfg, sharded.policy,
                sharded.serve_params, sharded.qparams, mode=sharded.mode,
                max_slots=2, prompt_cap=S, gen_cap=GEN, prefill_chunk=8,
                block_steps=3, **kw)

        jp = str(tmp_path / f"{which}.jsonl")
        with pytest.raises(SimulatedCrash):
            sched(journal=jp,
                  fault_plan=FaultPlan(crash=(2,))).run(_requests(toks))
        assert _by_rid(sched(journal=jp).recover()) == clean


class TestInterconnectContract:
    @needs2
    def test_tp_all_reduces_are_integer(self, engines):
        """The acceptance HLO assertion: every all-reduce in the tp=2
        prefill AND decode executables carries integer payload bytes
        (s32 row-epilogue accumulators; compressed_psum's integer fast
        path never even emits the scalar f32 pmax)."""
        base, tp2, sp2 = engines
        report = tp2.dry_run_report(batch=2, prompt_len=S)
        assert report["int8_all_reduces_ok"], report
        payloads = [p for ex in report["executables"].values()
                    for p in ex["all_reduce_payloads"]]
        assert payloads, "tp=2 executables must contain all-reduces"
        assert all(dt.startswith(("s", "u", "pred"))
                   for dt, _ in payloads), payloads

    @needs2
    def test_sp_has_no_all_reduce_at_all(self, engines):
        """Sequence parallelism merges flash partials with gathers, not
        reductions — the strict integer-all-reduce assertion is vacuous
        there BY CONSTRUCTION, and prefill moves zero collective bytes
        (each shard owns its rows outright)."""
        base, tp2, sp2 = engines
        report = sp2.dry_run_report(batch=2, prompt_len=S)
        assert report["int8_all_reduces_ok"], report
        for ex in report["executables"].values():
            assert ex["all_reduce_payloads"] == []
        assert report["executables"]["prefill"]["collective_bytes"] == 0

    @needs2
    def test_sharded_trace_passes_drift_check(self, engines):
        """dtype_drift over the REAL sharded jaxprs: the only float
        collective anywhere is the allowlisted sp_partial_combine
        gather."""
        from repro.analysis import dtype_drift as DD
        from repro.launch import steps as ST

        base, tp2, sp2 = engines
        for eng in (tp2, sp2):
            cache = eng.init_cache(2, 32)
            step = ST.make_prefill_step(eng.model, eng.cfg, eng.policy,
                                        eng.mode)
            jaxpr = jax.make_jaxpr(step)(
                eng.serve_params, eng.qparams,
                {"tokens": jnp.zeros((2, S), jnp.int32)}, cache)
            assert DD.check_dtype_drift(jaxpr) == []


class TestShardedCacheDurability:
    @needs2
    @pytest.mark.parametrize("which", ["tp", "sp"])
    def test_state_dict_roundtrip_mid_generation(self, engines, which):
        """Snapshot the cache after a sharded prefill, rebuild it from
        the state_dict, decode on both — bit-identical logits.  The
        cache pytree stays GLOBAL outside shard_map, so the unsharded
        state_dict machinery round-trips it untouched."""
        from repro.cache.base import KVCache
        from repro.launch import steps as ST

        base, tp2, sp2 = engines
        eng = tp2 if which == "tp" else sp2
        cache = eng.init_cache(2, 32)
        toks = _toks(eng.cfg, b=2)
        prefill = ST.make_prefill_step(eng.model, eng.cfg, eng.policy,
                                       eng.mode)
        decode = ST.make_serve_step(eng.model, eng.cfg, eng.policy,
                                    eng.mode)
        _, cache = prefill(eng.serve_params, eng.qparams,
                           {"tokens": toks}, cache)
        restored = jax.tree.map(
            lambda c: KVCache.from_state_dict(c.state_dict()), cache,
            is_leaf=lambda c: isinstance(c, KVCache))
        tok1 = jnp.zeros((2, 1), jnp.int32)
        want, _, _ = decode(eng.serve_params, eng.qparams, tok1, cache,
                            jnp.int32(S))
        got, _, _ = decode(eng.serve_params, eng.qparams, tok1, restored,
                           jnp.int32(S))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPartialSoftmax:
    """Kernel + merge algebra — single-device, runs everywhere."""

    def _setup(self, b=2, s=32, kv=2, g=2, d=16, pos=20):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(b, kv, g, d)), jnp.float32)
        k = jnp.asarray(rng.integers(-127, 128, size=(b, s, kv, d)),
                        jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, size=(b, s, kv, d)),
                        jnp.int8)
        ks = jnp.full((kv,), 0.02, jnp.float32)
        vs = jnp.full((kv,), 0.03, jnp.float32)
        cur = jnp.full((b,), pos, jnp.int32)
        return q, k, v, ks, vs, cur

    def test_partials_normalize_to_full_attention(self):
        from repro.kernels import decode_attention as DA

        q, k, v, ks, vs, cur = self._setup()
        full = DA.decode_attention_int8(q, k, v, ks, vs, cur,
                                        interpret=True)
        acc, m, l = DA.decode_attention_partials(q, k, v, ks, vs, cur,
                                                 interpret=True)
        got = acc / np.maximum(np.asarray(l)[..., None], 1e-30)
        got = got.reshape(np.asarray(full).shape)
        np.testing.assert_allclose(got, np.asarray(full), atol=1e-5)

    def test_two_shard_merge_is_exact(self):
        """Split S in half, run partials per half at shard-local
        positions, merge with the online-softmax identity — matches the
        full attention to f32 roundoff."""
        from repro.kernels import decode_attention as DA

        q, k, v, ks, vs, cur = self._setup(s=32, pos=20)
        full = np.asarray(DA.decode_attention_int8(q, k, v, ks, vs, cur,
                                                   interpret=True))
        parts = []
        for lo in (0, 16):
            valid = np.clip(np.asarray(cur) - lo, 0, 16)
            acc, m, l = DA.decode_attention_partials(
                q, k[:, lo:lo + 16], v[:, lo:lo + 16], ks, vs,
                jnp.asarray(valid, jnp.int32), interpret=True)
            parts.append((np.asarray(acc, np.float64),
                          np.asarray(m, np.float64),
                          np.asarray(l, np.float64)))
        (a0, m0, l0), (a1, m1, l1) = parts
        mg = np.maximum(m0, m1)
        w0, w1 = np.exp(m0 - mg), np.exp(m1 - mg)
        l = l0 * w0 + l1 * w1
        acc = a0 * w0[..., None] + a1 * w1[..., None]
        got = (acc / np.maximum(l, 1e-30)[..., None]).reshape(full.shape)
        np.testing.assert_allclose(got, full, atol=1e-5)

    def test_empty_shard_is_merge_identity(self):
        """A shard with zero valid rows emits (m=-inf-ish, l=0, acc=0):
        merging it in changes nothing."""
        from repro.kernels import decode_attention as DA

        q, k, v, ks, vs, _ = self._setup()
        acc, m, l = DA.decode_attention_partials(
            q, k, v, ks, vs, jnp.zeros((2,), jnp.int32), interpret=True)
        assert np.all(np.asarray(l) == 0.0)
        assert np.all(np.asarray(acc) == 0.0)
        assert np.all(np.asarray(m) <= -1e29)


class TestValidation:
    def test_tp_and_sp_together_rejected(self):
        from repro.shard.context import ShardContext

        with pytest.raises(ValueError, match="share the one"):
            ShardContext(axis="model", tp=2, sp=2)

    @needs2
    def test_tp_requires_int8_mode(self):
        from repro.shard.engine import ShardedEngine

        with pytest.raises(ValueError, match="mode='int8'"):
            ShardedEngine.from_checkpoint("smollm-135m", cfg=_cfg(),
                                          smoke=True, tp=2, fp=True)

    @needs2
    def test_sp_rejects_paged_layout(self):
        from repro.shard.engine import ShardedEngine

        with pytest.raises(ValueError, match="paged"):
            ShardedEngine.from_checkpoint("smollm-135m", cfg=_cfg(),
                                          smoke=True, sp=2,
                                          cache_layout="paged")

    @needs2
    def test_tp_head_divisibility_enforced(self):
        from repro.launch.mesh import make_serving_mesh
        from repro.models import build_model
        from repro.shard.model import ShardedModel

        cfg = get_config("smollm-135m", smoke=True)  # 3 heads
        with pytest.raises(ValueError, match="not divisible by tp"):
            ShardedModel(build_model(cfg), cfg, make_serving_mesh(2),
                         tp=2)
