"""DecodeStrategy protocol (ISSUE 5): strategy/loop parity and the
no-retrace contract.

Acceptance:

  * ``GreedyStrategy`` / ``SamplingStrategy`` through the new strategy
    loops are BIT-IDENTICAL to the pre-redesign scan loops (same seed,
    same tokens) — pinned against verbatim copies of the old loop bodies
    kept in this file as the oracle;
  * ``SpeculativeStrategy`` is bit-identical to greedy under the
    deterministic accept rule, on the real model (whatever the
    acceptance rate) and on a cyclic stub where acceptance is provably
    > 0 (windows emit multiple tokens);
  * closure-side trace counters (a counter bumped inside the to-be-jitted
    Python body runs only on jit-cache miss) prove ONE compiled loop
    executable across draft lengths, match patterns, and admission
    patterns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import api as A
from repro.launch import steps as ST
from repro.launch import strategies as SG
from repro.launch.scheduler import Request, SlotScheduler
from repro.models import build_model

B, S, GEN = 2, 32, 6
CHUNK = 8


def _calibrated(arch="smollm-135m", kv_int8=True, **pol):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=kv_int8, **pol)
    qp = A.init_qparams(model, params, policy)
    qp = ST.make_calibrate_step(model, cfg, policy)(params, qp,
                                                    {"tokens": toks})
    qp = A.finalize_calibration(qp, policy)
    return cfg, model, params, qp, policy, toks


# -- the pre-redesign loop bodies, verbatim (the parity oracle) -------------

def _legacy_decode_loop(model, cfg, policy, mode="int8", n_steps=16,
                        temperature=0.0, top_p=1.0):
    step = ST.make_serve_step(model, cfg, policy, mode=mode)
    sampled = temperature > 0.0

    def decode_loop(serve_params, qparams, tok0, cache, pos0, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)

        def body(carry, _):
            tok, cache, pos, key = carry
            nxt, logits, cache = step(serve_params, qparams, tok[:, None],
                                      cache, pos)
            if sampled:
                key, sub = jax.random.split(key)
                nxt = ST.sample_tokens(logits[:, -1, :], sub,
                                       temperature=temperature, top_p=top_p)
            return (nxt, cache, pos + 1, key), nxt

        carry0 = (tok0, cache, jnp.asarray(pos0, jnp.int32), key)
        (_, cache, _, _), toks = jax.lax.scan(body, carry0, None,
                                              length=n_steps - 1)
        toks = jnp.concatenate([tok0[:, None], jnp.moveaxis(toks, 0, 1)],
                               axis=1)
        return toks, cache

    return decode_loop


def _legacy_slot_decode_loop(model, cfg, policy, mode="int8", n_steps=8,
                             temperature=0.0, top_p=1.0, eos_id=-1):
    step = ST.make_serve_step(model, cfg, policy, mode=mode)
    sampled = temperature > 0.0

    def slot_decode_loop(serve_params, qparams, tok0, cache, pos0, active0,
                         key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        cache_len = SG._attn_cache_len(cache)

        def body(carry, _):
            tok, cache, pos, active, key = carry
            if cache_len is not None:
                active = active & (pos < cache_len)
            nxt, logits, cache = step(serve_params, qparams, tok[:, None],
                                      cache, pos, active)
            if sampled:
                key, sub = jax.random.split(key)
                nxt = ST.sample_tokens(logits[:, -1, :], sub,
                                       temperature=temperature, top_p=top_p)
            nxt = jnp.where(active, nxt, tok)
            emitted = active
            if eos_id >= 0:
                active = active & (nxt != eos_id)
            pos = jnp.where(emitted, pos + 1, pos)
            return (nxt, cache, pos, active, key), (nxt, emitted)

        pos0 = jnp.asarray(pos0, jnp.int32)
        active0 = jnp.asarray(active0, bool)
        carry0 = (jnp.asarray(tok0, jnp.int32), cache, pos0, active0, key)
        (tok, cache, pos, active, key), (toks, emitted) = jax.lax.scan(
            body, carry0, None, length=n_steps)
        return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(emitted, 0, 1),
                cache, pos, active, key)

    return slot_decode_loop


class TestLegacyLoopParity:
    """The tentpole's bit-exactness contract: the strategy-backed loops
    reproduce the pre-redesign loops token for token, emission for
    emission — greedy AND sampled (same seed, same key schedule)."""

    @pytest.mark.parametrize("temperature,top_p", [(0.0, 1.0), (1.3, 0.9)])
    def test_single_stream_bit_identical(self, temperature, top_p):
        cfg, model, params, qp, policy, toks = _calibrated()
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        new = jax.jit(ST.make_decode_loop(
            model, cfg, policy, mode="none", n_steps=GEN,
            temperature=temperature, top_p=top_p))
        old = jax.jit(_legacy_decode_loop(
            model, cfg, policy, mode="none", n_steps=GEN,
            temperature=temperature, top_p=top_p))
        outs = []
        for loop in (new, old):
            cache = model.init_cache(B, S + GEN, cfg.dtype, kv_int8=True)
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            out, _ = loop(params, qp, tok0, cache, S,
                          jax.random.PRNGKey(42))
            outs.append(np.asarray(out))
        np.testing.assert_array_equal(outs[0], outs[1])

    @pytest.mark.parametrize("temperature", [0.0, 1.1])
    def test_slot_loop_bit_identical(self, temperature, eos_id=-1):
        cfg, model, params, qp, policy, toks = _calibrated()
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        new = jax.jit(ST.make_slot_decode_loop(
            model, cfg, policy, mode="none", n_steps=4,
            temperature=temperature, eos_id=eos_id))
        old = jax.jit(_legacy_slot_decode_loop(
            model, cfg, policy, mode="none", n_steps=4,
            temperature=temperature, eos_id=eos_id))
        res = []
        for loop in (new, old):
            cache = model.init_cache(B, S + GEN, cfg.dtype, kv_int8=True)
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            out = loop(params, qp, tok0, cache,
                       jnp.full((B,), S, jnp.int32),
                       jnp.asarray([True, False]), jax.random.PRNGKey(3))
            res.append(out)
        for a, b in zip(res[0][:2] + res[0][3:5], res[1][:2] + res[1][3:5]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # caches bit-identical too (inactive slot neutrality included)
        for a, b in zip(jax.tree.leaves(res[0][2]),
                        jax.tree.leaves(res[1][2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSpeculativeParity:
    """Speculative == greedy, bit for bit, under deterministic accept."""

    def test_single_stream_matches_greedy(self):
        cfg, model, params, qp, policy, toks = _calibrated()
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        greedy = jax.jit(ST.make_decode_loop(model, cfg, policy,
                                             mode="none", n_steps=GEN))
        strat = SG.SpeculativeStrategy(model, cfg, policy, mode="none",
                                       draft_k=3, ngram=2)
        spec = jax.jit(SG.make_strategy_decode_loop(
            model, cfg, policy, strat, mode="none", n_steps=GEN))
        cache_len = S + GEN + strat.draft_k
        outs = {}
        for name in ("greedy", "spec"):
            cache = model.init_cache(B, cache_len, cfg.dtype, kv_int8=True)
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            if name == "greedy":
                out, _ = greedy(params, qp, tok0, cache, S)
            else:
                hist = jnp.zeros((B, cache_len), jnp.int32)
                hist = hist.at[:, :S].set(toks).at[:, S].set(tok0)
                out, _ = spec(params, qp, tok0, cache,
                              jnp.full((B,), S, jnp.int32),
                              jax.random.PRNGKey(0), hist)
            outs[name] = np.asarray(out)
        np.testing.assert_array_equal(outs["spec"], outs["greedy"])

    def test_scheduler_matches_greedy_and_counts_stay_one(self):
        """Two admission patterns through a SPECULATIVE scheduler: tokens
        equal the greedy scheduler's, and the closure trace counters stay
        at one executable per piece — draft lengths, match patterns, and
        admission patterns are all data."""
        cfg, model, params, qp, policy, toks = _calibrated()

        def sched(**kw):
            return SlotScheduler(model, cfg, policy, params, qp,
                                 mode="none", max_slots=2, prompt_cap=S,
                                 gen_cap=GEN + 2, prefill_chunk=CHUNK,
                                 block_steps=3, **kw)

        # repetitive prompt -> lookup hits; random prompt -> misses
        rep = np.tile(np.asarray(toks[0, :4]), 8)[:S].astype(np.int32)
        patterns = [
            [Request(rid=0, tokens=rep, max_gen=GEN),
             Request(rid=1, tokens=np.asarray(toks[1, :20]), max_gen=GEN)],
            [Request(rid=0, tokens=np.asarray(toks[0, :9]), max_gen=GEN)],
        ]
        g, s = sched(), sched(strategy="speculative", spec_k=3,
                              spec_ngram=2)
        for reqs in patterns:
            want = {c.rid: c.tokens for c in g.run(list(reqs))}
            got = {c.rid: c.tokens for c in s.run(list(reqs))}
            assert got == want
        counts = s.executable_counts()
        assert counts == {"prefill": 1, "decode": 1, "insert": 1,
                          "resume": 0}, counts
        assert s.spec_stats()["verify_windows"] > 0


class _CyclicStub:
    """decode/verify emit one-hot logits for (token + 1) % cycle: greedy
    text is perfectly periodic, so prompt-lookup drafts are provably
    accepted once the cycle has repeated in the history."""

    def __init__(self, vocab, cycle=8):
        self.vocab, self.cycle = vocab, cycle

    def _logits(self, tokens):
        nxt = (tokens + 1) % self.cycle
        return jax.nn.one_hot(nxt, self.vocab) * 10.0

    def decode_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        return self._logits(tokens), cache

    def verify_step(self, params, tokens, cache, cur_pos, ctx=None, *,
                    slot_mask=None):
        return self._logits(tokens), cache


class TestSpeculativeStub:
    CYCLE = 8

    def _loop(self, n_steps=3, k=4, eos_id=-1, cache_len=64):
        model = _CyclicStub(16, self.CYCLE)
        cfg = get_config("smollm-135m", smoke=True)
        strat = SG.SpeculativeStrategy(model, cfg, A.QuantPolicy(),
                                       mode="none", draft_k=k, ngram=2)
        loop = SG.make_strategy_slot_loop(model, cfg, A.QuantPolicy(), strat,
                                          mode="none", n_steps=n_steps,
                                          eos_id=eos_id)
        cache = {"attn": {"k": jnp.zeros((2, cache_len, 1, 1))}}
        # history = the periodic greedy text, pending token at pos 10
        hist = jnp.tile((jnp.arange(cache_len) + 1) % self.CYCLE,
                        (2, 1)).astype(jnp.int32)
        hist = hist.at[:, 11:].set(0)
        pos0 = jnp.asarray([10, 10], jnp.int32)
        return loop(None, {}, jnp.asarray([3, 3], jnp.int32), cache, pos0,
                    jnp.ones((2,), bool), None, hist)

    def test_full_acceptance_windows(self):
        """With a periodic history every draft matches: each window emits
        draft_k + 1 tokens — the speculation payoff — and the emitted
        stream is exactly the greedy continuation 4,5,6,7,0,1,..."""
        toks, emitted, _, pos, active, _, hist, _ = self._loop(n_steps=2, k=4)
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        assert emitted.all()                    # every lane accepted
        want = [(4 + i) % self.CYCLE for i in range(10)]
        assert toks[0].tolist() == want
        assert np.asarray(pos).tolist() == [20, 20]   # 2 windows * 5 tokens
        # history recorded the emissions at their absolute positions
        assert np.asarray(hist)[0, 11:21].tolist() == want

    def test_eos_mid_window_cuts_tail_and_freezes(self):
        """EOS inside an accepted window: the EOS lane is emitted, later
        lanes in the window are cut, the slot freezes, and its position
        only advances past what was emitted."""
        toks, emitted, _, pos, active, _, _, _ = self._loop(
            n_steps=2, k=4, eos_id=6)
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        # window 1 would emit 4,5,6,7,0 -> cut after the EOS (6)
        assert toks[0, :3].tolist() == [4, 5, 6]
        assert emitted[0].tolist() == [True, True, True] + [False] * 7
        assert not np.asarray(active)[0]
        assert np.asarray(pos)[0] == 13
    def test_capacity_guard_freezes_before_partial_window(self):
        """A slot without room for a WHOLE window freezes rather than
        clamp-writing a partial one."""
        toks, emitted, _, pos, active, _, _, _ = self._loop(
            n_steps=2, k=4, cache_len=17)
        # pos 10 + window 5 <= 17 fits once; a second window would need 20
        assert np.asarray(emitted)[0].tolist() == [True] * 5 + [False] * 5
        assert np.asarray(pos)[0] == 15
        assert not np.asarray(active)[0]

    def test_one_loop_executable_across_match_patterns(self):
        """Closure-side trace counter: histories with full matches, no
        matches, and mixed matches reuse ONE compiled loop — draft
        length is data, never shape."""
        model = _CyclicStub(16, self.CYCLE)
        cfg = get_config("smollm-135m", smoke=True)
        strat = SG.SpeculativeStrategy(model, cfg, A.QuantPolicy(),
                                       mode="none", draft_k=4, ngram=2)
        traces = {"n": 0}
        inner = SG.make_strategy_slot_loop(model, cfg, A.QuantPolicy(),
                                           strat, mode="none", n_steps=2)

        def counted(*args):
            traces["n"] += 1
            return inner(*args)

        loop = jax.jit(counted)
        cache = {"attn": {"k": jnp.zeros((2, 64, 1, 1))}}
        hists = [
            jnp.tile((jnp.arange(64) + 1) % self.CYCLE, (2, 1)),  # hits
            jnp.zeros((2, 64)),                                   # misses
            jax.random.randint(jax.random.PRNGKey(0), (2, 64), 0, 16),
        ]
        for h in hists:
            loop(None, {}, jnp.asarray([3, 3], jnp.int32), cache,
                 jnp.asarray([10, 10], jnp.int32), jnp.ones((2,), bool),
                 jax.random.PRNGKey(0), h.astype(jnp.int32))
        assert traces["n"] == 1


class TestEngineSinglePrompt:
    def test_generate_one_is_generate_batch_at_b1(self):
        """The single-prompt path delegates to generate_batch (B == 1) —
        same executables, so it cannot drift from the batched one."""
        from repro.launch.engine import Engine

        cfg, model, params, qp, policy, toks = _calibrated()
        engine = Engine(model, cfg, policy, params, qp, mode="none")
        one = engine.generate_one(np.asarray(toks[0]), GEN)
        batch = engine.generate_batch({"tokens": toks[:1]}, GEN)
        np.testing.assert_array_equal(np.asarray(one.tokens),
                                      np.asarray(batch.tokens))
        assert one.tokens.shape == (1, GEN)
        with pytest.raises(ValueError, match="1-D prompt"):
            engine.generate_one(np.asarray(toks), GEN)


class TestVerifyPath:
    """model.verify_step — the speculative verify pass — against decode."""

    def test_s1_window_bit_matches_per_slot_decode(self):
        """A 1-token verify window IS per-slot decode: same rope, same
        append, same mask, same contraction order — bit-identical logits
        and cache (the anchor that makes speculative == greedy exact)."""
        cfg, model, params, qp, policy, toks = _calibrated()
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        ctx = SG._serve_ctx("none", policy, qp)
        pos = jnp.full((B,), S, jnp.int32)
        mask = jnp.asarray([True, False])

        def run(fn):
            cache = model.init_cache(B, S + GEN, cfg.dtype, kv_int8=True)
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            return jax.jit(fn)(tok0[:, None], cache)

        lg_d, cache_d = run(lambda t, c: model.decode_step(
            params, t, c, pos, ctx, slot_mask=mask))
        lg_v, cache_v = run(lambda t, c: model.verify_step(
            params, t, c, pos, ctx, slot_mask=mask))
        np.testing.assert_array_equal(np.asarray(lg_d, np.float32),
                                      np.asarray(lg_v, np.float32))
        for a, b in zip(jax.tree.leaves(cache_d), jax.tree.leaves(cache_v)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_verify_matches_jnp_verify(self):
        """policy.use_pallas routes the verify window through the
        flash-prefill kernel's per-request q_start vector; logits must
        stay within the usual kernel-parity budget of the jnp path."""
        cfg, model, params, qp, policy, toks = _calibrated()
        pol_p = A.QuantPolicy(kv_int8=True, use_pallas=True)
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        window = jax.random.randint(jax.random.PRNGKey(9), (B, 4), 0,
                                    cfg.vocab)
        pos = jnp.asarray([S, S - 7], jnp.int32)
        outs = []
        for pol in (policy, pol_p):
            cache = model.init_cache(B, S + GEN + 4, cfg.dtype,
                                     kv_int8=True)
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
            ctx = SG._serve_ctx("none", pol, qp)
            lgv, _ = jax.jit(lambda t, c, ctx=ctx: model.verify_step(
                params, t, c, pos, ctx))(window, cache)
            outs.append(np.asarray(lgv, np.float32))
        np.testing.assert_allclose(outs[1], outs[0], atol=0.1)
