"""Prefill + decode (KV cache / SSM state) equivalence with full forward.

MoE archs use drop-free capacity (cf = E/K) here: with finite capacity the
full forward legitimately drops tokens that the one-token decode path does
not — that's MoE semantics, not a cache bug, so equivalence is only defined
in the drop-free regime.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

B, S = 2, 32


def _dropfree(cfg):
    if cfg.ffn == "moe":
        return cfg.replace(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    return cfg


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = _dropfree(get_config(arch, smoke=True))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, 24, cfg.frame_dim)
                                   ).astype(cfg.dtype)
        logits, _ = model(params, {"frames": frames, "tokens": toks})
        # decoder cache sized to the TOKEN length (the encoder memory
        # length is independent)
        cache = model.init_cache(B, S)
        _, cache = model.prefill(params, {"frames": frames,
                                          "tokens": toks[:, : S - 1]}, cache)
        dec, _ = model.decode_step(params, toks[:, S - 1 : S], cache, S - 1)
    elif cfg.modality == "vlm":
        patches = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.mm_patches, cfg.mm_dim)
        ).astype(cfg.dtype)
        logits, _ = model(params, {"patches": patches, "tokens": toks})
        cache = model.init_cache(B, S + cfg.mm_patches)
        _, cache = model.prefill(
            params, {"patches": patches, "tokens": toks[:, : S - 1]}, cache
        )
        dec, _ = model.decode_step(
            params, toks[:, S - 1 : S], cache, cfg.mm_patches + S - 1
        )
    else:
        logits, _ = model(params, {"tokens": toks})
        cache = model.init_cache(B, S)
        _, cache = model.prefill(params, {"tokens": toks[:, : S - 1]}, cache)
        dec, _ = model.decode_step(params, toks[:, S - 1 : S], cache, S - 1)

    ref = logits[:, -1:, :]
    rel = float(
        jnp.linalg.norm((ref - dec).astype(jnp.float32))
        / (jnp.linalg.norm(ref.astype(jnp.float32)) + 1e-9)
    )
    assert rel < 2e-2, f"{arch}: decode rel err {rel}"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-12b", "mamba2-780m",
                                  "hymba-1.5b"])
def test_multi_step_decode(arch):
    """Greedy-decode 4 tokens step by step == full forward on the grown
    sequence (teacher forcing)."""
    cfg = _dropfree(get_config(arch, smoke=True))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_new = 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    cache = model.init_cache(B, S)
    _, cache = model.prefill(params, {"tokens": toks[:, : S - n_new]}, cache)
    outs = []
    for i in range(n_new):
        pos = S - n_new + i
        lg, cache = model.decode_step(params, toks[:, pos : pos + 1], cache, pos)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    full_logits, _ = model(params, {"tokens": toks})
    ref = full_logits[:, S - n_new :, :]
    rel = float(
        jnp.linalg.norm((ref - dec_logits).astype(jnp.float32))
        / (jnp.linalg.norm(ref.astype(jnp.float32)) + 1e-9)
    )
    assert rel < 2e-2, f"{arch}: multi-step decode rel err {rel}"


def test_swa_ring_buffer_bounded_memory():
    """SWA cache is window-sized regardless of max_len (what makes
    long_500k feasible for SWA archs)."""
    cfg = get_config("mixtral-8x7b", smoke=True)
    model = build_model(cfg)
    cache = model.init_cache(2, 4096)
    k = cache["layer0"]["attn"]["k"]
    assert k.shape[1] == cfg.window  # ring buffer, not max_len


def test_mamba_state_constant_memory():
    cfg = get_config("mamba2-780m", smoke=True)
    model = build_model(cfg)
    cache = model.init_cache(2, 1 << 20)
    ssm = cache["layer0"]["mamba"]["ssm"]
    # state size independent of the 1M max_len
    assert ssm.shape == (2, model.stack.blocks[0].mamba.n_heads,
                         cfg.ssm_state, model.stack.blocks[0].mamba.head_dim)
