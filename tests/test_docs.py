"""The CI docs lane, runnable locally: intra-repo markdown links resolve
and the README documents every serve.py CLI flag (scripts/check_docs.py
is the single source of truth; this test just runs it)."""
import subprocess
import sys
from pathlib import Path

SCRIPT = Path(__file__).resolve().parent.parent / "scripts" / "check_docs.py"


def test_docs_links_and_flag_reference():
    proc = subprocess.run([sys.executable, str(SCRIPT)],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr or proc.stdout


def test_flag_extraction_sees_the_cli():
    """The ast-based flag scan must actually find the serve CLI (guards
    against a refactor silently emptying the docs check)."""
    sys.path.insert(0, str(SCRIPT.parent))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    flags = dict(check_docs.serve_flags())
    assert "--max-slots" in flags and "--prefill-chunk" in flags
    assert len(flags) >= 10
    # enum flags carry their choices so the docs check can demand the
    # modes be documented, not just the flag name
    assert set(flags["--restore"]) == {"journal", "snapshot"}
    assert set(flags["--shed-policy"]) == {"shed", "block"}
    assert flags["--journal"] == []
