"""The static-analysis gate: every analyzer has a red path and the real
engine is clean.

Two halves, mirroring the CI contract:

* **Red tests** — each rule catches a deliberately broken fixture.  Real
  traced fixtures where JAX lets the violation exist (non-dividing
  BlockSpec, raw int8 cast, f32 collective, trained-threshold qparams);
  stub equations where it does not (pallas refuses tracer capture at
  trace time, jnp inserts explicit converts before mixed-dtype
  arithmetic — the rules still guard hand-lowered graphs, so they are
  unit-tested against synthetic equations).
* **Clean tests** — the serving entry points at smoke shapes, the real
  kernel sources, and the converted engine's qparams/cache produce zero
  findings, so the CI lane's "fail on any finding" gate is meaningful.

Also here: the canned-HLO unit tests for launch/hlo_analysis.py (trip
counts under both while-operand orderings, collective byte accounting,
the unparseable-condition -> 1 fallback) and the repro_lint rule tests
over tmp-file fixtures.
"""
import importlib.util
import json
import sys
import textwrap
from pathlib import Path
from types import SimpleNamespace as NS

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import budgets as BU
from repro.analysis import donation as DO
from repro.analysis import dtype_drift as DD
from repro.analysis import entrypoints as EP
from repro.analysis import pallas_contracts as PC
from repro.analysis.dtype_drift import AllowRule
from repro.analysis.jaxprs import find_eqns
from repro.analysis.report import (Finding, make_report, validate_report,
                                   write_report)
from repro.launch import hlo_analysis as H

ROOT = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "repro_lint", ROOT / "scripts" / "repro_lint.py")
RL = importlib.util.module_from_spec(_spec)
sys.modules["repro_lint"] = RL
_spec.loader.exec_module(RL)


def codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# report schema
# ---------------------------------------------------------------------------

class TestReportSchema:
    def test_roundtrip(self, tmp_path):
        f = Finding(analyzer="lint", code="lint.syntax", message="boom")
        rep = make_report([f], tool="repro_lint", entry_points=["src"],
                          backend="cpu")
        assert validate_report(rep) == []
        out = tmp_path / "r.json"
        write_report(str(out), rep)
        assert validate_report(json.loads(out.read_text())) == []

    def test_counts_mismatch_rejected(self):
        rep = make_report([], tool="t")
        bad = dict(rep, counts={"error": 1, "warning": 0})
        assert any("tally" in e for e in validate_report(bad))

    def test_entry_point_count_mismatch_rejected(self):
        rep = make_report([], tool="t", entry_points=["a", "b"])
        bad = dict(rep, n_entry_points=3)
        assert any("n_entry_points" in e for e in validate_report(bad))

    def test_bad_severity_rejected(self):
        rep = json.loads(json.dumps(make_report(
            [Finding(analyzer="a", code="c", message="m")], tool="t")))
        rep["findings"][0]["severity"] = "fatal"
        assert any("severity" in e for e in validate_report(rep))

    def test_write_refuses_invalid(self, tmp_path):
        rep = make_report([], tool="t")
        rep["schema_version"] = 99
        with pytest.raises(ValueError, match="refusing"):
            write_report(str(tmp_path / "x.json"), rep)
        assert not (tmp_path / "x.json").exists()


# ---------------------------------------------------------------------------
# dtype drift
# ---------------------------------------------------------------------------

def _var(dt, shape):
    return NS(aval=NS(dtype=np.dtype(dt), shape=shape))


class TestDtypeDrift:
    def test_promote_red_stub(self):
        # jnp always converts the narrow operand first, so a mixed-dtype
        # add only exists in hand-lowered graphs — unit-test the rule on
        # a synthetic equation
        eqn = NS(primitive=NS(name="add"),
                 invars=[_var("bfloat16", (4,)), _var("float32", (4,))],
                 outvars=[_var("float32", (4,))], params={},
                 source_info=None)
        assert codes(DD.check_dtype_drift(NS(eqns=[eqn]))) == \
            ["drift.promote"]

    def test_explicit_convert_clean(self):
        jx = jax.make_jaxpr(lambda a, b: a.astype(jnp.float32) + b)(
            jnp.ones((4,), jnp.bfloat16), jnp.ones((4,), jnp.float32))
        assert DD.check_dtype_drift(jx) == []

    def test_raw_int_cast_red(self):
        jx = jax.make_jaxpr(lambda x: x.astype(jnp.int8))(
            jnp.ones((4,), jnp.float32))
        assert codes(DD.check_dtype_drift(jx)) == ["drift.raw-int-cast"]

    def test_quantizer_cast_clean(self):
        # round/clip arrive as pjit-wrapped sub-jaxprs: the ancestry walk
        # must see through the wrapper or every real quantizer is flagged
        jx = jax.make_jaxpr(
            lambda x: jnp.clip(jnp.round(x / 0.1), -127, 127)
            .astype(jnp.int8))(jnp.ones((4,), jnp.float32))
        assert DD.check_dtype_drift(jx) == []

    def test_float_collective_red(self):
        jx = jax.make_jaxpr(lambda x: jax.lax.psum(x, "i"),
                            axis_env=[("i", 2)])(jnp.ones((4,), jnp.float32))
        assert codes(DD.check_dtype_drift(jx)) == ["drift.collective"]

    def test_int_collective_clean(self):
        jx = jax.make_jaxpr(lambda x: jax.lax.psum(x, "i"),
                            axis_env=[("i", 2)])(jnp.ones((4,), jnp.int32))
        assert DD.check_dtype_drift(jx) == []

    def test_allowlist_scalar_pmax_in_scope(self):
        # same shape as dist/collectives.py::compressed_psum — the scoped
        # one-scalar AllowRule admits it
        def compressed_psum(x):
            return jax.lax.pmax(jnp.max(jnp.abs(x)), "i")

        jx = jax.make_jaxpr(compressed_psum, axis_env=[("i", 2)])(
            jnp.ones((8,), jnp.float32))
        assert DD.check_dtype_drift(jx) == []

    def test_allowlist_max_elems_bounds_the_hole(self):
        # the exemption is ONE scalar: a tensor pmax in the same scope
        # must still be flagged
        def compressed_psum(x):
            return jax.lax.pmax(x, "i")

        jx = jax.make_jaxpr(compressed_psum, axis_env=[("i", 2)])(
            jnp.ones((8,), jnp.float32))
        assert codes(DD.check_dtype_drift(jx)) == ["drift.collective"]

    def test_allow_rule_matching(self):
        rule = AllowRule(code="drift.collective", primitive="pmax",
                         max_elems=1, note="n")
        eqn = NS(primitive=NS(name="pmax"), source_info=None)
        assert rule.matches("drift.collective", eqn, 1)
        assert not rule.matches("drift.collective", eqn, 2)
        assert not rule.matches("drift.promote", eqn, 1)
        assert not rule.matches(
            "drift.collective", NS(primitive=NS(name="psum"),
                                   source_info=None), 1)


# ---------------------------------------------------------------------------
# pallas contracts
# ---------------------------------------------------------------------------

def _decode_attention_jaxpr(kv_bits=8):
    from repro.kernels import ops
    b, s, kv, g, d = 2, 32, 3, 4, 16
    dp = d if kv_bits == 8 else d // 2
    q = jnp.ones((b, kv, g, d), jnp.float32)
    kp = jnp.ones((b, s, kv, dp), jnp.int8)
    vp = jnp.ones((b, s, kv, dp), jnp.int8)
    sc = jnp.ones((kv,), jnp.float32)
    return jax.make_jaxpr(
        lambda *a: ops.decode_attention(*a, block_s=16, kv_bits=kv_bits))(
        q, kp, vp, sc, sc, jnp.int32(7))


class TestPallasContracts:
    def test_real_kernel_clean(self):
        jx = _decode_attention_jaxpr()
        assert PC.check_pallas_jaxpr(jx, expect_interpret=True) == []

    def test_int4_kernel_clean(self):
        # dp = D/2 nibbles: the packing rule must see the pool and accept
        jx = _decode_attention_jaxpr(kv_bits=4)
        assert PC.check_pallas_jaxpr(jx, expect_interpret=True) == []

    def test_interpret_mismatch_red(self):
        jx = _decode_attention_jaxpr()
        assert codes(PC.check_pallas_jaxpr(jx, expect_interpret=False)) == \
            ["pallas.interpret"]

    def test_block_divide_red(self):
        import jax.experimental.pallas as pl

        def _copy(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def bad(x):
            # (3,) blocks over an (8,) operand: pallas pads silently
            return pl.pallas_call(
                _copy, grid=(3,),
                in_specs=[pl.BlockSpec((3,), lambda i: (i,))],
                out_specs=pl.BlockSpec((3,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((8,), x.dtype),
                interpret=True)(x)

        jx = jax.make_jaxpr(bad)(jnp.ones((8,)))
        found = PC.check_pallas_jaxpr(jx, expect_interpret=True)
        assert set(codes(found)) == {"pallas.block-divide"}
        assert len(found) == 2  # input and output operand both flagged

    def test_prefetch_arity_red(self):
        # doctor a REAL launch's grid mapping: claim one more scalar-
        # prefetch operand than the index maps were written for
        eqn = find_eqns(_decode_attention_jaxpr(), "pallas_call")[0]
        gm = eqn.params["grid_mapping"]
        doctored = gm.replace(num_index_operands=gm.num_index_operands + 1)
        found = PC._block_mapping_findings(eqn, doctored, "t")
        assert set(codes(found)) == {"pallas.prefetch-arity"}

    def test_int4_packing_red_stub(self):
        def bm(shape, dt):
            return NS(array_shape_dtype=NS(shape=shape, dtype=np.dtype(dt)))

        # pool width 12 against head dim 16: neither D nor D/2
        gm = NS(block_mappings=[bm((2, 16, 3, 16), "float32"),
                                bm((2, 16, 3, 12), "int8")], num_inputs=2)
        assert codes(PC._packing_findings(NS(source_info=None), gm, "t")) \
            == ["pallas.int4-packing"]
        ok = NS(block_mappings=[bm((2, 16, 3, 16), "float32"),
                                bm((2, 16, 3, 8), "int8")], num_inputs=2)
        assert PC._packing_findings(NS(source_info=None), ok, "t") == []

    def test_kernel_closure_red_stub(self):
        # pallas itself rejects tracer capture at trace time on this
        # backend, so the constvar case is pinned with a stub equation
        kern = NS(constvars=[NS(aval="f32[4]")])
        gm = NS(grid=(), num_index_operands=0, num_inputs=0,
                block_mappings=[])
        eqn = NS(primitive=NS(name="pallas_call"),
                 params={"grid_mapping": gm, "interpret": True,
                         "jaxpr": kern},
                 source_info=None)
        assert codes(PC.check_pallas_jaxpr(NS(eqns=[eqn]),
                                           expect_interpret=True)) == \
            ["pallas.kernel-closure"]

    def test_source_rules_red(self):
        bad = textwrap.dedent("""\
            import functools
            from jax.experimental import pallas as pl

            def build(x, table):
                def _kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...]
                bound = functools.partial(_kernel, table=table)
                return pl.pallas_call(bound, grid=(4,),
                                      in_specs=[pl.BlockSpec(
                                          (8,), lambda i: (i + table,))],
                                      out_shape=None)(x)
            """)
        assert codes(PC.check_source_text(bad)) == [
            "pallas.interpret-threading", "pallas.interpret-threading",
            "pallas.static-capture", "pallas.static-capture"]

    def test_source_rules_clean(self):
        good = textwrap.dedent("""\
            import functools
            import jax
            from jax.experimental import pallas as pl

            @functools.partial(jax.jit, static_argnames=("block",))
            def build(x, block, interpret):
                def _kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...]
                bound = functools.partial(_kernel, block=block)
                return pl.pallas_call(bound, grid=(4,),
                                      in_specs=[pl.BlockSpec(
                                          (block,), lambda i: (i,))],
                                      interpret=interpret,
                                      out_shape=None)(x)
            """)
        assert PC.check_source_text(good) == []

    def test_module_registry_red(self, tmp_path):
        (tmp_path / "rogue.py").write_text(
            "from jax.experimental import pallas as pl\n\n"
            "def f(x):\n"
            "    return pl.pallas_call(lambda r, o: None,"
            " out_shape=None)(x)\n")
        assert codes(PC.check_kernel_sources(str(tmp_path))) == \
            ["pallas.module-registry"]

    def test_real_kernel_sources_clean(self):
        assert PC.check_kernel_sources() == []


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

class TestBudgets:
    def test_clean_counts(self):
        counts = {"prefill": 1, "decode": 1, "insert": 1, "resume": 0}
        assert BU.check_executable_budgets(counts) == []
        assert BU.check_executable_budgets(counts,
                                           require_all_ran=True) == []

    def test_retrace_red(self):
        found = BU.check_executable_budgets({"decode": 3})
        assert codes(found) == ["budget.retrace"]

    def test_undeclared_piece_red(self):
        found = BU.check_executable_budgets({"decode": 1, "newpiece": 1})
        assert codes(found) == ["budget.undeclared"]

    def test_never_traced_red(self):
        counts = {"prefill": 0}
        assert BU.check_executable_budgets(counts) == []  # partial session
        assert codes(BU.check_executable_budgets(
            counts, require_all_ran=True)) == ["budget.never-traced"]

    def test_compile_watch_cold_then_warm(self):
        @jax.jit
        def f(x):
            return x * 2.0 + 1.0

        x = jnp.arange(7.0)
        with BU.CompileWatch() as cold:
            f(x).block_until_ready()
        assert cold.count >= 1
        assert codes(cold.check(max_compiles=0, what="cold jit")) == \
            ["budget.compile"]
        with BU.CompileWatch() as warm:
            f(x).block_until_ready()
        assert warm.count == 0
        assert warm.check(max_compiles=0, what="warm jit") == []


# ---------------------------------------------------------------------------
# donation / freeze
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def calibrated_qparams():
    from repro.configs import get_config
    from repro.core import api as A
    from repro.launch import steps as ST
    from repro.models import build_model

    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=True)
    qp = A.init_qparams(model, params, policy)
    qp = ST.make_calibrate_step(model, cfg, policy)(params, qp,
                                                    {"tokens": toks})
    return qp, policy


class TestDonationAndFreeze:
    def test_duplicate_buffer_red(self):
        x = jnp.arange(4.0)
        found = DO.check_duplicate_donation({"a": x, "b": x,
                                             "c": jnp.arange(3.0)})
        assert codes(found) == ["donate.duplicate-buffer"]

    def test_distinct_buffers_clean(self):
        # distinct VALUES on purpose: identical constants could legally
        # share a deduplicated buffer
        tree = {"a": jnp.arange(4.0), "b": jnp.arange(4.0) + 1.0}
        assert DO.check_duplicate_donation(tree) == []

    def test_trained_thresholds_red(self, calibrated_qparams):
        from repro.core import api as A

        qp, policy = calibrated_qparams
        qp_t = A.finalize_calibration(qp, policy, train_thresholds=True)
        assert codes(DO.check_frozen_qparams(qp_t)) == \
            ["freeze.log2_t-leaf", "freeze.trainable-mask"]

    def test_frozen_qparams_clean(self, calibrated_qparams):
        from repro.core import api as A

        qp, policy = calibrated_qparams
        assert DO.check_frozen_qparams(
            A.finalize_calibration(qp, policy)) == []
        # and freeze_thresholds undoes the trained parameterization
        qp_t = A.finalize_calibration(qp, policy, train_thresholds=True)
        assert DO.check_frozen_qparams(A.freeze_thresholds(qp_t)) == []

    def test_fake_quant_eqn_red(self):
        from repro.kernels import ops

        jx = jax.make_jaxpr(
            lambda x: ops.fake_quant(x, jnp.float32(2.0),
                                     jnp.float32(0.9)))(jnp.ones((8, 16)))
        assert codes(DO.check_no_fake_quant(jx)) == ["freeze.fake-quant-eqn"]

    def test_real_kernel_no_fake_quant(self):
        assert DO.check_no_fake_quant(_decode_attention_jaxpr()) == []


# ---------------------------------------------------------------------------
# the serving surface is clean
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def entry_points_int8():
    return EP.build_entry_points("smollm-135m", use_pallas=True, kv_bits=8)


class TestEntryPointsClean:
    def test_all_entries_traced(self, entry_points_int8):
        assert {ep.name for ep in entry_points_int8} == {
            "prefill", "chunked_prefill", "decode_loop", "decode_block",
            "resume", "speculative_verify"}

    def test_zero_findings(self, entry_points_int8):
        found = EP.analyze_entry_points(entry_points_int8)
        assert found == [], "\n".join(
            f"{f.entry_point}: {f.code}: {f.message}" for f in found)

    def test_pallas_actually_on_the_surface(self, entry_points_int8):
        # the clean verdict is vacuous unless the traced graphs really
        # contain pallas launches
        n = sum(len(find_eqns(ep.jaxpr, "pallas_call"))
                for ep in entry_points_int8)
        assert n > 0


# ---------------------------------------------------------------------------
# hlo_analysis (canned post-optimization HLO text)
# ---------------------------------------------------------------------------

_LOOPED_HLO = textwrap.dedent("""\
    HloModule m

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %one = s32[] constant(1)
      %next = s32[] add(%i, %one)
      %a = f32[8,4] constant({...})
      %b = f32[4,8] constant({...})
      %d = f32[8,8] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %r = (s32[], f32[8,8]) tuple(%next, %d)
    }

    %cond (q: (s32[], f32[8,8])) -> pred[] {
      %q = (s32[], f32[8,8]) parameter(0)
      %j = s32[] get-tuple-element(%q), index=0
      %n = s32[] constant(5)
      %lt = pred[] compare(%j, %n), direction=LT
    }

    ENTRY %main (arg: f32[8,8]) -> (s32[], f32[8,8]) {
      %arg = f32[8,8] parameter(0)
      %z = s32[] constant(0)
      %t = (s32[], f32[8,8]) tuple(%z, %arg)
      %w = (s32[], f32[8,8]) while(%t), condition=%cond, body=%body
    }
    """)

# one dot per iteration: 2 * prod(out 8x8) * contracted 4 = 512 flops
_DOT_FLOPS = 2 * 64 * 4


class TestHloAnalysis:
    def test_parse_computations(self):
        comps, entry = H.parse_computations(_LOOPED_HLO)
        assert entry == "main"
        assert set(comps) == {"main", "cond", "body"}

    def test_trip_counted_dot_flops_condition_first(self):
        assert H.analyze(_LOOPED_HLO).dot_flops == 5 * _DOT_FLOPS

    def test_trip_counted_dot_flops_body_first(self):
        # the while operands print in either order depending on the XLA
        # version — both must resolve to the same trip count
        rev = _LOOPED_HLO.replace("condition=%cond, body=%body",
                                  "body=%body, condition=%cond")
        assert H.analyze(rev).dot_flops == 5 * _DOT_FLOPS

    def test_unparseable_condition_counts_once(self):
        # no s32[] constant in the condition: conservatively trip 1
        degenerate = _LOOPED_HLO.replace(
            "  %n = s32[] constant(5)\n", "").replace(
            "compare(%j, %n)", "compare(%j, %j)")
        assert H.analyze(degenerate).dot_flops == _DOT_FLOPS

    def test_collective_bytes(self):
        hlo = textwrap.dedent("""\
            HloModule c

            %sum (x: f32[], y: f32[]) -> f32[] {
              %x = f32[] parameter(0)
              %y = f32[] parameter(1)
              %s = f32[] add(%x, %y)
            }

            ENTRY %main (p: f32[1024]) -> f32[1024] {
              %p = f32[1024] parameter(0)
              %ar = f32[1024] all-reduce(%p), to_apply=%sum
            }
            """)
        costs = H.analyze(hlo)
        assert costs.collective_bytes == 1024 * 4
        assert costs.collective_by_kind["all-reduce"] == 1024 * 4
        assert costs.collective_by_kind["all-gather"] == 0.0


# ---------------------------------------------------------------------------
# repro_lint
# ---------------------------------------------------------------------------

def _lint(tmp_path, src, name="fixture.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return RL.lint_paths([p])


class TestReproLint:
    def test_tracer_cast_red(self, tmp_path):
        found = _lint(tmp_path, """\
            import jax.numpy as jnp

            def schedule(step):
                lr = jnp.cos(step)
                return lr * float(step)
            """)
        assert codes(found) == ["lint.tracer-cast"]

    def test_tracer_cast_suppressed(self, tmp_path):
        found = _lint(tmp_path, """\
            import jax.numpy as jnp

            def schedule(step):
                lr = jnp.cos(step)
                return lr * float(step)  # repro-lint: ok
            """)
        assert found == []

    def test_host_handoff_cast_clean(self, tmp_path):
        # int(rid) fed straight INTO a jax call runs before tracing —
        # an explicit host->device handoff, not a tracer readback
        found = _lint(tmp_path, """\
            import jax

            def slot_key(key, rid):
                return jax.random.fold_in(key, int(rid))
            """)
        assert found == []

    def test_host_in_scan_red(self, tmp_path):
        found = _lint(tmp_path, """\
            import time
            from jax import lax

            def body(c, x):
                t = time.time()
                return c + t, x

            def run(xs):
                return lax.scan(body, 0.0, xs)
            """)
        assert codes(found) == ["lint.host-in-scan"]

    def test_jit_method_red(self, tmp_path):
        found = _lint(tmp_path, """\
            import jax

            class Engine:
                @jax.jit
                def step(self, x):
                    return x
            """)
        assert codes(found) == ["lint.jit-method"]

    def test_undocumented_flag_red(self, tmp_path):
        found = _lint(tmp_path, """\
            import argparse

            ap = argparse.ArgumentParser()
            ap.add_argument("--verbose")
            ap.add_argument("--arch", help="model architecture")
            """)
        assert codes(found) == ["lint.undocumented-flag"]
        assert "--verbose" in found[0].message

    def test_syntax_error_is_a_finding(self, tmp_path):
        found = _lint(tmp_path, "def broken(:\n")
        assert codes(found) == ["lint.syntax"]

    def test_repo_is_lint_clean(self):
        found = RL.lint_paths([ROOT / d for d in
                               ("src", "tests", "scripts", "benchmarks",
                                "examples")])
        assert found == [], "\n".join(
            f"{f.location}: {f.code}" for f in found)
