"""The KVCache protocol: layout units, dense↔paged parity, ring parity,
prefix-sharing admission, and the extended no-retrace contract.

Acceptance (ISSUE 4): the DenseCache/RingCache refactor is bit-exact vs
the pre-refactor behavior (scheduler/fastpath suites pin that), the
PagedCache matches dense logits bit-for-bit (the identity block table is
literally a reshape of the dense layout, and a permuted table only moves
storage), and a shared prompt admits with ZERO prefill executions —
verified on the scheduler's call counters while the trace counters stay
pinned at one executable per piece.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (DenseCache, PagedCache, RingCache, make_cache,
                         set_table_row, splice_dense_into_pages)
from repro.configs import get_config
from repro.core import api as A
from repro.launch import steps as ST
from repro.launch.scheduler import Request, SlotScheduler
from repro.models import build_model
from repro.models.attention import Attention

B, S, GEN = 2, 32, 6
CHUNK = 8


def _calibrated(arch="smollm-135m", kv_int8=True, **pol):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    policy = A.QuantPolicy(kv_int8=kv_int8, **pol)
    qp = A.init_qparams(model, params, policy)
    qp = ST.make_calibrate_step(model, cfg, policy)(params, qp,
                                                    {"tokens": toks})
    qp = A.finalize_calibration(qp, policy)
    return cfg, model, params, qp, policy, toks


def _tiles(key, b, s, kv=2, d=8):
    return jax.random.randint(key, (b, s, kv, d), -127, 127, jnp.int8)


class TestLayoutUnits:
    def test_make_cache_layout_selection(self):
        kw = dict(n_kv=2, head_dim=8, dtype=jnp.bfloat16)
        assert isinstance(make_cache(1, 64, layout="dense", **kw),
                          DenseCache)
        assert isinstance(make_cache(1, 64, layout="ring", window=16, **kw),
                          RingCache)
        # window >= max_len needs no ring
        assert isinstance(make_cache(1, 16, layout="ring", window=16, **kw),
                          DenseCache)
        c = make_cache(1, 64, layout="paged", page_size=16, **kw)
        assert isinstance(c, PagedCache) and c.capacity == 64
        with pytest.raises(ValueError, match="layout"):
            make_cache(1, 64, layout="torus", **kw)

    def test_dense_append_slots_inactive_readback(self):
        cache = DenseCache.init(B, 16, 2, 8, dtype=jnp.int8, quantized=True)
        cache = cache.append(_tiles(jax.random.PRNGKey(0), B, 16),
                             _tiles(jax.random.PRNGKey(1), B, 16), 0)
        new_k = _tiles(jax.random.PRNGKey(2), B, 1)
        new_v = _tiles(jax.random.PRNGKey(3), B, 1)
        starts = jnp.asarray([3, 7], jnp.int32)
        upd = cache.append_slots(new_k, new_v, starts,
                                 active=jnp.asarray([True, False]))
        np.testing.assert_array_equal(np.asarray(upd.k[0, 3]),
                                      np.asarray(new_k[0, 0]))
        # inactive slot 1 is bit-identical everywhere
        np.testing.assert_array_equal(np.asarray(upd.k[1]),
                                      np.asarray(cache.k[1]))

    def test_ring_position_invariant(self):
        """Position p lives at slot p % window after a one-shot prompt
        write and stays there through single-token appends."""
        win, s = 8, 13
        cache = RingCache.init(1, win, 1, 4, dtype=jnp.float32)
        k = jnp.arange(s, dtype=jnp.float32).reshape(1, s, 1, 1) \
            * jnp.ones((1, s, 1, 4))
        cache = cache.append(k, k, 0)
        for p in range(s - win, s):           # surviving positions
            assert float(cache.k[0, p % win, 0, 0]) == p
        tok = jnp.full((1, 1, 1, 4), float(s))
        cache = cache.append(tok, tok, s)
        assert float(cache.k[0, s % win, 0, 0]) == s
        abs_pos = np.asarray(cache.abs_positions(s))
        assert abs_pos[s % win] == s

    def test_paged_dense_view_roundtrip(self):
        """Token-scatter append through a PERMUTED table gathers back to
        exactly the dense contents."""
        dense = DenseCache.init(B, 32, 2, 8, dtype=jnp.int8, quantized=True)
        paged = PagedCache.init(B, 32, 2, 8, dtype=jnp.int8, quantized=True,
                                page_size=8)
        # scramble the private pages (still per-slot distinct)
        perm = np.random.RandomState(0).permutation(B * 4)
        paged = dataclasses.replace(
            paged, table=jnp.asarray(perm.reshape(B, 4), jnp.int32))
        kq = _tiles(jax.random.PRNGKey(0), B, 20)
        vq = _tiles(jax.random.PRNGKey(1), B, 20)
        dense = dense.append(kq, vq, 5)
        paged = paged.append(kq, vq, 5)
        dk, dv = dense.dense_view()
        pk, pv = paged.dense_view()
        np.testing.assert_array_equal(np.asarray(dk[:, 5:25]),
                                      np.asarray(pk[:, 5:25]))
        np.testing.assert_array_equal(np.asarray(dv[:, 5:25]),
                                      np.asarray(pv[:, 5:25]))

    def test_paged_append_slots_matches_dense(self):
        dense = DenseCache.init(B, 32, 2, 8, dtype=jnp.int8, quantized=True)
        paged = PagedCache.init(B, 32, 2, 8, dtype=jnp.int8, quantized=True,
                                page_size=8)
        kq = _tiles(jax.random.PRNGKey(0), B, 1)
        vq = _tiles(jax.random.PRNGKey(1), B, 1)
        starts = jnp.asarray([9, 31], jnp.int32)
        active = jnp.asarray([True, True])
        d = dense.append_slots(kq, vq, starts, active=active)
        p = paged.append_slots(kq, vq, starts, active=active)
        np.testing.assert_array_equal(np.asarray(d.k),
                                      np.asarray(p.dense_view()[0]))

    def test_splice_and_table_ops_stacked_layers(self):
        """The scheduler-side page ops tolerate a leading (L,) layer axis
        (scanned stacks): splice + row write + gather round-trips."""
        L, nb, ps = 3, 4, 8
        paged = PagedCache.init(B, nb * ps, 2, 8, dtype=jnp.int8,
                                quantized=True, page_size=ps)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), paged)
        slot_dense = DenseCache.init(1, nb * ps, 2, 8, dtype=jnp.int8,
                                     quantized=True)
        kq = _tiles(jax.random.PRNGKey(0), 1, nb * ps)
        slot_dense = dataclasses.replace(slot_dense, k=kq, v=kq)
        sl_stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), slot_dense)
        row = jnp.asarray([1, 0, 3, 2], jnp.int32)   # slot 0's pages, permuted
        out = splice_dense_into_pages(stacked, sl_stacked, row)
        out = set_table_row(out, 1, row)
        # layer 2, slot 1 now maps the spliced tiles through `row`
        got = np.asarray(out.k[2])[np.asarray(row)].reshape(nb * ps, 2, 8)
        np.testing.assert_array_equal(got, np.asarray(kq[0]))


class TestDensePagedParity:
    """Same tokens through the full model: dense and paged caches must
    produce bit-identical logits and greedy generations (acceptance)."""

    def _serve(self, layout, model, cfg, params, qp, policy, toks,
               chunked=False):
        cache = model.init_cache(B, S + GEN, cfg.dtype, kv_int8=True,
                                 layout=layout, page_size=8)
        if chunked:
            pre = jax.jit(ST.make_prefill_step(model, cfg, policy,
                                               mode="none",
                                               prefill_chunk=CHUNK))
            lg, cache = pre(params, qp, {"tokens": toks}, cache,
                            jnp.full((B,), S, jnp.int32))
        else:
            pre = jax.jit(ST.make_prefill_step(model, cfg, policy,
                                               mode="none"))
            lg, cache = pre(params, qp, {"tokens": toks}, cache)
        loop = jax.jit(ST.make_decode_loop(model, cfg, policy, mode="none",
                                           n_steps=GEN))
        tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
        out, _ = loop(params, qp, tok0, cache, S)
        return np.asarray(lg), np.asarray(out)

    def test_one_shot_bit_identical(self):
        cfg, model, params, qp, policy, toks = _calibrated()
        lg_d, out_d = self._serve("dense", model, cfg, params, qp, policy,
                                  toks)
        lg_p, out_p = self._serve("paged", model, cfg, params, qp, policy,
                                  toks)
        np.testing.assert_array_equal(lg_d, lg_p)
        np.testing.assert_array_equal(out_d, out_p)

    def test_chunked_prefill_bit_identical(self):
        cfg, model, params, qp, policy, toks = _calibrated()
        lg_d, out_d = self._serve("dense", model, cfg, params, qp, policy,
                                  toks, chunked=True)
        lg_p, out_p = self._serve("paged", model, cfg, params, qp, policy,
                                  toks, chunked=True)
        np.testing.assert_array_equal(lg_d, lg_p)
        np.testing.assert_array_equal(out_d, out_p)

    def test_fused_kernels_paged_matches_dense(self):
        """policy.use_pallas: both kernels read through the block table
        (identity for dense) — same compiled body, same numbers."""
        cfg, model, params, qp, policy, toks = _calibrated(
            use_pallas=True)
        # cache length must tile for the fused decode kernel
        cap = -(-(S + GEN) // 128) * 128
        outs = {}
        for layout in ("dense", "paged"):
            cache = model.init_cache(B, cap, cfg.dtype, kv_int8=True,
                                     layout=layout, page_size=128)
            pre = jax.jit(ST.make_prefill_step(model, cfg, policy,
                                               mode="none",
                                               prefill_chunk=CHUNK))
            lg, cache = pre(params, qp, {"tokens": toks}, cache,
                            jnp.full((B,), S, jnp.int32))
            loop = jax.jit(ST.make_decode_loop(model, cfg, policy,
                                               mode="none", n_steps=GEN))
            tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            out, _ = loop(params, qp, tok0, cache, S)
            outs[layout] = (np.asarray(lg), np.asarray(out))
        np.testing.assert_array_equal(outs["dense"][0], outs["paged"][0])
        np.testing.assert_array_equal(outs["dense"][1], outs["paged"][1])


class TestRingParity:
    def test_ring_decode_matches_forced_dense_window(self):
        """An SWA layer decoding through its ring buffer must match the
        same layer decoding through a forced-dense cache with window
        masking (two code paths, one contraction)."""
        win, d_model = 8, 32
        attn = Attention(d_model, 4, 2, 8, path="t/attn", window=win,
                         dtype=jnp.float32)
        params = attn.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d_model),
                              jnp.float32)
        ring = attn.init_cache(B, S + 4, jnp.float32, layout="ring")
        dense = attn.init_cache(B, S + 4, jnp.float32, layout="dense")
        assert isinstance(ring, RingCache) and isinstance(dense, DenseCache)
        y_r, ring = attn.prefill(params, x, ring)
        y_d, dense = attn.prefill(params, x, dense)
        np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_d),
                                   atol=1e-5)
        for step in range(3):
            xt = jax.random.normal(jax.random.PRNGKey(2 + step),
                                   (B, 1, d_model), jnp.float32)
            o_r, ring = attn.decode(params, xt, ring, S + step)
            o_d, dense = attn.decode(params, xt, dense, S + step)
            np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_d),
                                       atol=1e-5,
                                       err_msg=f"decode step {step}")


class TestPrefixSharing:
    def _sched(self, layout, model, cfg, policy, params, qp, **kw):
        kw.setdefault("mode", "none")
        kw.setdefault("max_slots", 2)
        kw.setdefault("prompt_cap", S)
        kw.setdefault("gen_cap", GEN + 2)
        kw.setdefault("prefill_chunk", CHUNK)
        kw.setdefault("block_steps", 3)
        kw.setdefault("page_size", 8)
        return SlotScheduler(model, cfg, policy, params, qp,
                             cache_layout=layout, **kw)

    def test_shared_prompt_zero_prefill(self):
        """ISSUE acceptance: a repeated prompt admits with ZERO prefill
        executions (call-counter-verified), generates exactly the tokens
        the dense scheduler generates, and leaves one compiled executable
        per piece."""
        cfg, model, params, qp, policy, toks = _calibrated()
        same = [Request(rid=r, tokens=np.asarray(toks[0, :S]), max_gen=GEN)
                for r in range(3)]
        ref = {c.rid: c for c in self._sched(
            "dense", model, cfg, policy, params, qp).run(list(same))}
        sched = self._sched("paged", model, cfg, policy, params, qp)
        done = {c.rid: c for c in sched.run(list(same))}
        for r in ref:
            assert done[r].tokens == ref[r].tokens, r
        assert sched.call_counts()["prefill"] == 1   # 3 admissions, 1 run
        stats = sched.prefix_stats()
        assert stats["hits"] == 2 and stats["misses"] == 1
        assert stats["shared_tokens"] == 2 * S

    def test_partial_tail_page_is_private(self):
        """A prompt that does NOT fill its last page still shares the full
        pages; the tail page is copied, not shared, so the sharer's
        decode writes can't corrupt the original (tokens stay equal to
        the dense run for both residents)."""
        cfg, model, params, qp, policy, toks = _calibrated()
        L = 27                                  # 3 full pages of 8 + tail 3
        same = [Request(rid=r, tokens=np.asarray(toks[1, :L]), max_gen=GEN)
                for r in range(2)]
        ref = {c.rid: c for c in self._sched(
            "dense", model, cfg, policy, params, qp).run(list(same))}
        sched = self._sched("paged", model, cfg, policy, params, qp)
        done = {c.rid: c for c in sched.run(list(same))}
        for r in ref:
            assert done[r].tokens == ref[r].tokens, r
        assert sched.call_counts()["prefill"] == 1
        assert sched.prefix_stats()["hits"] == 1

    def test_paged_ragged_matches_dense(self):
        """Mixed-length DISTINCT prompts (all misses): the paged layout
        is pure storage indirection — token-for-token equal to dense."""
        cfg, model, params, qp, policy, toks = _calibrated()
        reqs = lambda: [Request(rid=r, tokens=np.asarray(toks[r % B, :n]),
                                max_gen=GEN)
                        for r, n in enumerate([32, 20, 9])]
        ref = {c.rid: c for c in self._sched(
            "dense", model, cfg, policy, params, qp).run(reqs())}
        sched = self._sched("paged", model, cfg, policy, params, qp)
        done = {c.rid: c for c in sched.run(reqs())}
        for r in ref:
            assert done[r].tokens == ref[r].tokens, r

    def test_paged_no_retrace_across_patterns(self):
        """Extended no-retrace acceptance: ragged patterns, repeated
        prompts, and shared-prefix admissions all ride the SAME compiled
        executables — including the paged splice/table/copy pieces."""
        cfg, model, params, qp, policy, toks = _calibrated()
        sched = self._sched("paged", model, cfg, policy, params, qp)
        sched.run([Request(rid=r, tokens=np.asarray(toks[r % B, :n]),
                           max_gen=GEN)
                   for r, n in enumerate([32, 20, 16])])
        sched.run([Request(rid=r, tokens=np.asarray(toks[0, :27]),
                           max_gen=GEN - 2) for r in range(3)])
        counts = sched.executable_counts()
        assert counts == {"prefill": 1, "decode": 1, "insert": 1,
                          "resume": 0, "set_row": 1, "copy_page": 1}, counts
        assert sched.prefix_stats()["hits"] >= 2

    def test_scanned_stack_paged_matches_dense(self):
        """scan_layers=True stacks a leading (L,) axis on every cache
        leaf; the paged splice/table ops must still land pages correctly
        (token parity with the dense scheduler)."""
        cfg, model, params, qp, policy, toks = _calibrated()
        cfg_s = cfg.replace(scan_layers=True)
        model_s = build_model(cfg_s)
        params_s = model_s.init(jax.random.PRNGKey(0))
        qp_s = A.init_qparams(model_s, params_s, policy)
        qp_s = ST.make_calibrate_step(model_s, cfg_s, policy)(
            params_s, qp_s, {"tokens": toks})
        qp_s = A.finalize_calibration(qp_s, policy)
        reqs = lambda: [Request(rid=r, tokens=np.asarray(toks[0, :n]),
                                max_gen=GEN) for r, n in enumerate([32, 32])]
        ref = {c.rid: c for c in self._sched(
            "dense", model_s, cfg_s, policy, params_s, qp_s).run(reqs())}
        sched = self._sched("paged", model_s, cfg_s, policy, params_s, qp_s)
        done = {c.rid: c for c in sched.run(reqs())}
        for r in ref:
            assert done[r].tokens == ref[r].tokens, r
        assert sched.prefix_stats()["hits"] == 1


class TestEngineFacade:
    def test_engine_generate_batch_matches_cli_pipeline(self):
        """Engine.from_checkpoint + generate_batch reproduces the
        hand-assembled prefill + scanned-decode pipeline token for
        token."""
        from repro.launch.engine import Engine

        cfg, model, params, qp, policy, toks = _calibrated(kv_int8=True)
        engine = Engine(model, cfg, policy, params, qp, mode="none",
                        cache_layout="dense")
        res = engine.generate_batch({"tokens": toks}, GEN)
        pre = jax.jit(ST.make_prefill_step(model, cfg, policy, mode="none"))
        cache = model.init_cache(B, S + GEN, cfg.dtype, kv_int8=True,
                                 layout="dense")
        lg, cache = pre(params, qp, {"tokens": toks}, cache)
        loop = jax.jit(ST.make_decode_loop(model, cfg, policy, mode="none",
                                           n_steps=GEN))
        tok0 = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
        want, _ = loop(params, qp, tok0, cache, S)
        np.testing.assert_array_equal(np.asarray(res.tokens),
                                      np.asarray(want))

    def test_engine_paged_prefix_generate(self):
        """engine.generate with the paged layout: repeated prompts hit
        the prefix store across CALLS too (the scheduler — and its shared
        pages — persists on the engine)."""
        from repro.launch.engine import Engine

        cfg, model, params, qp, policy, toks = _calibrated(kv_int8=True)
        engine = Engine(model, cfg, policy, params, qp, mode="none",
                        cache_layout="paged", page_size=8,
                        prefill_chunk=CHUNK)
        req = lambda r: Request(rid=r, tokens=np.asarray(toks[0, :S]),
                                max_gen=GEN)
        a = engine.generate([req(0)], max_slots=2, prompt_cap=S,
                            gen_cap=GEN + 2)
        b = engine.generate([req(1)], max_slots=2, prompt_cap=S,
                            gen_cap=GEN + 2)
        assert a[0].tokens == b[0].tokens
        sched = engine.make_scheduler(max_slots=2, prompt_cap=S,
                                      gen_cap=GEN + 2)
        assert sched.call_counts()["prefill"] == 1
        assert sched.prefix_stats()["hits"] == 1


class TestRollback:
    """``KVCache.rollback`` (ISSUE 5): the speculative-decode rewind.

    Dense/ring rewinds are pure position bookkeeping (entries past the
    rollback point are dead data the masks never read); the paged layout
    additionally re-points rewound table blocks at private pages,
    copy-on-rewind for the partially-live boundary block, so a rewind
    into a SHARED prefix page can never let a later append mutate
    refcounted storage."""

    def test_dense_and_ring_are_noops(self):
        for cache in (DenseCache.init(B, 16, 2, 8, dtype=jnp.int8,
                                      quantized=True),
                      RingCache.init(B, 16, 2, 8)):
            cache = dataclasses.replace(
                cache, k=jnp.ones_like(cache.k), v=jnp.ones_like(cache.v))
            rolled = cache.rollback(jnp.asarray([3, 7], jnp.int32))
            for a, b in zip(jax.tree.leaves(rolled), jax.tree.leaves(cache)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def _paged(self, extra=2, ps=8, nb=4):
        cache = PagedCache.init(1, nb * ps, 1, 8, dtype=jnp.int8,
                                quantized=True, page_size=ps,
                                extra_pages=extra)
        # distinct content per pool cell so copies are traceable
        n = cache.k.size
        fill = (jnp.arange(n, dtype=jnp.int32) % 101 - 50).astype(jnp.int8)
        return dataclasses.replace(cache, k=fill.reshape(cache.k.shape),
                                   v=(-fill).reshape(cache.v.shape))

    def test_paged_rollback_into_shared_page_copies_on_rewind(self):
        """A slot whose table block 0 points at a SHARED page rolls back
        to a position INSIDE that page: the shared page must stay
        bit-identical (other residents reference it), the slot's private
        page receives the live prefix, and the table re-points — so the
        next append lands in private storage."""
        ps, nb = 8, 4
        cache = self._paged(extra=2, ps=ps, nb=nb)
        shared_page = nb  # first extra page
        private = jnp.arange(nb, dtype=jnp.int32)[None]       # (1, NB)
        cache = set_table_row(cache, 0, private.at[0, 0].set(shared_page)[0])
        shared_k = np.asarray(cache.k[shared_page]).copy()
        rolled = cache.rollback(jnp.asarray([2], jnp.int32),
                                private_row=private)
        # shared page bit-identical; private page 0 holds its copy
        np.testing.assert_array_equal(np.asarray(rolled.k[shared_page]),
                                      shared_k)
        np.testing.assert_array_equal(np.asarray(rolled.k[0]), shared_k)
        np.testing.assert_array_equal(np.asarray(rolled.table[0]),
                                      np.arange(nb))
        # an append at the rolled-back position mutates ONLY private
        # storage: the live prefix [0, 2) and the shared page survive
        kq = _tiles(jax.random.PRNGKey(7), 1, 2, kv=1, d=8)
        after = rolled.append_slots(kq, kq, jnp.asarray([2], jnp.int32))
        np.testing.assert_array_equal(np.asarray(after.k[shared_page]),
                                      shared_k)
        np.testing.assert_array_equal(np.asarray(after.k[0, :2]),
                                      shared_k[:2])
        np.testing.assert_array_equal(np.asarray(after.k[0, 2:4]),
                                      np.asarray(kq[0]))

    def test_paged_rollback_at_boundary_keeps_shared_prefix(self):
        """Rolling back to a page boundary leaves fully-live shared pages
        attached by reference (no copy, no table change for them) — only
        blocks at/after the boundary re-point."""
        ps, nb = 8, 4
        cache = self._paged(extra=2, ps=ps, nb=nb)
        shared_page = nb
        private = jnp.arange(nb, dtype=jnp.int32)[None]
        cache = set_table_row(cache, 0, private.at[0, 0].set(shared_page)[0])
        rolled = cache.rollback(jnp.asarray([ps], jnp.int32),
                                private_row=private)
        # block 0 still points at the shared page (its tokens are live)
        assert int(rolled.table[0, 0]) == shared_page
        np.testing.assert_array_equal(np.asarray(rolled.table[0, 1:]),
                                      np.arange(1, nb))

    def test_paged_rollback_without_rows_is_noop(self):
        cache = self._paged()
        rolled = cache.rollback(jnp.asarray([2], jnp.int32))
        for a, b in zip(jax.tree.leaves(rolled), jax.tree.leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_paged_rollback_stacked_layers(self):
        """A scanned-stack cache (leading (L,) axis on every leaf, one
        table per layer with identical entries) rolls back through the
        same trailing-axes math."""
        ps, nb, L = 8, 2, 3
        one = self._paged(extra=1, ps=ps, nb=nb)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)
        shared_page = nb
        private = jnp.arange(nb, dtype=jnp.int32)[None]
        stacked = set_table_row(stacked, 0,
                                private.at[0, 0].set(shared_page)[0])
        rolled = stacked.rollback(jnp.asarray([1], jnp.int32),
                                  private_row=private)
        assert rolled.table.shape == (L, 1, nb)
        np.testing.assert_array_equal(np.asarray(rolled.table[:, 0, 0]),
                                      np.zeros(L))
        for l in range(L):
            np.testing.assert_array_equal(
                np.asarray(rolled.k[l, 0]), np.asarray(stacked.k[l,
                                                                 shared_page]))


class TestMultiTokenAppendSlots:
    """The speculative verify window writes s > 1 tokens per slot in one
    ``append_slots`` — must equal s sequential one-token appends, per
    layout, including inactive-slot read-back neutrality and page-
    boundary-crossing windows."""

    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_window_equals_sequential_single_appends(self, layout):
        if layout == "dense":
            mk = lambda: DenseCache.init(B, 16, 2, 8, dtype=jnp.int8,
                                         quantized=True)
        else:
            mk = lambda: PagedCache.init(B, 16, 2, 8, dtype=jnp.int8,
                                         quantized=True, page_size=8)
        kq = _tiles(jax.random.PRNGKey(0), B, 3)
        vq = _tiles(jax.random.PRNGKey(1), B, 3)
        starts = jnp.asarray([2, 7], jnp.int32)   # slot 1 crosses a page
        active = jnp.asarray([True, False])
        win = mk().append_slots(kq, vq, starts, active=active)
        seq = mk()
        for j in range(3):
            seq = seq.append_slots(kq[:, j:j + 1], vq[:, j:j + 1],
                                   starts + j, active=active)
        for a, b in zip(jax.tree.leaves(win), jax.tree.leaves(seq)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPrefixStoreResilience:
    """Degradation paths of the shared-prefix allocator: pool exhaustion
    (the scheduler's ``alloc is None`` branch), LRU eviction, and
    refcount pinning — registration is opportunistic, so every denial
    must be silent, counted, and leave the store consistent."""

    def _store(self, n_pages=2, page_size=8):
        from repro.cache.paged import PrefixStore
        return PrefixStore(first_page=10, n_pages=n_pages,
                           page_size=page_size)

    def _register(self, store, key, length):
        from repro.cache.paged import PrefixEntry
        alloc = store.reserve(key, length)
        if alloc is None:
            return None
        pages, tail = alloc
        entry = PrefixEntry(pages=pages, tail_page=tail, length=length,
                            logits=np.zeros((1, 1, 4), np.float32))
        store.register(key, entry)
        return entry

    def test_exhaustion_returns_none_and_counts(self):
        store = self._store(n_pages=2, page_size=8)
        assert self._register(store, ("a",), 16) is not None  # both pages
        assert store.lookup(("a",), slot=0) is not None       # pin it
        assert store.reserve(("b",), 8) is None
        assert store.stats()["exhausted"] == 1
        # duplicate keys and zero-length prompts are denials, NOT
        # exhaustion — only a genuinely full pool bumps the counter
        assert store.reserve(("a",), 16) is None
        assert store.reserve(("c",), 0) is None
        assert store.stats()["exhausted"] == 1

    def test_lru_eviction_frees_unreferenced_entries(self):
        store = self._store(n_pages=2, page_size=8)
        assert self._register(store, ("a",), 8) is not None
        assert self._register(store, ("b",), 8) is not None   # pool full
        # no live users -> the least-recently-used entry ("a") is evicted
        assert self._register(store, ("c",), 8) is not None
        stats = store.stats()
        assert stats["evictions"] == 1
        assert stats["entries"] == 2
        assert store.lookup(("a",), slot=0) is None           # gone
        assert store.lookup(("b",), slot=0) is not None       # survived

    def test_refcount_pins_entry_against_eviction(self):
        store = self._store(n_pages=2, page_size=8)
        assert self._register(store, ("a",), 8) is not None
        assert self._register(store, ("b",), 8) is not None
        assert store.lookup(("a",), slot=3) is not None       # pin "a"
        # reclaim must step over the pinned entry and evict "b" instead,
        # even though "a" is older
        assert self._register(store, ("c",), 8) is not None
        assert store.lookup(("a",), slot=4) is not None
        assert store.lookup(("b",), slot=4) is None
        # releasing every holder makes "a" evictable again
        store.release(3)
        store.release(4)
        assert self._register(store, ("d",), 8) is not None
        assert store.stats()["evictions"] >= 2

    def test_tail_page_returned_on_eviction(self):
        store = self._store(n_pages=3, page_size=8)
        assert self._register(store, ("a",), 12) is not None  # 1 full + tail
        assert self._register(store, ("b",), 8) is not None   # last page
        # evicting "a" must return BOTH its full page and its tail page
        assert self._register(store, ("c",), 16) is not None
        assert store.stats()["evictions"] >= 1
        assert store.stats()["free_pages"] == 0
